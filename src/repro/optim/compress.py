"""Int8 gradient compression with error feedback (cross-pod sync).

Cross-pod links are the scarcest bandwidth in a multi-pod job (data-centre
network vs. intra-pod ICI), so the SWIRL ``gradsync`` step compresses the
pod-level gradient before its send/recv exchange:

* per-row (last-axis) absmax scaling to int8 — 4× fewer bytes than bf16·2;
* *error feedback* (Seide et al., 1-bit SGD lineage): the quantisation
  residual is added back to the next step's gradient, so the compression
  bias telescopes and SGD-style convergence is preserved.

These are pure functions over pytrees — used by the workflow-level trainer
(`launch/train.py`) between ``fwdbwd`` and ``update`` steps, and unit-tested
for the telescoping property.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Compressed(NamedTuple):
    q: PyTree  # int8 leaves
    scale: PyTree  # fp32 per-row scales


def _quant_leaf(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    g32 = g.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(g32), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress(grads: PyTree, error: PyTree | None = None) -> tuple[Compressed, PyTree]:
    """Quantise ``grads + error``; returns (compressed, new error feedback)."""
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    corrected = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, error
    )
    q = jax.tree.map(lambda c: _quant_leaf(c)[0], corrected)
    s = jax.tree.map(lambda c: _quant_leaf(c)[1], corrected)
    deq = jax.tree.map(_dequant_leaf, q, s)
    new_error = jax.tree.map(lambda c, d: c - d, corrected, deq)
    return Compressed(q=q, scale=s), new_error


def decompress(c: Compressed) -> PyTree:
    return jax.tree.map(_dequant_leaf, c.q, c.scale)


def allreduce_mean(parts: list[PyTree]) -> PyTree:
    """Host-side mean of decompressed pod gradients (gradsync step body)."""
    n = float(len(parts))
    out = parts[0]
    for p in parts[1:]:
        out = jax.tree.map(lambda a, b: a + b, out, p)
    return jax.tree.map(lambda a: a / n, out)


def compressed_bytes(c: Compressed) -> int:
    qb = sum(l.size for l in jax.tree.leaves(c.q))
    sb = sum(l.size * 4 for l in jax.tree.leaves(c.scale))
    return qb + sb
