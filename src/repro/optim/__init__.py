"""Optimisation: AdamW + schedule, ZeRO-1 specs, int8 gradient compression."""

from .adamw import AdamWConfig, AdamWState, global_norm, init, schedule, update
from .compress import (
    Compressed,
    allreduce_mean,
    compress,
    compressed_bytes,
    decompress,
)
from .zero import zero1_specs

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "init",
    "update",
    "schedule",
    "global_norm",
    "compress",
    "decompress",
    "allreduce_mean",
    "Compressed",
    "compressed_bytes",
    "zero1_specs",
]
