"""AdamW with warmup-cosine schedule and global-norm clipping.

Pure-pytree implementation (no optax dependency): ``init`` builds fp32
moments regardless of param dtype (mixed precision: bf16 params, fp32
state); ``update`` returns new params cast back to the param dtype.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array  # int32
    m: PyTree
    v: PyTree


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to ``min_lr_ratio × lr``."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps
    )
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(math.pi * t)
    )
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def update(
    cfg: AdamWConfig,
    grads: PyTree,
    state: AdamWState,
    params: PyTree,
) -> tuple[PyTree, AdamWState, dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    # Three passes (XLA CSEs the duplicated arithmetic under jit) — avoids
    # tuple-leaf ambiguity: param trees legitimately contain tuples (the
    # scanned "body" groups), so tuple-is-leaf transposition is unsafe.
    new_params = jax.tree.map(
        lambda p, g, m, v: upd(p, g, m, v)[0], params, grads, state.m, state.v
    )
    new_m = jax.tree.map(
        lambda p, g, m, v: upd(p, g, m, v)[1], params, grads, state.m, state.v
    )
    new_v = jax.tree.map(
        lambda p, g, m, v: upd(p, g, m, v)[2], params, grads, state.m, state.v
    )
    return (
        new_params,
        AdamWState(step=step, m=new_m, v=new_v),
        {"grad_norm": gnorm, "lr": lr},
    )
