"""ZeRO-1: shard optimizer moments over the data axis.

Params are TP-sharded (their PartitionSpec uses the ``model`` axis); AdamW
moments are element-wise state, so each may *additionally* be sharded over
``data`` — the classic ZeRO-1 memory split.  ``zero1_specs`` augments each
param spec: the first dimension that (a) is unsharded and (b) divides the
data-axis size takes ``"data"``.  XLA then materialises the ZeRO pattern:
moments update sharded; the param delta is all-gathered over ``data`` during
the parameter update (exactly ZeRO-1's gather-after-update).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

PyTree = Any


def _augment(spec: P, shape: tuple[int, ...], data_axis: str, data_size: int) -> P:
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, cur) in enumerate(zip(shape, parts)):
        if cur is None and dim % data_size == 0 and dim >= data_size:
            parts[i] = data_axis
            return P(*parts)
    return P(*parts)  # nothing divisible — leave as the param spec


def zero1_specs(
    param_specs: PyTree,
    param_shapes: PyTree,
    *,
    data_axis: str = "data",
    data_size: int = 1,
) -> PyTree:
    """PartitionSpecs for AdamW m/v given the param specs and shapes."""
    return jax.tree.map(
        lambda s, sh: _augment(s, tuple(sh.shape) if hasattr(sh, "shape") else tuple(sh), data_axis, data_size),
        param_specs,
        param_shapes,
        is_leaf=lambda x: isinstance(x, P),
    )
