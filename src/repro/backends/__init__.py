"""Pluggable backend registry for the staged-compilation pipeline.

``Plan.lower(backend="...")`` resolves names through this registry.  Four
backends are built in (``inprocess``, ``threaded``, ``multiprocess``,
``jax``); third parties add their own either programmatically::

    from repro.backends import register_backend
    register_backend("mycluster", MyClusterBackend)

or declaratively via the ``repro.backends`` entry-point group::

    [project.entry-points."repro.backends"]
    mycluster = "mypkg.backend:factory"

Factories are zero-argument callables returning a :class:`Backend`; they are
invoked lazily so registering (or merely installing) a backend never imports
its heavyweight dependencies.
"""

from __future__ import annotations

from typing import Callable

from .base import (
    Backend,
    BackendCapabilityError,
    BackendProgram,
    ExecutionResult,
    UnknownBackendError,
)
from .multiprocess import WorkerFailedError

__all__ = [
    "Backend",
    "BackendProgram",
    "BackendCapabilityError",
    "ExecutionResult",
    "UnknownBackendError",
    "WorkerFailedError",
    "register_backend",
    "get_backend",
    "available_backends",
]

BackendFactory = Callable[[], Backend]

_REGISTRY: dict[str, BackendFactory] = {}
_entry_points_loaded = False


def _builtin(module: str) -> BackendFactory:
    def load() -> Backend:
        import importlib

        return importlib.import_module(module).factory()

    return load


_REGISTRY.update(
    {
        "inprocess": _builtin("repro.backends.inprocess"),
        "threaded": _builtin("repro.backends.threaded_backend"),
        "multiprocess": _builtin("repro.backends.multiprocess"),
        "jax": _builtin("repro.backends.jax_backend"),
    }
)


def _load_entry_points() -> None:
    """Merge ``repro.backends`` entry points into the registry (once)."""
    global _entry_points_loaded
    if _entry_points_loaded:
        return
    _entry_points_loaded = True
    try:
        from importlib.metadata import entry_points

        for ep in entry_points(group="repro.backends"):
            # Explicit registrations and built-ins win over entry points.
            _REGISTRY.setdefault(ep.name, ep.load)
    except Exception:  # pragma: no cover - metadata lookup is best-effort
        pass


def register_backend(
    name: str, factory: BackendFactory, *, overwrite: bool = False
) -> None:
    """Register ``factory`` under ``name`` (entry-point style, in process)."""
    if not overwrite and name in _REGISTRY:
        raise ValueError(f"backend {name!r} is already registered")
    _REGISTRY[name] = factory


def get_backend(name: str) -> Backend:
    """Instantiate the backend registered under ``name``."""
    if name not in _REGISTRY:
        _load_entry_points()
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise UnknownBackendError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None
    backend = factory()
    if not isinstance(backend, Backend):
        raise TypeError(
            f"backend factory for {name!r} returned {type(backend).__name__},"
            " not a repro.backends.Backend"
        )
    return backend


def available_backends() -> tuple[str, ...]:
    _load_entry_points()
    return tuple(sorted(_REGISTRY))
