"""``jax`` backend — interpret location programs on a JAX host device mesh.

Each SWIRL location is pinned to a JAX device (round-robin over the host
mesh, or an explicit ``devices=`` option).  The compiled artifact then
interprets the per-location program IR deterministically:

* an enabled ``ExecOp`` runs the step function with its inputs resident on
  the leader location's device and replicates ``Out^D(s)`` onto every
  device of ``M(s)`` — the (EXEC) rule's "add to every ``D_i``" becomes
  ``jax.device_put``;
* a matching ``SendOp``/``RecvOp`` pair moves the payload to the
  destination location's device — (COMM) as a device-to-device copy.

Only array payloads (``jax.Array`` / ``numpy.ndarray``) are staged through
the device API; plain Python payloads are copied by reference, so results
are bit-identical with the other backends on non-numeric workflows.  This is
the lowering the mesh trainer builds on: SWIRL send/recv pairs between
locations on one mesh axis are exactly what ``ppermute``-style collectives
implement at scale (see ``launch/sharding.py``).
"""

from __future__ import annotations

import time
from typing import Any, Mapping

from repro.core.compile import StepMeta
from repro.core.syntax import WorkflowSystem
from repro.exec.interp import (
    Cursor,
    Deadline,
    StepGuard,
    enabled_exec_picks,
    first_enabled_comm,
    record_comm_fire,
    record_exec_fire,
    record_policy_fire,
)
from repro.exec.program import ExecProgram

from .base import Backend, BackendProgram, ExecutionResult, PayloadKey


def _is_array(x: Any) -> bool:
    import jax
    import numpy as np

    return isinstance(x, (jax.Array, np.ndarray))


def _plan_segments(program, *, min_len: int = 2) -> dict[int, list]:
    """Partition the deterministic exec firing order into fusable runs.

    The reducer's firing order depends only on cursor states and data
    *names*, never on payload values, so it can be replayed statically:
    simulate the run loop (drain comms, fire the lowest-named enabled
    exec) without calling any step body and record where straight-line
    EXEC runs break — at a COMM boundary, or when the leader location
    changes (a fused program runs on one device).  Returns
    ``{start_exec_index: [(ExecOp, picks), ...]}`` for every run of at
    least ``min_len`` ops — the picks are recorded at plan time so the
    runtime replays cursor completions directly instead of re-scanning
    enabledness per op; the runtime counts fired execs and swaps in the
    jitted segment when the counter hits a start index.
    """
    cursors = {lp.location: Cursor(lp) for lp in program.programs}
    data = {lp.location: set(lp.data) for lp in program.programs}
    order = sorted(cursors)
    seq: list = []
    breaks: set[int] = set()
    while True:
        comm_fired = False
        while True:
            hit = first_enabled_comm(cursors, data, order)
            if hit is None:
                break
            op, src, i, j = hit
            cursors[src].complete(i)
            cursors[op.dst].complete(j)
            data[op.dst].add(op.data)
            comm_fired = True
        execs = sorted(
            enabled_exec_picks(cursors, data, order),
            key=lambda pair: pair[0].step,
        )
        if not execs:
            break
        op, picks = execs[0]
        if (
            not seq
            or comm_fired
            or min(op.locations) != min(seq[-1][0].locations)
        ):
            breaks.add(len(seq))
        seq.append((op, picks))
        for loc, i in picks:
            cursors[loc].complete(i)
            data[loc].update(op.outputs)
    segments: dict[int, list] = {}
    starts = sorted(breaks) + [len(seq)]
    for a, b in zip(starts, starts[1:]):
        if b - a >= min_len:
            segments[a] = seq[a:b]
    return segments


class _FusedSegment:
    """One straight-line EXEC run compiled to a single jitted call.

    The segment function threads a data-name environment through the
    run's step bodies and returns every datum the run produces, so the
    per-location stores a fused run leaves behind are identical to the
    interpreted ones.  The env is split into ``(donated, kept)`` dicts:
    inputs the segment overwrites and that no other store entry aliases
    are donated so XLA can reuse their buffers in place (donation is
    skipped on CPU where the runtime does not support it).
    """

    def __init__(self, acts: list, steps: Mapping[str, StepMeta]):
        import jax

        self.acts = acts  # [(ExecOp, picks), ...] in firing order
        ops = [op for op, _ in acts]
        self.leader = min(ops[0].locations)
        produced: set[str] = set()
        ext: list[str] = []
        for op in ops:
            for d in op.inputs:
                if d not in produced and d not in ext:
                    ext.append(d)
            produced.update(op.outputs)
        self.ext = ext
        self.produced = produced
        # Data overwritten by the segment may have its input buffer
        # donated; everything else must survive the call.
        self.donatable = [d for d in ext if d in produced]
        self.out_names: list[str] = []
        for op in ops:
            for d in op.outputs:
                if d not in self.out_names:
                    self.out_names.append(d)
        step_fns = {op.step: steps[op.step].fn for op in ops}
        seg_ops = list(ops)
        out_names = list(self.out_names)

        def seg_fn(donated: dict, kept: dict) -> dict:
            env = dict(donated)
            env.update(kept)
            for op in seg_ops:
                out = step_fns[op.step]({d: env[d] for d in op.inputs})
                for d in op.outputs:
                    env[d] = out[d]
            return {d: env[d] for d in out_names}

        self.fn = jax.jit(seg_fn, donate_argnums=(0,))
        self.calls = 0
        self.seconds = 0.0  # warm (post-compile) call time only
        self.bytes = 0


class JaxMeshProgram(BackendProgram):
    def _device_map(self) -> dict[str, Any]:
        import jax

        devices = self.options.get("devices")
        if devices is None:
            platform = self.options.get("platform")
            devices = jax.devices(platform) if platform else jax.devices()
        locs = sorted(self.program.locations())
        schedule = self.options.get("schedule")
        if schedule is not None and getattr(schedule, "network", None):
            # Placement scheduler hand-down: keep each network group's
            # locations on one contiguous device block, so the cheap links
            # of the cost model map to intra-device placement.
            net = schedule.network
            locs.sort(key=lambda l: (net.group_of(l) or "", l))
            return {
                loc: devices[i * len(devices) // len(locs)]
                for i, loc in enumerate(locs)
            }
        return {loc: devices[i % len(devices)] for i, loc in enumerate(locs)}

    def run(
        self, initial_payloads: Mapping[PayloadKey, Any] | None = None
    ) -> ExecutionResult:
        import jax

        recorder = None
        if self.options.get("trace"):
            from repro.obs.events import TraceRecorder

            recorder = TraceRecorder()
        device_of = self._device_map()
        stats = {
            "execs": 0,
            "comms": 0,
            "device_puts": 0,
            "bytes_moved": 0,
            "devices": {l: str(d) for l, d in device_of.items()},
        }
        # Uniform fault policy: the deterministic reducer guards each step
        # fire with the shared timeout + retry helper and checks the run
        # deadline once per reduction round.
        policy = self.options.get("policy")
        guard = None
        deadline = Deadline(None)
        if policy is not None:
            guard = StepGuard(
                policy,
                on_retry=lambda step, n, e: record_policy_fire(
                    recorder, "retry", "-", step,
                    time.monotonic(), time.monotonic(),
                ),
            )
            deadline = Deadline(policy.deadline_s)
            stats["policy"] = {"retries": 0, "timeouts": 0}

        def place(loc: str, value: Any) -> Any:
            if not _is_array(value):
                return value
            stats["device_puts"] += 1
            stats["bytes_moved"] += int(getattr(value, "nbytes", 0))
            return jax.device_put(value, device_of[loc])

        payloads: dict[PayloadKey, Any] = {}
        for (loc, d), v in (initial_payloads or {}).items():
            payloads[(loc, d)] = place(loc, v)

        cursors = {
            lp.location: Cursor(lp) for lp in self.program.programs
        }
        data = {lp.location: set(lp.data) for lp in self.program.programs}
        order = sorted(cursors)

        def fire_one_comm() -> bool:
            hit = first_enabled_comm(cursors, data, order)
            if hit is None:
                return False
            op, src, i, j = hit
            cursors[src].complete(i)
            cursors[op.dst].complete(j)
            data[op.dst].add(op.data)
            if recorder is None:
                payloads[(op.dst, op.data)] = place(
                    op.dst, payloads[(op.src, op.data)]
                )
            else:
                payload = payloads[(op.src, op.data)]
                t0 = time.monotonic()
                payloads[(op.dst, op.data)] = place(op.dst, payload)
                record_comm_fire(
                    recorder, op, t0, time.monotonic(), payload
                )
            stats["comms"] += 1
            return True

        # Fused location programs: straight-line EXEC runs become single
        # jitted calls (segmented at COMM boundaries).  A fault policy
        # guard wraps individual step fires, which a fused call cannot
        # honour, so fusion is skipped when a guard is active.
        fuse = bool(self.options.get("fuse")) and guard is None
        if fuse and not hasattr(self, "_segments"):
            # Plan once per compiled program; jitted segment functions
            # live across run() calls so repeat runs hit XLA's cache
            # (and warm-call bandwidth is what roofline reports).
            self._segments = _plan_segments(self.program)
            self._seg_cache: dict[int, Any] = {}
        segments = self._segments if fuse else {}
        seg_cache = self._seg_cache if fuse else {}
        if fuse:
            stats["fused"] = {
                "segments_planned": len(segments),
                "fused_calls": 0,
                "fused_execs": 0,
                "fallbacks": 0,
                "locations": {},
            }
        exec_count = 0

        def run_segment(start: int) -> bool:
            """Fire a whole planned segment as one jitted call.

            Returns False (after caching the verdict) when the segment
            must stay interpreted — non-array inputs, or a step body
            that does not trace; the caller then falls through to the
            op-by-op path for every op in the run.
            """
            import time as _time

            seg = seg_cache.get(start)
            if seg == "eager":
                return False
            acts = segments[start]
            if seg is None:
                seg = _FusedSegment(acts, self.steps)
                seg_cache[start] = seg
            env = {d: payloads[(seg.leader, d)] for d in seg.ext}
            if not all(_is_array(v) for v in env.values()):
                seg_cache[start] = "eager"
                stats["fused"]["fallbacks"] += 1
                return False
            donated: dict[str, Any] = {}
            platform = getattr(device_of[seg.leader], "platform", "cpu")
            if platform != "cpu":
                for d in seg.donatable:
                    v = env[d]
                    if all(
                        d2 == d and l2 == seg.leader
                        for (l2, d2), v2 in payloads.items()
                        if v2 is v
                    ):
                        donated[d] = v
            kept = {d: v for d, v in env.items() if d not in donated}
            first_call = seg.calls == 0
            try:
                import jax

                t0 = _time.perf_counter()
                out = jax.block_until_ready(seg.fn(donated, kept))
                dt = _time.perf_counter() - t0
            except Exception:  # not traceable / unsupported payloads
                seg_cache[start] = "eager"
                stats["fused"]["fallbacks"] += 1
                return False
            seg.calls += 1
            moved = sum(
                int(getattr(v, "nbytes", 0)) for v in env.values()
            ) + sum(int(getattr(v, "nbytes", 0)) for v in out.values())
            if not first_call:
                # First call pays tracing + XLA compile; only warm calls
                # count toward achieved-bandwidth reporting.
                seg.seconds += dt
                seg.bytes += moved
            loc_stats = stats["fused"]["locations"].setdefault(
                seg.leader,
                {"calls": 0, "execs": 0, "bytes": 0, "seconds": 0.0},
            )
            loc_stats["calls"] += 1
            loc_stats["execs"] += len(acts)
            if not first_call:
                loc_stats["bytes"] += moved
                loc_stats["seconds"] += dt
            stats["fused"]["fused_calls"] += 1
            stats["fused"]["fused_execs"] += len(acts)
            # Replay the run's cursor/data effects from the recorded
            # plan — the values came from the fused call, the
            # bookkeeping (and the replication of Out^D(s) onto every
            # D_i) is unchanged.  Outputs already live on the leader's
            # device, so placement only pays for genuinely remote
            # locations.
            leader_dev = device_of[seg.leader]
            for op, picks in acts:
                if recorder is not None:
                    record_exec_fire(recorder, op, t0, t0 + dt)
                missing = set(op.outputs) - set(out)
                if missing:
                    raise RuntimeError(
                        f"step {op.step!r} did not produce "
                        f"{sorted(missing)}"
                    )
                for loc, i in picks:
                    cursors[loc].complete(i)
                    data[loc].update(op.outputs)
                    for d in op.outputs:
                        payloads[(loc, d)] = (
                            out[d]
                            if device_of[loc] is leader_dev
                            else place(loc, out[d])
                        )
                stats["execs"] += 1
            return True

        max_rounds = int(self.options.get("max_rounds", 1_000_000))
        for _ in range(max_rounds):
            deadline.check()
            progressed = False
            # Drain communications first (they are τ — silent, confluent).
            while fire_one_comm():
                progressed = True
            if fuse and exec_count in segments:
                if run_segment(exec_count):
                    exec_count += len(segments[exec_count])
                    progressed = True
                    continue
            # Deterministic firing order: lowest step name first.
            execs = sorted(
                enabled_exec_picks(cursors, data, order),
                key=lambda pair: pair[0].step,
            )
            if execs:
                op, picks = execs[0]
                leader = min(op.locations)
                inputs = {d: payloads[(leader, d)] for d in op.inputs}
                fn = self.steps[op.step].fn
                fire = (
                    (lambda: guard.fire(op.step, lambda: fn(inputs)))
                    if guard is not None
                    else (lambda: fn(inputs))
                )
                if recorder is None:
                    out = fire()
                else:
                    t0 = time.monotonic()
                    out = fire()
                    record_exec_fire(recorder, op, t0, time.monotonic())
                missing = set(op.outputs) - set(out)
                if missing:
                    raise RuntimeError(
                        f"step {op.step!r} did not produce {sorted(missing)}"
                    )
                for loc, i in picks:
                    cursors[loc].complete(i)
                    data[loc].update(op.outputs)
                    for d in op.outputs:
                        payloads[(loc, d)] = place(loc, out[d])
                stats["execs"] += 1
                exec_count += 1
                progressed = True
            if not progressed:
                break

        if fuse:
            from repro.roofline import HBM_BW

            roofline = {}
            for loc, ls in stats["fused"]["locations"].items():
                achieved = (
                    ls["bytes"] / ls["seconds"] if ls["seconds"] > 0 else 0.0
                )
                roofline[loc] = {
                    "achieved_bytes_per_s": achieved,
                    "theoretical_bytes_per_s": HBM_BW,
                    "fraction_of_roof": achieved / HBM_BW,
                }
            stats["fused"]["roofline"] = roofline
        if guard is not None:
            stats["policy"] = guard.counts()
        if not all(c.finished() for c in cursors.values()):
            remaining = self.program.remaining_system(
                {l: c.done_flags() for l, c in cursors.items()},
                {l: frozenset(d) for l, d in data.items()},
            )
            raise RuntimeError(
                "jax backend: workflow did not terminate; remaining:\n"
                + remaining.pretty()
            )
        result: dict[str, dict[str, Any]] = {
            loc: {} for loc in self.program.locations()
        }
        for (loc, d), v in payloads.items():
            result.setdefault(loc, {})[d] = v
        profile = None
        if recorder is not None:
            from repro.obs.profile import RunProfile

            profile = RunProfile.from_recorder("jax", recorder)
        return ExecutionResult(
            backend="jax", data=result, stats=stats, profile=profile
        )


class JaxBackend(Backend):
    name = "jax"
    capabilities = frozenset({"mesh", "device-placement"})

    def known_options(self) -> frozenset[str]:
        return super().known_options() | frozenset(
            {"devices", "platform", "max_rounds", "fuse"}
        )

    def compile(
        self,
        program: ExecProgram | WorkflowSystem,
        steps: Mapping[str, StepMeta],
        options: Mapping[str, Any],
    ) -> JaxMeshProgram:
        return JaxMeshProgram(
            program=self.lower(program, options),
            steps=dict(steps),
            options=dict(options),
        )


def factory() -> Backend:
    return JaxBackend()
