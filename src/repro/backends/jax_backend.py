"""``jax`` backend — interpret location programs on a JAX host device mesh.

Each SWIRL location is pinned to a JAX device (round-robin over the host
mesh, or an explicit ``devices=`` option).  The compiled artifact then
interprets the per-location program IR deterministically:

* an enabled ``ExecOp`` runs the step function with its inputs resident on
  the leader location's device and replicates ``Out^D(s)`` onto every
  device of ``M(s)`` — the (EXEC) rule's "add to every ``D_i``" becomes
  ``jax.device_put``;
* a matching ``SendOp``/``RecvOp`` pair moves the payload to the
  destination location's device — (COMM) as a device-to-device copy.

Only array payloads (``jax.Array`` / ``numpy.ndarray``) are staged through
the device API; plain Python payloads are copied by reference, so results
are bit-identical with the other backends on non-numeric workflows.  This is
the lowering the mesh trainer builds on: SWIRL send/recv pairs between
locations on one mesh axis are exactly what ``ppermute``-style collectives
implement at scale (see ``launch/sharding.py``).
"""

from __future__ import annotations

import time
from typing import Any, Mapping

from repro.core.compile import StepMeta
from repro.core.syntax import WorkflowSystem
from repro.exec.interp import (
    Cursor,
    Deadline,
    StepGuard,
    enabled_exec_picks,
    first_enabled_comm,
    record_comm_fire,
    record_exec_fire,
    record_policy_fire,
)
from repro.exec.program import ExecProgram

from .base import Backend, BackendProgram, ExecutionResult, PayloadKey


def _is_array(x: Any) -> bool:
    import jax
    import numpy as np

    return isinstance(x, (jax.Array, np.ndarray))


class JaxMeshProgram(BackendProgram):
    def _device_map(self) -> dict[str, Any]:
        import jax

        devices = self.options.get("devices")
        if devices is None:
            platform = self.options.get("platform")
            devices = jax.devices(platform) if platform else jax.devices()
        locs = sorted(self.program.locations())
        schedule = self.options.get("schedule")
        if schedule is not None and getattr(schedule, "network", None):
            # Placement scheduler hand-down: keep each network group's
            # locations on one contiguous device block, so the cheap links
            # of the cost model map to intra-device placement.
            net = schedule.network
            locs.sort(key=lambda l: (net.group_of(l) or "", l))
            return {
                loc: devices[i * len(devices) // len(locs)]
                for i, loc in enumerate(locs)
            }
        return {loc: devices[i % len(devices)] for i, loc in enumerate(locs)}

    def run(
        self, initial_payloads: Mapping[PayloadKey, Any] | None = None
    ) -> ExecutionResult:
        import jax

        recorder = None
        if self.options.get("trace"):
            from repro.obs.events import TraceRecorder

            recorder = TraceRecorder()
        device_of = self._device_map()
        stats = {
            "execs": 0,
            "comms": 0,
            "device_puts": 0,
            "bytes_moved": 0,
            "devices": {l: str(d) for l, d in device_of.items()},
        }
        # Uniform fault policy: the deterministic reducer guards each step
        # fire with the shared timeout + retry helper and checks the run
        # deadline once per reduction round.
        policy = self.options.get("policy")
        guard = None
        deadline = Deadline(None)
        if policy is not None:
            guard = StepGuard(
                policy,
                on_retry=lambda step, n, e: record_policy_fire(
                    recorder, "retry", "-", step,
                    time.monotonic(), time.monotonic(),
                ),
            )
            deadline = Deadline(policy.deadline_s)
            stats["policy"] = {"retries": 0, "timeouts": 0}

        def place(loc: str, value: Any) -> Any:
            if not _is_array(value):
                return value
            stats["device_puts"] += 1
            stats["bytes_moved"] += int(getattr(value, "nbytes", 0))
            return jax.device_put(value, device_of[loc])

        payloads: dict[PayloadKey, Any] = {}
        for (loc, d), v in (initial_payloads or {}).items():
            payloads[(loc, d)] = place(loc, v)

        cursors = {
            lp.location: Cursor(lp) for lp in self.program.programs
        }
        data = {lp.location: set(lp.data) for lp in self.program.programs}
        order = sorted(cursors)

        def fire_one_comm() -> bool:
            hit = first_enabled_comm(cursors, data, order)
            if hit is None:
                return False
            op, src, i, j = hit
            cursors[src].complete(i)
            cursors[op.dst].complete(j)
            data[op.dst].add(op.data)
            if recorder is None:
                payloads[(op.dst, op.data)] = place(
                    op.dst, payloads[(op.src, op.data)]
                )
            else:
                payload = payloads[(op.src, op.data)]
                t0 = time.monotonic()
                payloads[(op.dst, op.data)] = place(op.dst, payload)
                record_comm_fire(
                    recorder, op, t0, time.monotonic(), payload
                )
            stats["comms"] += 1
            return True

        max_rounds = int(self.options.get("max_rounds", 1_000_000))
        for _ in range(max_rounds):
            deadline.check()
            progressed = False
            # Drain communications first (they are τ — silent, confluent).
            while fire_one_comm():
                progressed = True
            # Deterministic firing order: lowest step name first.
            execs = sorted(
                enabled_exec_picks(cursors, data, order),
                key=lambda pair: pair[0].step,
            )
            if execs:
                op, picks = execs[0]
                leader = min(op.locations)
                inputs = {d: payloads[(leader, d)] for d in op.inputs}
                fn = self.steps[op.step].fn
                fire = (
                    (lambda: guard.fire(op.step, lambda: fn(inputs)))
                    if guard is not None
                    else (lambda: fn(inputs))
                )
                if recorder is None:
                    out = fire()
                else:
                    t0 = time.monotonic()
                    out = fire()
                    record_exec_fire(recorder, op, t0, time.monotonic())
                missing = set(op.outputs) - set(out)
                if missing:
                    raise RuntimeError(
                        f"step {op.step!r} did not produce {sorted(missing)}"
                    )
                for loc, i in picks:
                    cursors[loc].complete(i)
                    data[loc].update(op.outputs)
                    for d in op.outputs:
                        payloads[(loc, d)] = place(loc, out[d])
                stats["execs"] += 1
                progressed = True
            if not progressed:
                break

        if guard is not None:
            stats["policy"] = guard.counts()
        if not all(c.finished() for c in cursors.values()):
            remaining = self.program.remaining_system(
                {l: c.done_flags() for l, c in cursors.items()},
                {l: frozenset(d) for l, d in data.items()},
            )
            raise RuntimeError(
                "jax backend: workflow did not terminate; remaining:\n"
                + remaining.pretty()
            )
        result: dict[str, dict[str, Any]] = {
            loc: {} for loc in self.program.locations()
        }
        for (loc, d), v in payloads.items():
            result.setdefault(loc, {})[d] = v
        profile = None
        if recorder is not None:
            from repro.obs.profile import RunProfile

            profile = RunProfile.from_recorder("jax", recorder)
        return ExecutionResult(
            backend="jax", data=result, stats=stats, profile=profile
        )


class JaxBackend(Backend):
    name = "jax"
    capabilities = frozenset({"mesh", "device-placement"})

    def known_options(self) -> frozenset[str]:
        return super().known_options() | frozenset(
            {"devices", "platform", "max_rounds"}
        )

    def compile(
        self,
        program: ExecProgram | WorkflowSystem,
        steps: Mapping[str, StepMeta],
        options: Mapping[str, Any],
    ) -> JaxMeshProgram:
        return JaxMeshProgram(
            program=self.lower(program, options),
            steps=dict(steps),
            options=dict(options),
        )


def factory() -> Backend:
    return JaxBackend()
