"""``jax`` backend — lower location traces onto a JAX host device mesh.

Each SWIRL location is pinned to a JAX device (round-robin over the host
mesh, or an explicit ``devices=`` option).  The program then *reduces* the
system deterministically:

* (EXEC) runs the step function with its inputs resident on the leader
  location's device and replicates ``Out^D(s)`` onto every device of
  ``M(s)`` — the rule's "add to every ``D_i``" becomes ``jax.device_put``;
* (COMM) moves the payload to the destination location's device.

Only array payloads (``jax.Array`` / ``numpy.ndarray``) are staged through
the device API; plain Python payloads are copied by reference, so results
are bit-identical with the other backends on non-numeric workflows.  This is
the lowering the mesh trainer builds on: SWIRL send/recv pairs between
locations on one mesh axis are exactly what ``ppermute``-style collectives
implement at scale (see ``launch/sharding.py``).
"""

from __future__ import annotations

from typing import Any, Mapping

from repro.core.compile import StepMeta
from repro.core.semantics import (
    CommTransition,
    ExecTransition,
    apply_transition,
    enabled_transitions,
)
from repro.core.syntax import WorkflowSystem

from .base import Backend, BackendProgram, ExecutionResult, PayloadKey


def _is_array(x: Any) -> bool:
    import jax
    import numpy as np

    return isinstance(x, (jax.Array, np.ndarray))


class JaxMeshProgram(BackendProgram):
    def _device_map(self) -> dict[str, Any]:
        import jax

        devices = self.options.get("devices")
        if devices is None:
            platform = self.options.get("platform")
            devices = jax.devices(platform) if platform else jax.devices()
        locs = sorted(self.system.locations())
        schedule = self.options.get("schedule")
        if schedule is not None and getattr(schedule, "network", None):
            # Placement scheduler hand-down: keep each network group's
            # locations on one contiguous device block, so the cheap links
            # of the cost model map to intra-device placement.
            net = schedule.network
            locs.sort(key=lambda l: (net.group_of(l) or "", l))
            return {
                loc: devices[i * len(devices) // len(locs)]
                for i, loc in enumerate(locs)
            }
        return {loc: devices[i % len(devices)] for i, loc in enumerate(locs)}

    def run(
        self, initial_payloads: Mapping[PayloadKey, Any] | None = None
    ) -> ExecutionResult:
        import jax

        device_of = self._device_map()
        stats = {
            "execs": 0,
            "comms": 0,
            "device_puts": 0,
            "bytes_moved": 0,
            "devices": {l: str(d) for l, d in device_of.items()},
        }

        def place(loc: str, value: Any) -> Any:
            if not _is_array(value):
                return value
            stats["device_puts"] += 1
            stats["bytes_moved"] += int(getattr(value, "nbytes", 0))
            return jax.device_put(value, device_of[loc])

        payloads: dict[PayloadKey, Any] = {}
        for (loc, d), v in (initial_payloads or {}).items():
            payloads[(loc, d)] = place(loc, v)

        state = self.system
        max_rounds = int(self.options.get("max_rounds", 1_000_000))
        for _ in range(max_rounds):
            progressed = False
            # Drain communications first (they are τ — silent, confluent).
            while True:
                comm = next(
                    (
                        t
                        for t in enabled_transitions(state)
                        if isinstance(t, CommTransition)
                    ),
                    None,
                )
                if comm is None:
                    break
                s = comm.send
                state = apply_transition(state, comm)
                payloads[(s.dst, s.data)] = place(
                    s.dst, payloads[(s.src, s.data)]
                )
                stats["comms"] += 1
                progressed = True
            execs = sorted(
                (
                    t
                    for t in enabled_transitions(state)
                    if isinstance(t, ExecTransition)
                ),
                key=lambda t: t.action.step,
            )
            if execs:
                act = execs[0].action
                leader = sorted(act.locations)[0]
                inputs = {
                    d: payloads[(leader, d)] for d in sorted(act.inputs)
                }
                out = self.steps[act.step].fn(inputs)
                missing = act.outputs - set(out)
                if missing:
                    raise RuntimeError(
                        f"step {act.step!r} did not produce {sorted(missing)}"
                    )
                state = apply_transition(state, execs[0])
                for loc in act.locations:
                    for d in act.outputs:
                        payloads[(loc, d)] = place(loc, out[d])
                stats["execs"] += 1
                progressed = True
            if not progressed:
                break

        if not state.is_terminated():
            raise RuntimeError(
                "jax backend: workflow did not terminate; remaining:\n"
                + state.pretty()
            )
        data: dict[str, dict[str, Any]] = {
            loc: {} for loc in self.system.locations()
        }
        for (loc, d), v in payloads.items():
            data.setdefault(loc, {})[d] = v
        return ExecutionResult(backend="jax", data=data, stats=stats)


class JaxBackend(Backend):
    name = "jax"
    capabilities = frozenset({"mesh", "device-placement"})

    def known_options(self) -> frozenset[str]:
        return super().known_options() | frozenset(
            {"devices", "platform", "max_rounds"}
        )

    def compile(
        self,
        system: WorkflowSystem,
        steps: Mapping[str, StepMeta],
        options: Mapping[str, Any],
    ) -> JaxMeshProgram:
        return JaxMeshProgram(
            system=system, steps=dict(steps), options=dict(options)
        )


def factory() -> Backend:
    return JaxBackend()
