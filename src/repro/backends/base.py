"""The ``Backend`` protocol every execution target implements.

A backend turns a lowered :class:`~repro.exec.program.ExecProgram` — the
per-location executable program IR of :mod:`repro.exec` — plus a step
registry into a :class:`BackendProgram`, the backend-specific compiled
artifact behind :class:`repro.api.Executable`.  Every in-tree backend is an
interpreter over that one IR; none re-derives traces from the recursive
tree form.  Four backends ship in-tree (see :mod:`repro.backends`):

======================  =====================================================
``inprocess``           reduction-driven :class:`repro.workflow.Runtime`
                        (checkpointable, retry/speculation fault tolerance)
``threaded``            decentralised per-location threads over the
                        in-memory transport
                        (:class:`repro.workflow.ThreadedRuntime`)
``multiprocess``        one OS process per location group over the ack-based
                        socket transport; checkpointable, typed
                        ``WorkerFailedError`` on worker death
``jax``                 per-location lowering onto a JAX host device mesh;
                        array payloads are staged with ``jax.device_put``
======================  =====================================================

Third-party backends register through
:func:`repro.backends.register_backend` or the ``repro.backends``
entry-point group declared in ``pyproject.toml``.
"""

from __future__ import annotations

from abc import ABC, abstractmethod
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

from repro.core.compile import StepMeta
from repro.core.syntax import WorkflowSystem
from repro.exec.program import ExecProgram, ensure_program

PayloadKey = tuple[str, str]  # (location, data element)

#: Default bound on concurrently-executing instances in :meth:`run_many`.
DEFAULT_MAX_CONCURRENT = 8


class BackendCapabilityError(NotImplementedError):
    """The selected backend does not support the requested operation."""


class UnknownBackendError(KeyError):
    """No backend registered under the requested name."""


@dataclass
class ExecutionResult:
    """What one :meth:`repro.api.Executable.run` produced.

    ``data`` maps location → data element → payload: the contents of every
    location's data scope after the system terminated, identical across
    backends for the same plan + steps (the bisimulation guarantee made
    observable).
    """

    backend: str
    data: dict[str, dict[str, Any]]
    stats: Any = None
    #: :class:`repro.obs.RunProfile` when the run was traced
    #: (``lower(..., trace=True)``), else ``None``.
    profile: Any = None

    def payload(self, location: str, data: str) -> Any:
        return self.data[location][data]

    def location_data(self, location: str) -> dict[str, Any]:
        return dict(self.data[location])


@dataclass
class BackendProgram(ABC):
    """A compiled, runnable artifact for one backend.

    Holds the lowered :class:`~repro.exec.program.ExecProgram` the backend
    interprets; ``system`` is the SWIRL term view of the same program
    (reconstructed from the op arrays, cached).  Compiled once, a program
    can be run many times — :meth:`run_many` executes a batch of workflow
    instances against the same lowered artifact with a bounded pool.
    """

    program: ExecProgram
    steps: Mapping[str, StepMeta]
    options: dict[str, Any] = field(default_factory=dict)

    @property
    def system(self) -> WorkflowSystem:
        return self.program.system

    @abstractmethod
    def run(
        self, initial_payloads: Mapping[PayloadKey, Any] | None = None
    ) -> ExecutionResult:
        ...

    # -- compile-once / run-many ---------------------------------------------
    def run_many(
        self,
        inputs: Sequence[Mapping[PayloadKey, Any] | None],
        *,
        max_concurrent: int = DEFAULT_MAX_CONCURRENT,
    ) -> list[ExecutionResult]:
        """Execute one workflow instance per entry of ``inputs``.

        All instances interpret the *same* compiled program (encode /
        rewrite / lower / compile are paid once); at most ``max_concurrent``
        instances are in flight at a time.  Results are returned in input
        order.  Backends override :meth:`_run_instance` when per-instance
        isolation needs care (shared transports, mutable snapshot state).
        """
        inputs = list(inputs)
        if max_concurrent < 1:
            raise ValueError(
                f"max_concurrent must be >= 1, got {max_concurrent}"
            )
        if not inputs:
            return []
        results: list[ExecutionResult | None] = [None] * len(inputs)
        workers = min(max_concurrent, len(inputs))
        with ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="swirl-run-many"
        ) as pool:
            futures = [
                pool.submit(self._run_instance, payloads, str(i))
                for i, payloads in enumerate(inputs)
            ]
            errors: list[BaseException] = []
            for i, fut in enumerate(futures):
                try:
                    results[i] = fut.result()
                except BaseException as e:  # noqa: BLE001
                    errors.append(e)
            if errors:
                raise errors[0]
        return results  # type: ignore[return-value]

    def _run_instance(
        self,
        initial_payloads: Mapping[PayloadKey, Any] | None,
        instance_tag: str,
    ) -> ExecutionResult:
        """Run one instance of a :meth:`run_many` batch (override-point)."""
        return self.run(initial_payloads)

    def concurrent_batches(self) -> bool:
        """Whether overlapping whole runs on this one program are safe.

        ``False`` (the default) means a run mutates program-level state —
        snapshot slots, a worker fleet, device buffers — so
        :class:`repro.api.Executable` serialises whole runs behind its
        re-entry guard.  Backends whose runs are fully isolated from each
        other (fresh per-run transports, per-instance endpoint namespaces)
        return ``True`` and one compiled Executable then serves many
        concurrent batches — the serving gateway's cache-hit hot path.
        """
        return False

    # Optional capabilities — backends that support them override.
    def checkpoint(self):
        raise BackendCapabilityError(
            f"backend does not support checkpointing: {type(self).__name__}"
        )

    def restore(self, ckpt) -> None:
        raise BackendCapabilityError(
            f"backend does not support restore: {type(self).__name__}"
        )


class Backend(ABC):
    """Factory for :class:`BackendProgram` instances.

    ``capabilities`` advertises optional features (``"checkpoint"``,
    ``"fault-injection"``, ``"mesh"``); :mod:`repro.api` consults it to fail
    fast instead of deep inside a run.
    """

    name: str = "abstract"
    capabilities: frozenset[str] = frozenset()

    @abstractmethod
    def compile(
        self,
        program: ExecProgram | WorkflowSystem,
        steps: Mapping[str, StepMeta],
        options: Mapping[str, Any],
    ) -> BackendProgram:
        """Compile a lowered program (a bare system is lowered on entry).

        Implementations call :meth:`lower` first so both an
        :class:`~repro.exec.program.ExecProgram` (the staged pipeline) and
        a :class:`WorkflowSystem` (legacy/third-party callers written
        against the PR-1 signature) are accepted.
        """
        ...

    @staticmethod
    def lower(
        program: ExecProgram | WorkflowSystem,
        options: Mapping[str, Any] | None = None,
    ) -> ExecProgram:
        """Coerce a ``compile`` source into the execution IR."""
        return ensure_program(
            program, schedule=(options or {}).get("schedule")
        )

    def validate_options(self, options: Mapping[str, Any]) -> None:
        """Reject unknown lowering options early (override to extend)."""
        unknown = set(options) - self.known_options()
        if unknown:
            raise TypeError(
                f"unknown options for backend {self.name!r}: "
                f"{sorted(unknown)}; supported: {sorted(self.known_options())}"
            )

    def known_options(self) -> frozenset[str]:
        # "schedule" is the uniform hand-down of the placement scheduler's
        # ScheduleReport (repro.sched): Plan.lower attaches it for every
        # backend; backends may consult it (the jax backend groups rack
        # members onto devices) or ignore it.  "trace" turns on the
        # repro.obs span recorder — every backend understands it and
        # attaches a RunProfile to its results.  "policy" is the uniform
        # :class:`repro.exec.policy.FaultPolicy` — every backend honors
        # retry/timeout/deadline through the shared interp helpers (each
        # adds the mechanisms its architecture affords on top).
        return frozenset({"schedule", "trace", "policy"})
