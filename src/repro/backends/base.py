"""The ``Backend`` protocol every execution target implements.

A backend turns an (optimised) :class:`~repro.core.syntax.WorkflowSystem`
plus a step registry into a :class:`BackendProgram` — the backend-specific
compiled artifact behind :class:`repro.api.Executable`.  Four backends ship
in-tree (see :mod:`repro.backends`):

======================  =====================================================
``inprocess``           reduction-driven :class:`repro.workflow.Runtime`
                        (checkpointable, retry/speculation fault tolerance)
``threaded``            decentralised per-location threads over the
                        in-memory transport
                        (:class:`repro.workflow.ThreadedRuntime`)
``multiprocess``        one OS process per location group over the ack-based
                        socket transport; checkpointable, typed
                        ``WorkerFailedError`` on worker death
``jax``                 per-location lowering onto a JAX host device mesh;
                        array payloads are staged with ``jax.device_put``
======================  =====================================================

Third-party backends register through
:func:`repro.backends.register_backend` or the ``repro.backends``
entry-point group declared in ``pyproject.toml``.
"""

from __future__ import annotations

from abc import ABC, abstractmethod
from dataclasses import dataclass, field
from typing import Any, Mapping

from repro.core.compile import StepMeta
from repro.core.syntax import WorkflowSystem

PayloadKey = tuple[str, str]  # (location, data element)


class BackendCapabilityError(NotImplementedError):
    """The selected backend does not support the requested operation."""


class UnknownBackendError(KeyError):
    """No backend registered under the requested name."""


@dataclass
class ExecutionResult:
    """What one :meth:`repro.api.Executable.run` produced.

    ``data`` maps location → data element → payload: the contents of every
    location's data scope after the system terminated, identical across
    backends for the same plan + steps (the bisimulation guarantee made
    observable).
    """

    backend: str
    data: dict[str, dict[str, Any]]
    stats: Any = None

    def payload(self, location: str, data: str) -> Any:
        return self.data[location][data]

    def location_data(self, location: str) -> dict[str, Any]:
        return dict(self.data[location])


@dataclass
class BackendProgram(ABC):
    """A compiled, runnable artifact for one backend."""

    system: WorkflowSystem
    steps: Mapping[str, StepMeta]
    options: dict[str, Any] = field(default_factory=dict)

    @abstractmethod
    def run(
        self, initial_payloads: Mapping[PayloadKey, Any] | None = None
    ) -> ExecutionResult:
        ...

    # Optional capabilities — backends that support them override.
    def checkpoint(self):
        raise BackendCapabilityError(
            f"backend does not support checkpointing: {type(self).__name__}"
        )

    def restore(self, ckpt) -> None:
        raise BackendCapabilityError(
            f"backend does not support restore: {type(self).__name__}"
        )


class Backend(ABC):
    """Factory for :class:`BackendProgram` instances.

    ``capabilities`` advertises optional features (``"checkpoint"``,
    ``"fault-injection"``, ``"mesh"``); :mod:`repro.api` consults it to fail
    fast instead of deep inside a run.
    """

    name: str = "abstract"
    capabilities: frozenset[str] = frozenset()

    @abstractmethod
    def compile(
        self,
        system: WorkflowSystem,
        steps: Mapping[str, StepMeta],
        options: Mapping[str, Any],
    ) -> BackendProgram:
        ...

    def validate_options(self, options: Mapping[str, Any]) -> None:
        """Reject unknown lowering options early (override to extend)."""
        unknown = set(options) - self.known_options()
        if unknown:
            raise TypeError(
                f"unknown options for backend {self.name!r}: "
                f"{sorted(unknown)}; supported: {sorted(self.known_options())}"
            )

    def known_options(self) -> frozenset[str]:
        # "schedule" is the uniform hand-down of the placement scheduler's
        # ScheduleReport (repro.sched): Plan.lower attaches it for every
        # backend; backends may consult it (the jax backend groups rack
        # members onto devices) or ignore it.
        return frozenset({"schedule"})
