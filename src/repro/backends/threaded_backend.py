"""``threaded`` backend — decentralised per-location threads over channels.

This is the execution model of the paper's generated TCP programs: every
location interprets only its own compiled bundle; there is no central
orchestrator.  Channel fault injection (drops / delays, seeded per endpoint)
threads through the ``Lowered`` options, which is how the fault-tolerance
experiments select their failure model.
"""

from __future__ import annotations

from typing import Any, Mapping

from repro._compat import suppress_deprecations
from repro.core.compile import StepMeta, build_bundles
from repro.core.syntax import WorkflowSystem

from .base import Backend, BackendProgram, ExecutionResult, PayloadKey


class ThreadedProgram(BackendProgram):
    def run(
        self, initial_payloads: Mapping[PayloadKey, Any] | None = None
    ) -> ExecutionResult:
        from repro.workflow.channels import ChannelRegistry
        from repro.workflow.threaded import ThreadedRuntime
        from repro.workflow.transport import InMemoryTransport, Transport

        opts = dict(self.options)
        opts.pop("schedule", None)  # placement already baked into the system
        transport = opts.pop("transport", None)
        registry = opts.pop("channels", None)
        channel_kwargs = {
            k: opts.pop(k)
            for k in ("drop_prob", "delay_s", "seed")
            if k in opts
        }
        if transport is not None:
            if not isinstance(transport, Transport):
                raise TypeError(
                    "transport= must be a repro.workflow.Transport instance "
                    f"(got {type(transport).__name__}); named transports "
                    "need per-run addresses — construct one explicitly"
                )
            if registry is not None or channel_kwargs:
                raise TypeError(
                    "pass either transport= or channel options "
                    "(channels=/drop_prob/delay_s/seed), not both"
                )
        else:
            if registry is None:
                registry = ChannelRegistry(**channel_kwargs)
            elif channel_kwargs:
                raise TypeError(
                    "pass either channels= or per-channel options "
                    f"({sorted(channel_kwargs)}), not both"
                )
            transport = InMemoryTransport(registry)
        step_fns = {name: meta.fn for name, meta in self.steps.items()}
        bundles = build_bundles(
            self.system, step_fns, step_meta=dict(self.steps)
        )
        with suppress_deprecations():
            rt = ThreadedRuntime(
                bundles,
                initial_payloads=initial_payloads,
                transport=transport,
                **opts,
            )
            data = rt.run()
        return ExecutionResult(
            backend="threaded",
            data={loc: dict(d) for loc, d in data.items()},
            stats=transport.stats(),
        )


class ThreadedBackend(Backend):
    name = "threaded"
    capabilities = frozenset({"decentralised", "fault-injection"})

    def known_options(self) -> frozenset[str]:
        return super().known_options() | frozenset(
            {
                "channels",
                "transport",
                "drop_prob",
                "delay_s",
                "seed",
                "timeout_s",
            }
        )

    def compile(
        self,
        system: WorkflowSystem,
        steps: Mapping[str, StepMeta],
        options: Mapping[str, Any],
    ) -> ThreadedProgram:
        return ThreadedProgram(
            system=system, steps=dict(steps), options=dict(options)
        )


def factory() -> Backend:
    return ThreadedBackend()
