"""``threaded`` backend — decentralised per-location threads over channels.

This is the execution model of the paper's generated TCP programs: every
location interprets only its own per-location program
(:class:`~repro.exec.program.LocationProgram` op arrays — no central
orchestrator, no trace trees).  Channel fault injection (drops / delays,
seeded per endpoint) threads through the ``Lowered`` options, which is how
the fault-tolerance experiments select their failure model.

``run_many`` shares **one** transport across the whole batch: each
instance's channel endpoints are namespaced by an instance tag, so many
workflow instances stream through the same wire concurrently while the
compiled program is reused untouched.  Tags carry a process-unique batch
prefix (``b3.17`` = instance 17 of batch 3), so *whole batches* may also
overlap: a compiled ``ThreadedProgram`` builds no mutable program-level
state per run — every run gets its own transport (unless the caller passed
a shared one) and its own runtimes — which is why it advertises
``concurrent_batches`` and one Executable can serve many concurrent
batches (the serving gateway's cache-hit hot path).
"""

from __future__ import annotations

import itertools
from typing import Any, Mapping, Sequence

from repro.core.compile import StepMeta
from repro.core.syntax import WorkflowSystem
from repro.exec.program import ExecProgram

from .base import (
    DEFAULT_MAX_CONCURRENT,
    Backend,
    BackendProgram,
    ExecutionResult,
    PayloadKey,
)


#: Process-unique batch sequence: prefixes every batch's instance tags so
#: concurrent run_many batches can never collide on channel endpoints,
#: even when the caller shares one transport across batches.
_BATCH_SEQ = itertools.count()


class ThreadedProgram(BackendProgram):
    def concurrent_batches(self) -> bool:
        # Runs are isolated by construction (fresh transport per run,
        # batch-unique endpoint tags) — except when the caller supplied a
        # shared transport/registry, where a concurrent *untagged* run()
        # could collide with another run's endpoints.
        return "transport" not in self.options and "channels" not in self.options

    def _make_transport(self, opts: dict[str, Any]):
        from repro.workflow.channels import ChannelRegistry
        from repro.workflow.transport import InMemoryTransport, Transport

        transport = opts.pop("transport", None)
        registry = opts.pop("channels", None)
        channel_kwargs = {
            k: opts.pop(k)
            for k in ("drop_prob", "delay_s", "seed")
            if k in opts
        }
        if transport is not None:
            if not isinstance(transport, Transport):
                raise TypeError(
                    "transport= must be a repro.workflow.Transport instance "
                    f"(got {type(transport).__name__}); named transports "
                    "need per-run addresses — construct one explicitly"
                )
            if registry is not None or channel_kwargs:
                raise TypeError(
                    "pass either transport= or channel options "
                    "(channels=/drop_prob/delay_s/seed), not both"
                )
        else:
            if registry is None:
                registry = ChannelRegistry(**channel_kwargs)
            elif channel_kwargs:
                raise TypeError(
                    "pass either channels= or per-channel options "
                    f"({sorted(channel_kwargs)}), not both"
                )
            transport = InMemoryTransport(registry)
        return transport

    def _local_steps(self) -> dict[str, dict[str, StepMeta]]:
        return {
            lp.location: {
                s: self.steps[s] for s in lp.exec_step_names()
            }
            for lp in self.program.programs
        }

    @staticmethod
    def _make_recorder(opts: dict[str, Any]):
        if not opts.pop("trace", False):
            return None
        from repro.obs.events import TraceRecorder

        return TraceRecorder()

    @staticmethod
    def _profile(recorder):
        if recorder is None:
            return None
        from repro.obs.profile import RunProfile

        # Lazy: detaches the raw buffers; spans materialise on first
        # access, not per instance on the run_many hot path.
        return RunProfile.from_recorder("threaded", recorder)

    def run(
        self, initial_payloads: Mapping[PayloadKey, Any] | None = None
    ) -> ExecutionResult:
        from repro.workflow.threaded import ThreadedProgramRuntime

        opts = dict(self.options)
        opts.pop("schedule", None)  # placement already baked into the IR
        timeout_s = float(opts.pop("timeout_s", 60.0))
        policy = opts.pop("policy", None)
        recorder = self._make_recorder(opts)
        transport = self._make_transport(opts)
        rt = ThreadedProgramRuntime(
            self.program.by_location,
            self._local_steps(),
            initial_payloads=initial_payloads,
            transport=transport,
            timeout_s=timeout_s,
            recorder=recorder,
            policy=policy,
        )
        data = rt.run()
        stats = transport.stats()
        if policy is not None:
            stats["policy"] = rt._guard.counts() if rt._guard else {}
            stats["recoveries"] = list(rt.recoveries)
        return ExecutionResult(
            backend="threaded",
            data={loc: dict(d) for loc, d in data.items()},
            stats=stats,
            profile=self._profile(recorder),
        )

    def run_many(
        self,
        inputs: Sequence[Mapping[PayloadKey, Any] | None],
        *,
        max_concurrent: int = DEFAULT_MAX_CONCURRENT,
    ) -> list[ExecutionResult]:
        """Pipelined batch execution over one shared transport.

        Instead of spawning fresh location threads per instance (the
        dominant cost at serving scale), the batch runs on a **persistent
        serving pool**: ``lanes × |locations|`` long-lived threads, each
        streaming its lane's instances through one location's op array,
        plus one shared branch pool for parallel trace branches.  Channel
        endpoints are namespaced per instance, so up to ``max_concurrent``
        instances are in flight on the same transport concurrently.
        """
        import threading
        from concurrent.futures import ThreadPoolExecutor

        from repro.workflow.threaded import (
            ThreadedProgramRuntime,
            total_par_branches,
        )

        inputs = list(inputs)
        if max_concurrent < 1:
            raise ValueError(
                f"max_concurrent must be >= 1, got {max_concurrent}"
            )
        if not inputs:
            return []
        opts = dict(self.options)
        opts.pop("schedule", None)
        timeout_s = float(opts.pop("timeout_s", 60.0))
        tracing = bool(opts.pop("trace", False))
        policy = opts.pop("policy", None)
        transport = self._make_transport(opts)
        batch_tag = f"b{next(_BATCH_SEQ)}"
        programs = self.program.by_location
        local_steps = self._local_steps()
        lanes = min(max_concurrent, len(inputs))
        n_branches = total_par_branches(programs)
        branch_pool = (
            ThreadPoolExecutor(
                max_workers=lanes * n_branches,
                thread_name_prefix="swirl-serve-branch",
            )
            if n_branches
            else None
        )
        # One pre-built runtime per instance: cheap (dict setup only —
        # programs, step registries and control specs are shared), and the
        # per-instance endpoint tag keeps the shared transport partitioned.
        recorders = [None] * len(inputs)
        if tracing:
            from repro.obs.events import TraceRecorder

            recorders = [TraceRecorder() for _ in inputs]
        runtimes = [
            ThreadedProgramRuntime(
                programs,
                local_steps,
                initial_payloads=payloads,
                transport=transport,
                timeout_s=timeout_s,
                instance_tag=f"{batch_tag}.{i}",
                branch_pool=branch_pool,
                validate=False,  # compile() already checked coverage
                recorder=recorders[i],
                policy=policy,
            )
            for i, payloads in enumerate(inputs)
        ]

        def lane_worker(lane: int, loc: str) -> None:
            for idx in range(lane, len(runtimes), lanes):
                runtimes[idx]._run_location(loc)

        threads = [
            threading.Thread(
                target=lane_worker,
                args=(lane, loc),
                name=f"swirl-serve-{lane}-{loc}",
                daemon=True,
            )
            for lane in range(lanes)
            for loc in sorted(programs)
        ]
        try:
            for th in threads:
                th.start()
            per_lane = -(-len(runtimes) // lanes)  # ceil
            deadline_join = timeout_s * per_lane
            for th in threads:
                th.join(deadline_join)
                if th.is_alive():
                    for rt in runtimes:
                        rt._raise_first_error()
                    raise TimeoutError(
                        "a serving lane did not finish its instances"
                    )
        finally:
            if branch_pool is not None:
                branch_pool.shutdown(wait=False, cancel_futures=True)
        # Transport stats are whole-batch aggregates (one shared wire);
        # each result gets its own copy, marked as such, so per-run
        # consumers can tell batch totals from single-run counts and a
        # mutation through one result never aliases the others.
        stats = transport.stats()
        results = []
        for rt, recorder in zip(runtimes, recorders):
            rt._raise_first_error()
            extra: dict[str, Any] = {}
            if policy is not None:
                extra = {
                    "policy": rt._guard.counts() if rt._guard else {},
                    "recoveries": list(rt.recoveries),
                }
            results.append(
                ExecutionResult(
                    backend="threaded",
                    data={loc: dict(d) for loc, d in rt.data.items()},
                    stats=dict(stats, batch_instances=len(runtimes), **extra),
                    profile=self._profile(recorder),
                )
            )
        return results


class ThreadedBackend(Backend):
    name = "threaded"
    capabilities = frozenset({"decentralised", "fault-injection", "serve"})

    def known_options(self) -> frozenset[str]:
        return super().known_options() | frozenset(
            {
                "channels",
                "transport",
                "drop_prob",
                "delay_s",
                "seed",
                "timeout_s",
            }
        )

    def compile(
        self,
        program: ExecProgram | WorkflowSystem,
        steps: Mapping[str, StepMeta],
        options: Mapping[str, Any],
    ) -> ThreadedProgram:
        return ThreadedProgram(
            program=self.lower(program, options),
            steps=dict(steps),
            options=dict(options),
        )


def factory() -> Backend:
    return ThreadedBackend()
