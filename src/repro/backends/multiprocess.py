"""``multiprocess`` backend — one OS process per SWIRL location (group).

This is the paper's deployment model made real inside one machine: every
location's lowered program (:class:`~repro.exec.program.LocationProgram` —
the self-contained, picklable op array shipped to the worker) runs in its
*own operating-system process* and
COMM messages cross a genuine transport boundary (the ``socket`` transport
of :mod:`repro.workflow.transport` — ``multiprocessing.connection`` sockets
with pickle framing, per-message acks, and resend on ack timeout).  There
is no shared memory between locations: everything a location learns, it
learns through its trace's recvs, exactly like the generated TCP bundles.

Topology
--------
A lightweight coordinator (the calling process) spawns one worker process
per *location group* and never touches payload routing — data flows
worker-to-worker.  Groups exist for two reasons:

* **spatial constraints** — a step with ``|M(s)| > 1`` synchronises through
  an in-process exec barrier, so its locations must share a process;
* **schedule pinning** — when a :class:`repro.sched.ScheduleReport` is
  handed down (``Plan.lower(..., placement="auto")``), locations in the
  same network group are pinned to the same worker process, mirroring the
  cost model's "cheap intra-rack links" assumption; an explicit
  ``workers=N`` option additionally packs groups onto ``N`` processes.

Fault surface
-------------
A worker that raises or dies (``SIGKILL`` included) is surfaced as a typed
:class:`WorkerFailedError` carrying the failed location and the step it was
executing; all sibling workers are torn down before the error propagates,
so no orphan processes remain.

Checkpointing
-------------
Workers stream per-step output deltas to the coordinator, which merges them
into a global payload store; :meth:`MultiprocessProgram.checkpoint` snapshots
that store as a standard :class:`repro.workflow.runtime.Checkpoint` (the
store is consistent mid-run because SWIRL payloads are immutable and the
completed-exec set only grows).  ``restore`` seeds the next run with the
snapshot: completed steps replay their recorded outputs instead of
re-executing, and the at-least-once transport makes the replayed sends
harmless.

Requirements: the default start method is ``fork`` (closures and lambdas
work as step functions); with ``start_method="spawn"`` every step function
and payload must be picklable.
"""

from __future__ import annotations

import multiprocessing as mp
import os
import shutil
import signal
import tempfile
import threading
import time
import warnings
from dataclasses import replace
from types import SimpleNamespace
from typing import Any, Mapping, Sequence

from repro.core.compile import StepMeta
from repro.core.parser import dumps
from repro.core.syntax import Exec, WorkflowSystem, actions
from repro.exec.program import ExecProgram, LocationProgram

from .base import Backend, BackendProgram, ExecutionResult, PayloadKey

DEFAULT_TIMEOUT_S = 120.0


class WorkerFailedError(RuntimeError):
    """A worker process crashed or raised while executing its locations.

    ``location`` names the failed location, ``step`` the step it was
    executing when it died (``None`` if it failed outside a step, e.g.
    while waiting on a recv).
    """

    def __init__(
        self,
        location: str | None,
        step: str | None = None,
        *,
        worker_id: int | None = None,
        exitcode: int | None = None,
        reason: str = "",
    ):
        self.location = location
        self.step = step
        self.worker_id = worker_id
        self.exitcode = exitcode
        self.reason = reason
        at = f" in step {step!r}" if step else ""
        why = reason or (
            f"killed (exit code {exitcode})"
            if exitcode is not None
            else "crashed"
        )
        super().__init__(
            f"worker for location {location!r} failed{at}: {why}"
        )


# ---------------------------------------------------------------------------
# Location → worker-process assignment
# ---------------------------------------------------------------------------


def assign_workers(
    system: ExecProgram | WorkflowSystem,
    *,
    workers: int | None = None,
    schedule: Any = None,
) -> list[tuple[str, ...]]:
    """Group locations into worker processes (deterministically).

    Locations sharing a spatially-constrained step (``|M(s)| > 1``) are
    always co-resident (the exec barrier is in-process).  When a
    ``ScheduleReport`` is given, locations in the same network group are
    pinned together.  ``workers=N`` then packs the groups onto ``N``
    processes, largest-first onto the least-loaded process.

    Accepts the lowered :class:`~repro.exec.program.ExecProgram` (the
    backend path, read straight off the op arrays) or a bare
    :class:`WorkflowSystem` (legacy callers).
    """
    if isinstance(system, ExecProgram):
        locs = sorted(system.locations())
        spatial = [
            tuple(sorted(ls))
            for ls in system.placement().values()
            if len(ls) > 1
        ]
    else:
        locs = sorted(system.locations())
        spatial = [
            tuple(sorted(a.locations))
            for cfg in system.configs
            for a in actions(cfg.trace)
            if isinstance(a, Exec) and len(a.locations) > 1
        ]
    parent = {l: l for l in locs}

    def find(x: str) -> str:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a: str, b: str) -> None:
        ra, rb = find(a), find(b)
        if ra != rb:
            # Deterministic root: keep the lexicographically smaller.
            lo, hi = sorted((ra, rb))
            parent[hi] = lo

    for group in spatial:
        first, *rest = group
        for other in rest:
            union(first, other)

    network = getattr(schedule, "network", None)
    if network is not None:
        by_group: dict[str, list[str]] = {}
        for l in locs:
            g = network.group_of(l)
            if g is not None:
                by_group.setdefault(g, []).append(l)
        for members in by_group.values():
            first, *rest = members
            for other in rest:
                union(first, other)

    units: dict[str, list[str]] = {}
    for l in locs:
        units.setdefault(find(l), []).append(l)
    groups = sorted(tuple(sorted(v)) for v in units.values())
    if workers is None or workers >= len(groups):
        return groups
    if workers < 1:
        raise ValueError(f"workers must be >= 1, got {workers}")
    bins: list[list[str]] = [[] for _ in range(workers)]
    sizes = [0] * workers
    for unit in sorted(groups, key=lambda u: (-len(u), u)):
        i = min(range(workers), key=lambda j: (sizes[j], j))
        bins[i].extend(unit)
        sizes[i] += len(unit)
    return sorted(tuple(sorted(b)) for b in bins if b)


def _recorded_outputs(program: ExecProgram, ckpt: Any) -> dict[str, dict]:
    """Per-step output payloads recoverable from a checkpoint's store."""
    recorded: dict[str, dict] = {}
    payloads: Mapping[PayloadKey, Any] = ckpt.payloads
    for lp in program.programs:
        for op in lp.exec_ops():
            if op.step in recorded:
                continue
            if op.step not in ckpt.completed_execs:
                continue
            out, missing = {}, False
            for d in op.outputs:
                for l in sorted(op.locations):
                    if (l, d) in payloads:
                        out[d] = payloads[(l, d)]
                        break
                else:
                    # The datum may only survive where a comm moved it.
                    hit = next(
                        (v for (l, dd), v in payloads.items() if dd == d),
                        _MISSING,
                    )
                    if hit is _MISSING:
                        missing = True
                        break
                    out[d] = hit
            if not missing:
                recorded[op.step] = out
    return recorded


_MISSING = object()


# ---------------------------------------------------------------------------
# Worker process
# ---------------------------------------------------------------------------


def _worker_main(cfg: dict) -> None:
    """Entry point of one worker: run my locations' bundles to completion.

    Control-plane protocol (worker → coordinator over the duplex pipe):
    ``("ready", wid, pid, monotonic)`` → *waits for* ``("go",)`` → then any
    number of ``("exec", wid, loc, step)`` / ``("delta", loc, step,
    outputs)`` / ``("spans", wid, events)`` / finally one of
    ``("done", wid, data)`` or ``("error", wid, loc, step, reason)``.

    The worker's ``time.monotonic()`` rides on the ready message so the
    coordinator can align span timestamps recorded on this process's
    clock (workers record absolute monotonic time via ``t_zero=0.0``);
    span batches are flushed incrementally — before each step body and
    before done/error — so a SIGKILLed worker's earlier spans survive up
    to the last coordinator merge.
    """
    ctl = cfg["ctl"]
    wid = cfg["worker_id"]
    transport = None
    ctl_lock = threading.Lock()

    def tell(msg: tuple) -> None:
        with ctl_lock:
            try:
                ctl.send(msg)
            except (OSError, BrokenPipeError, ValueError):
                pass  # coordinator is gone; nothing left to report to

    # Uniform fault policy: per-step retry + timeout run *inside* the
    # control-protocol wrapper, around the raw step body — a retried
    # transient failure must never reach the coordinator as an "error"
    # (which would tear the fleet down before the retry could succeed).
    # Each policy outcome is reported upstream so the coordinator can
    # count it; the messages double as progress heartbeats.
    policy = cfg.get("policy")
    guard = None
    if policy is not None and (
        policy.max_retries or policy.timeout_s is not None
    ):
        from repro.exec.interp import StepGuard

        guard = StepGuard(
            policy,
            on_retry=lambda step, n, e: tell(("retry", wid, step)),
            on_timeout=lambda step: tell(("step_timeout", wid, step)),
        )

    recorder = None
    if cfg.get("trace"):
        from repro.obs.events import TraceRecorder

        recorder = TraceRecorder(t_zero=0.0)

    def flush_spans() -> None:
        if recorder is not None and len(recorder):
            tell(("spans", wid, recorder.drain()))

    try:
        from repro.workflow.threaded import ThreadedProgramRuntime
        from repro.workflow.transport import HybridTransport, get_transport

        transport_cls = get_transport(cfg["transport"])
        transport = transport_cls(
            cfg["addresses"],
            serve=cfg["locations"],
            authkey=cfg["authkey"],
            ack_timeout=cfg["ack_timeout"],
            connect_timeout=cfg["timeout_s"],
        )
        if len(cfg["locations"]) > 1:
            # Co-resident locations (schedule pinning / workers= packing)
            # talk in memory instead of through socket loopback.
            transport = HybridTransport(transport, cfg["locations"])
        tell(("ready", wid, os.getpid(), time.monotonic()))
        if ctl.recv() != ("go",):  # coordinator aborted startup
            return

        programs: Mapping[str, LocationProgram] = cfg["programs"]
        metas: Mapping[str, StepMeta] = cfg["steps"]
        completed: frozenset[str] = cfg["completed"]
        recorded: Mapping[str, dict] = cfg["recorded"]
        kill_at = cfg.get("kill_at_step")
        current: dict[str, str] = {}

        def wrap(loc: str, step: str, fn):
            def run(inputs, _loc=loc, _step=step, _fn=fn):
                current[_loc] = _step
                flush_spans()  # ship earlier ops' spans before this step
                tell(("exec", wid, _loc, _step))
                if kill_at is not None and _step == kill_at:
                    os.kill(os.getpid(), signal.SIGKILL)  # fault injection
                if _step in completed and _step in recorded:
                    out = dict(recorded[_step])  # resume: replay, don't redo
                else:
                    try:
                        if guard is not None:
                            out = dict(
                                guard.fire(_step, lambda: _fn(inputs))
                            )
                        else:
                            out = dict(_fn(inputs))
                    except BaseException as e:  # noqa: BLE001
                        tell(
                            (
                                "error",
                                wid,
                                _loc,
                                _step,
                                f"{type(e).__name__}: {e}",
                            )
                        )
                        raise
                tell(("delta", _loc, _step, dict(out)))
                current.pop(_loc, None)
                return out

            return run

        local_steps = {
            loc: {
                s: replace(metas[s], fn=wrap(loc, s, metas[s].fn))
                for s in lp.exec_step_names()
            }
            for loc, lp in programs.items()
        }
        init = {
            (l, d): v
            for (l, d), v in cfg["initial"].items()
            if l in programs
        }
        rt = ThreadedProgramRuntime(
            programs,
            local_steps,
            initial_payloads=init,
            transport=transport,
            timeout_s=cfg["timeout_s"],
            recorder=recorder,
        )
        try:
            data = rt.run()
        except BaseException as e:  # noqa: BLE001
            loc, err = (rt.errors or [(cfg["locations"][0], e)])[0]
            flush_spans()
            tell(
                (
                    "error",
                    wid,
                    loc,
                    current.get(loc),
                    f"{type(err).__name__}: {err}",
                )
            )
            return
        flush_spans()
        tell(("done", wid, {l: dict(d) for l, d in data.items()}))
    except BaseException as e:  # noqa: BLE001
        loc = cfg["locations"][0] if cfg["locations"] else None
        tell(("error", wid, loc, None, f"{type(e).__name__}: {e}"))
    finally:
        if transport is not None:
            transport.close()


# ---------------------------------------------------------------------------
# Coordinator
# ---------------------------------------------------------------------------


class MultiprocessProgram(BackendProgram):
    # un-annotated → plain class attributes, not dataclass fields
    _store = None  # merged (location, datum) -> payload
    _completed = None  # set of completed step names
    _pending_ckpt = None
    #: ``(attempt, worker id) -> OS pid`` across every fleet the last run
    #: spawned (one entry per worker per recovery attempt; never mutated).
    last_pids = {}
    #: RunProfile of the last traced run — set even when the run raised
    #: (e.g. a SIGKILLed worker), holding every span merged before the
    #: failure.  ``None`` when the last run was untraced.
    last_profile = None

    def _run_instance(
        self,
        initial_payloads: Mapping[PayloadKey, Any] | None,
        instance_tag: str,
    ) -> ExecutionResult:
        # run() spawns a full worker-process fleet and mutates the shared
        # snapshot state (_pending_ckpt swap, _store/_completed) — batch
        # instances are serialised rather than racing a process fleet per
        # pool thread.  run_many still amortises lowering/compilation.
        lock = self.__dict__.setdefault("_instance_lock", threading.Lock())
        with lock:
            return self.run(initial_payloads)

    def run(
        self, initial_payloads: Mapping[PayloadKey, Any] | None = None
    ) -> ExecutionResult:
        from repro.workflow.transport import get_transport

        opts = dict(self.options)
        schedule = opts.pop("schedule", None)
        workers = opts.pop("workers", None)
        zero_copy = bool(opts.pop("zero_copy", False))
        transport_name = opts.pop("transport", None)
        if transport_name is None:
            transport_name = "shm" if zero_copy else "socket"
        elif zero_copy and transport_name != "shm":
            raise ValueError(
                f"zero_copy=True requires the shared-memory transport; "
                f"got transport={transport_name!r}"
            )
        start_method = opts.pop("start_method", None)
        timeout_s = float(opts.pop("timeout_s", DEFAULT_TIMEOUT_S))
        ack_timeout = float(opts.pop("ack_timeout", 1.0))
        kill_at = opts.pop("_kill_at_step", None)
        tracing = bool(opts.pop("trace", False))
        policy = opts.pop("policy", None)
        recover = str(opts.pop("recover", "off"))
        if recover not in ("off", "spare", "fold"):
            raise ValueError(
                f'recover must be "off", "spare" or "fold", got {recover!r}'
            )
        spares = list(opts.pop("spares", ()) or ())
        max_recoveries = int(opts.pop("max_recoveries", 8))
        recorder = None
        offsets: dict[int, float] = {}  # wid -> additive clock shift
        if tracing:
            from repro.obs.events import TraceRecorder

            recorder = TraceRecorder()
        self.last_profile = None

        transport_cls = get_transport(transport_name)
        if not getattr(transport_cls, "crosses_processes", False):
            raise ValueError(
                f"transport {transport_name!r} cannot cross process "
                "boundaries; the multiprocess backend needs one that can "
                '(e.g. "socket")'
            )
        if start_method is None:
            start_method = (
                "fork"
                if "fork" in mp.get_all_start_methods()
                else "spawn"
            )

        completed: set[str] = set()
        recorded: dict[str, dict] = {}
        store: dict[PayloadKey, Any] = {}
        if self._pending_ckpt is not None:
            ckpt, self._pending_ckpt = self._pending_ckpt, None
            store.update(ckpt.payloads)
            completed |= set(ckpt.completed_execs)
            recorded = _recorded_outputs(self.program, ckpt)
        if initial_payloads:
            store.update(initial_payloads)
        self._store, self._completed = store, completed

        from repro.exec.interp import Deadline

        ctx = mp.get_context(start_method)
        program = self.program
        recoveries: list[dict] = []
        all_pids: dict[tuple[int, int], int] = {}
        fatal: tuple | None = None
        attempt = 0
        deadline = Deadline(
            policy.deadline_s if policy is not None else None
        )
        policy_counts = {"retries": 0, "timeouts": 0, "heartbeat_deaths": 0}
        while True:
            groups = assign_workers(
                program,
                workers=workers,
                # A stale schedule speaks pre-rename location names; its
                # network pinning only applies to the fleet it planned.
                schedule=schedule if attempt == 0 else None,
            )
            hb_before = policy_counts["heartbeat_deaths"]
            rem = deadline.remaining()
            failure, finals, pids = self._attempt(
                program,
                store,
                completed,
                recorded,
                groups=groups,
                ctx=ctx,
                transport_name=transport_name,
                timeout_s=(
                    timeout_s if rem is None
                    else max(min(timeout_s, rem), 0.01)
                ),
                ack_timeout=ack_timeout,
                kill_at=kill_at,
                tracing=tracing,
                recorder=recorder,
                offsets=offsets,
                policy=policy,
                policy_counts=policy_counts,
            )
            for wid, pid in pids.items():
                all_pids[(attempt, wid)] = pid
            self.last_pids = dict(all_pids)
            if failure is None:
                break
            if failure[0] == "timeout" and deadline.expired():
                deadline.check()  # the run deadline, not the step timeout
            # Only process *death* is recoverable — a deterministic step
            # exception ("error") would just re-raise on the replacement,
            # and a timeout already tore the whole fleet down.
            if (
                failure[0] != "crash"
                or recover == "off"
                or len(recoveries) >= max_recoveries
            ):
                fatal = failure
                break
            t0 = time.monotonic()
            wid = failure[1]
            dead = sorted(groups[wid])
            live = [
                l for l in program.locations() if l not in set(dead)
            ]
            from repro.exec.elastic import rename_program, resimulate
            from repro.workflow.elastic import fold_payloads, plan_recovery

            try:
                ren = plan_recovery(
                    live, dead, spares if recover == "spare" else []
                )
            except RuntimeError:
                fatal = failure  # nothing to recover onto
                break
            spares = [s for s in spares if s not in set(ren.values())]
            program = rename_program(program, ren)
            store = fold_payloads(store, ren)
            # The resume point: everything the coordinator merged before
            # the crash, folded under the substitution.  Completed steps
            # replay these recorded outputs — their bodies never re-run.
            resume = SimpleNamespace(
                payloads=store, completed_execs=frozenset(completed)
            )
            recorded = _recorded_outputs(program, resume)
            self._store = store
            kill_at = None  # the injected fault fires once
            event = {
                "attempt": len(recoveries) + 1,
                "mode": recover,
                "worker_id": wid,
                "failed_step": failure[3],
                "dead": list(dead),
                "renaming": dict(ren),
                "completed_steps": len(completed),
            }
            if policy_counts["heartbeat_deaths"] > hb_before:
                # The worker was not SIGKILLed from outside — the policy's
                # progress heartbeat declared the straggler dead.
                event["declared_by"] = "heartbeat"
            if schedule is not None:
                try:
                    event["predicted_makespan_s"] = resimulate(
                        program
                    ).makespan
                except Exception:  # noqa: BLE001 - prediction is best-effort
                    pass
            recoveries.append(event)
            if recorder is not None:
                t1 = time.monotonic()
                for d in dead:
                    recorder.span(
                        "phase",
                        ren[d],
                        f"recover:{recover}",
                        t0,
                        t1,
                        src=d,
                        dst=ren[d],
                    )
            attempt += 1

        profile = None
        if recorder is not None:
            from repro.obs.profile import RunProfile

            profile = RunProfile.from_recorder("multiprocess", recorder)
            # Survives even a failed run: everything merged before the
            # worker died is inspectable post-mortem.
            self.last_profile = profile

        if fatal is not None:
            if fatal[0] == "timeout":
                raise TimeoutError(
                    f"multiprocess run exceeded {timeout_s}s; "
                    "workers terminated"
                )
            kind, wid, loc, step, info = fatal
            raise WorkerFailedError(
                loc,
                step,
                worker_id=wid,
                exitcode=info if kind == "crash" else None,
                reason=info if kind == "error" else "",
            )

        data: dict[str, dict[str, Any]] = {
            loc: {} for loc in program.locations()
        }
        for wid in sorted(finals):
            for loc, local in finals[wid].items():
                data[loc].update(local)
                for d, v in local.items():
                    store[(loc, d)] = v
        stats = {
            "workers": len(groups),
            "groups": {i: list(g) for i, g in enumerate(groups)},
            "pids": dict(pids),
            "transport": transport_name,
            "start_method": start_method,
            "recoveries": recoveries,
        }
        if policy is not None:
            stats["policy"] = dict(policy_counts)
        return ExecutionResult(
            backend="multiprocess",
            data=data,
            stats=stats,
            profile=profile,
        )

    def _attempt(
        self,
        program: ExecProgram,
        store: dict[PayloadKey, Any],
        completed: set[str],
        recorded: Mapping[str, dict],
        *,
        groups: list[tuple[str, ...]],
        ctx,
        transport_name: str,
        timeout_s: float,
        ack_timeout: float,
        kill_at: str | None,
        tracing: bool,
        recorder,
        offsets: dict[int, float],
        policy=None,
        policy_counts: dict[str, int] | None = None,
    ) -> tuple[tuple | None, dict, dict[int, int]]:
        """Spawn one worker fleet for ``program`` and drive it to done/fail.

        Each attempt binds a *fresh* set of transport endpoints (its own
        socket directory + authkey) — after a recovery renaming this is
        what rebinds the renamed locations' channels; ``HybridTransport``
        pinning for co-resident groups happens inside the workers.
        Mutates ``store``/``completed`` in place as deltas arrive (the
        coordinator-merged checkpoint the recovery path resumes from) and
        returns ``(failure, finals, pids)`` with every worker torn down.
        """
        from multiprocessing import connection as mpc

        from repro.workflow.transport import get_transport, socket_addresses

        tmpdir = tempfile.mkdtemp(prefix="swirl-mp-")
        addresses = socket_addresses(program.locations(), base_dir=tmpdir)
        authkey = os.urandom(16)

        procs: list = []
        parent_conns: list = []
        pids: dict[int, int] = {}
        last_exec: dict[int, tuple[str, str]] = {}
        finals: dict[int, dict[str, dict[str, Any]]] = {}
        failure: tuple | None = None
        counts = policy_counts if policy_counts is not None else {}
        #: Progress heartbeat: every control message from a worker is a
        #: beat.  A worker *inside a step* (an un-matched "exec") that
        #: stays silent past the policy's heartbeat deadline is a
        #: straggler — declared dead below, which maps it onto the same
        #: ("crash", ...) path a SIGKILL takes, so elastic recovery fires
        #: without waiting for the process to actually die.
        hb_timeout = (
            policy.heartbeat_timeout_s if policy is not None else None
        )
        last_progress: dict[int, float] = {}

        def handle(msg: tuple, wid: int) -> tuple | None:
            """Apply one worker message; return a failure record or None."""
            nonlocal started
            last_progress[wid] = time.monotonic()
            kind = msg[0]
            if kind == "retry":
                counts["retries"] = counts.get("retries", 0) + 1
                if recorder is not None:
                    t = time.monotonic()
                    recorder.add(
                        ("policy", groups[wid][0], f"retry:{msg[2]}",
                         t, t, None, None, None, None)
                    )
                return None
            if kind == "step_timeout":
                counts["timeouts"] = counts.get("timeouts", 0) + 1
                return None
            if kind == "ready":
                ready.add(wid)
                pids[wid] = msg[2]
                if recorder is not None and len(msg) > 3:
                    # Clock alignment piggybacked on the handshake: the
                    # worker's monotonic instant maps to "now" here, so a
                    # worker-absolute span time t lands on this recorder's
                    # clock at t + offset.
                    offsets[wid] = (
                        time.monotonic() - msg[3] - recorder.t_zero
                    )
                if not started and len(ready) == len(procs):
                    started = True
                    for c in list(live_conns):
                        try:
                            c.send(("go",))
                        except (OSError, BrokenPipeError):
                            pass
            elif kind == "exec":
                last_exec[wid] = (msg[2], msg[3])
            elif kind == "delta":
                _, loc, step, out = msg
                for d, v in out.items():
                    store[(loc, d)] = v
                completed.add(step)
                if last_exec.get(wid) == (loc, step):
                    # The step finished — a later crash while e.g. blocked
                    # on a recv must not be pinned on it (step=None then).
                    del last_exec[wid]
            elif kind == "spans":
                if recorder is not None:
                    recorder.absorb(msg[2], offset=offsets.get(wid, 0.0))
            elif kind == "done":
                finals[wid] = msg[2]
                pending.discard(wid)
            elif kind == "error":
                return ("error", wid, msg[2], msg[3], msg[4])
            return None

        def drain(conn, wid: int) -> tuple | None:
            """Consume every buffered message on one control pipe."""
            first_failure = None
            while True:
                try:
                    if not conn.poll(0):
                        break
                    msg = conn.recv()
                except (EOFError, OSError):
                    live_conns.pop(conn, None)
                    break
                err = handle(msg, wid)
                if err is not None and first_failure is None:
                    first_failure = err
            return first_failure

        try:
            for wid, group in enumerate(groups):
                parent, child = ctx.Pipe()
                cfg = dict(
                    worker_id=wid,
                    locations=group,
                    programs={loc: program[loc] for loc in group},
                    steps=dict(self.steps),
                    addresses=addresses,
                    authkey=authkey,
                    transport=transport_name,
                    ctl=child,
                    initial={
                        k: v for k, v in store.items() if k[0] in group
                    },
                    completed=frozenset(completed),
                    recorded=recorded,
                    timeout_s=timeout_s,
                    ack_timeout=ack_timeout,
                    kill_at_step=kill_at,
                    trace=tracing,
                    policy=policy,
                )
                proc = ctx.Process(
                    target=_worker_main,
                    args=(cfg,),
                    name=f"swirl-worker-{wid}",
                    daemon=True,
                )
                with warnings.catch_warnings():
                    # Forking a process that imported a multithreaded
                    # library (jax) warns; workers only run pure Python.
                    warnings.simplefilter("ignore")
                    proc.start()
                child.close()
                procs.append(proc)
                parent_conns.append(parent)

            ready: set[int] = set()
            started = False
            pending = set(range(len(procs)))
            live_conns = {parent_conns[i]: i for i in range(len(procs))}
            sentinels = {procs[i].sentinel: i for i in range(len(procs))}
            deadline = time.monotonic() + timeout_s

            while pending and failure is None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    failure = ("timeout",)
                    break
                wait_timeout = remaining
                if hb_timeout is not None:
                    # Wake often enough to notice a silent straggler well
                    # within one heartbeat window.
                    wait_timeout = min(remaining, max(hb_timeout / 4, 0.05))
                objs = list(live_conns) + [
                    procs[i].sentinel for i in pending
                ]
                for obj in mpc.wait(objs, timeout=wait_timeout):
                    if obj in live_conns:
                        wid = live_conns[obj]
                        try:
                            msg = obj.recv()
                        except (EOFError, OSError):
                            del live_conns[obj]
                            continue
                        failure = handle(msg, wid) or failure
                        if failure is not None:
                            break
                    else:
                        wid = sentinels.get(obj)
                        if wid is None or wid not in pending:
                            continue
                        # Harvest everything already in flight (deltas,
                        # done/error reports) before declaring a crash.
                        for conn in list(live_conns):
                            failure = (
                                failure or drain(conn, live_conns[conn])
                            )
                        if wid in pending and failure is None:
                            loc, step = last_exec.get(
                                wid, (groups[wid][0], None)
                            )
                            # The sentinel fires when the child exits, but
                            # the exit *code* is only available once the
                            # child is reaped — join first or a killed
                            # worker races to exitcode=None.
                            procs[wid].join(5)
                            failure = (
                                "crash",
                                wid,
                                loc,
                                step,
                                procs[wid].exitcode,
                            )
                        break
                if failure is None and hb_timeout is not None and started:
                    now = time.monotonic()
                    for wid in sorted(pending):
                        if wid not in last_exec:
                            # Blocked on a recv/barrier — waiting on a peer
                            # is not straggling; only a worker silent *inside
                            # a step* can be declared.
                            continue
                        if now - last_progress.get(wid, now) <= hb_timeout:
                            continue
                        loc, step = last_exec[wid]
                        counts["heartbeat_deaths"] = (
                            counts.get("heartbeat_deaths", 0) + 1
                        )
                        if recorder is not None:
                            recorder.add(
                                ("policy", loc,
                                 f"heartbeat_death:{step or '-'}",
                                 now, now, None, None, None, None)
                            )
                        # Declare the straggler dead: terminate it and
                        # surface the same ("crash", ...) record a real
                        # process death produces — the elastic recovery
                        # path (spare/fold) takes over from there.
                        procs[wid].terminate()
                        procs[wid].join(5)
                        if procs[wid].is_alive():
                            procs[wid].kill()
                            procs[wid].join(5)
                        failure = (
                            "crash", wid, loc, step, procs[wid].exitcode
                        )
                        break
        finally:
            for proc in procs:
                if proc.is_alive():
                    proc.terminate()
            for proc in procs:
                proc.join(5)
                if proc.is_alive():
                    proc.kill()
                    proc.join(5)
            for conn in parent_conns:
                try:
                    conn.close()
                except OSError:
                    pass
            shutil.rmtree(tmpdir, ignore_errors=True)
            # A worker killed mid-send cannot reclaim its shared-memory
            # segments; the coordinator sweeps the attempt's namespace
            # (derived from this attempt's authkey) so a crashed fleet
            # never leaks /dev/shm entries.
            sweep = getattr(get_transport(transport_name), "sweep", None)
            if sweep is not None:
                sweep(authkey)
        return failure, finals, pids

    # -- checkpoint capability ----------------------------------------------

    def checkpoint(self):
        """Snapshot the coordinator's merged store (consistent mid-run)."""
        from repro.workflow.runtime import Checkpoint

        return Checkpoint(
            system_text=dumps(self.system),
            payloads=dict(self._store or {}),
            completed_execs=frozenset(self._completed or ()),
        )

    def restore(self, ckpt) -> None:
        self._pending_ckpt = ckpt


class MultiprocessBackend(Backend):
    name = "multiprocess"
    capabilities = frozenset(
        {"checkpoint", "distributed", "fault-injection", "elastic-recovery"}
    )

    def known_options(self) -> frozenset[str]:
        return super().known_options() | frozenset(
            {
                "workers",
                "transport",
                "zero_copy",
                "start_method",
                "timeout_s",
                "ack_timeout",
                "_kill_at_step",
                "recover",
                "spares",
                "max_recoveries",
            }
        )

    def compile(
        self,
        program: ExecProgram | WorkflowSystem,
        steps: Mapping[str, StepMeta],
        options: Mapping[str, Any],
    ) -> MultiprocessProgram:
        return MultiprocessProgram(
            program=self.lower(program, options),
            steps=dict(steps),
            options=dict(options),
        )


def factory() -> Backend:
    return MultiprocessBackend()
