"""``inprocess`` backend — the centralised, checkpointable dataflow runtime.

Interprets the per-location program IR (:mod:`repro.exec`) with the
semantics' enabling rules — matching SEND/RECV pairs fire as (COMM) copies,
EXEC ops fire synchronised across ``M(s)`` — with real effects on a thread
pool.  This is the backend with the richest fault-tolerance story (retry,
straggler speculation, heartbeats, consistent snapshots), so it also
implements the optional ``checkpoint``/``restore`` capability; snapshots
are still reachable SWIRL terms (the remaining term is rebuilt from the
program's completion flags), interchangeable with every other
checkpointing backend.
"""

from __future__ import annotations

from typing import Any, Mapping

from repro.core.compile import StepMeta
from repro.core.parser import dumps
from repro.core.syntax import WorkflowSystem
from repro.exec.program import ExecProgram, lower_system

from .base import Backend, BackendProgram, ExecutionResult, PayloadKey


class InprocessProgram(BackendProgram):
    # un-annotated → plain class attributes, not dataclass fields
    _runtime = None
    _pending_ckpt = None

    def _build_runtime(
        self,
        initial_payloads: Mapping[PayloadKey, Any] | None,
        *,
        program: ExecProgram | None = None,
        completed: frozenset[str] = frozenset(),
    ):
        from repro.exec.central import ProgramRuntime

        expected = {
            name: meta.expected_seconds
            for name, meta in self.steps.items()
            if meta.expected_seconds is not None
        }
        kwargs = dict(self.options)
        kwargs.pop("schedule", None)  # placement already baked into the IR
        kwargs.setdefault("expected_s", expected or None)
        if kwargs.pop("trace", False):
            from repro.obs.events import TraceRecorder

            kwargs["recorder"] = TraceRecorder()
        return ProgramRuntime(
            program or self.program,
            dict(self.steps),
            initial_payloads=initial_payloads,
            completed=completed,
            **kwargs,
        )

    def _profile(self, rt, stats):
        if rt.recorder is None:
            return None
        from repro.obs.profile import RunProfile

        # Lazy: spans materialise on first access, not per run.
        return RunProfile.from_recorder(
            "inprocess", rt.recorder,
            wall_s=getattr(stats, "wall_s", 0.0) or None,
        )

    def run(
        self, initial_payloads: Mapping[PayloadKey, Any] | None = None
    ) -> ExecutionResult:
        if self._pending_ckpt is not None:
            ckpt, self._pending_ckpt = self._pending_ckpt, None
            # Resume from the snapshot's remaining term: re-lower it and
            # replay completed steps' recorded outputs instead of redoing.
            payloads = dict(ckpt.payloads)
            payloads.update(initial_payloads or {})
            rt = self._build_runtime(
                payloads,
                program=lower_system(ckpt.system),
                completed=frozenset(ckpt.completed_execs),
            )
        else:
            rt = self._build_runtime(initial_payloads)
        self._runtime = rt
        stats = rt.run()
        return ExecutionResult(
            backend="inprocess", data=self._collect(rt), stats=stats,
            profile=self._profile(rt, stats),
        )

    def _run_instance(
        self,
        initial_payloads: Mapping[PayloadKey, Any] | None,
        instance_tag: str,
    ) -> ExecutionResult:
        # run_many instances each get a pristine runtime; the shared
        # snapshot state (_runtime/_pending_ckpt) is left untouched.
        rt = self._build_runtime(initial_payloads)
        stats = rt.run()
        return ExecutionResult(
            backend="inprocess", data=self._collect(rt), stats=stats,
            profile=self._profile(rt, stats),
        )

    def _collect(self, rt) -> dict[str, dict[str, Any]]:
        data: dict[str, dict[str, Any]] = {
            loc: {} for loc in self.system.locations()
        }
        for (loc, d), v in rt.payloads.items():
            data.setdefault(loc, {})[d] = v
        return data

    def checkpoint(self):
        from repro.workflow.runtime import Checkpoint

        if self._runtime is not None:
            return self._runtime.checkpoint()
        # Pristine snapshot: nothing has run yet.
        return Checkpoint(
            system_text=dumps(self.system),
            payloads={},
            completed_execs=frozenset(),
        )

    def restore(self, ckpt) -> None:
        self._pending_ckpt = ckpt


class InprocessBackend(Backend):
    name = "inprocess"
    capabilities = frozenset({"checkpoint", "retry", "speculation"})

    def known_options(self) -> frozenset[str]:
        return super().known_options() | frozenset(
            {
                "retry",
                "speculation",
                "expected_s",
                "max_workers",
                "checkpoint_every",
                "checkpoint_path",
                "heartbeat",
            }
        )

    def compile(
        self,
        program: ExecProgram | WorkflowSystem,
        steps: Mapping[str, StepMeta],
        options: Mapping[str, Any],
    ) -> InprocessProgram:
        return InprocessProgram(
            program=self.lower(program, options),
            steps=dict(steps),
            options=dict(options),
        )


def factory() -> Backend:
    return InprocessBackend()
