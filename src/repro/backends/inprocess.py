"""``inprocess`` backend — the reduction-driven, checkpointable Runtime.

Execution *is* SWIRL reduction: the program repeatedly applies the paper's
(EXEC)/(COMM) rules with real effects on a thread pool.  This is the backend
with the richest fault-tolerance story (retry, straggler speculation,
heartbeats, consistent snapshots), so it also implements the optional
``checkpoint``/``restore`` capability.
"""

from __future__ import annotations

from typing import Any, Mapping

from repro._compat import suppress_deprecations
from repro.core.compile import StepMeta
from repro.core.parser import dumps
from repro.core.syntax import WorkflowSystem

from .base import Backend, BackendProgram, ExecutionResult, PayloadKey


class InprocessProgram(BackendProgram):
    # un-annotated → plain class attributes, not dataclass fields
    _runtime = None
    _pending_ckpt = None

    def run(
        self, initial_payloads: Mapping[PayloadKey, Any] | None = None
    ) -> ExecutionResult:
        from repro.workflow.runtime import Runtime

        step_fns = {name: meta.fn for name, meta in self.steps.items()}
        expected = {
            name: meta.expected_seconds
            for name, meta in self.steps.items()
            if meta.expected_seconds is not None
        }
        kwargs = dict(self.options)
        kwargs.pop("schedule", None)  # placement already baked into the system
        kwargs.setdefault("expected_s", expected or None)
        with suppress_deprecations():
            if self._pending_ckpt is not None:
                rt = Runtime.restore(self._pending_ckpt, step_fns, **kwargs)
                if initial_payloads:
                    rt.payloads.update(initial_payloads)
                self._pending_ckpt = None
            else:
                rt = Runtime(
                    self.system,
                    step_fns,
                    initial_payloads=initial_payloads,
                    **kwargs,
                )
            self._runtime = rt
            stats = rt.run()
        data: dict[str, dict[str, Any]] = {
            loc: {} for loc in self.system.locations()
        }
        for (loc, d), v in rt.payloads.items():
            data.setdefault(loc, {})[d] = v
        return ExecutionResult(backend="inprocess", data=data, stats=stats)

    def checkpoint(self):
        from repro.workflow.runtime import Checkpoint

        if self._runtime is not None:
            return self._runtime.checkpoint()
        # Pristine snapshot: nothing has run yet.
        return Checkpoint(
            system_text=dumps(self.system),
            payloads={},
            completed_execs=frozenset(),
        )

    def restore(self, ckpt) -> None:
        self._pending_ckpt = ckpt


class InprocessBackend(Backend):
    name = "inprocess"
    capabilities = frozenset({"checkpoint", "retry", "speculation"})

    def known_options(self) -> frozenset[str]:
        return super().known_options() | frozenset(
            {
                "retry",
                "speculation",
                "expected_s",
                "max_workers",
                "checkpoint_every",
                "checkpoint_path",
                "heartbeat",
            }
        )

    def compile(
        self,
        system: WorkflowSystem,
        steps: Mapping[str, StepMeta],
        options: Mapping[str, Any],
    ) -> InprocessProgram:
        return InprocessProgram(
            system=system, steps=dict(steps), options=dict(options)
        )


def factory() -> Backend:
    return InprocessBackend()
