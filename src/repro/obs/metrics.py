"""A minimal Prometheus-text-format metrics registry (stdlib only).

Just enough of the exposition format (version 0.0.4) for the gateway's
``GET /v1/metrics``: counters, gauges, and cumulative histograms with
label sets, rendered as ``# HELP`` / ``# TYPE`` blocks.  Counters and
gauges support both incremental updates (request counting in the hot
path) and absolute ``set`` (snapshot-sourced values copied out of
``WorkflowService.stats()`` at scrape time).
"""

from __future__ import annotations

import threading
from typing import Mapping, Sequence

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]

#: Default latency buckets (seconds) — tuned for an in-process HTTP
#: gateway where cache-hit runs are sub-millisecond and compiles can
#: take whole seconds.
DEFAULT_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


def _fmt_value(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labels_str(labels: Mapping[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="{_escape_label(str(v))}"' for k, v in sorted(labels.items())
    )
    return "{" + inner + "}"


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help_text: str):
        self.name = name
        self.help = help_text
        self._lock = threading.Lock()

    def render(self) -> list[str]:  # pragma: no cover - abstract
        raise NotImplementedError


class Counter(_Metric):
    kind = "counter"

    def __init__(self, name: str, help_text: str):
        super().__init__(name, help_text)
        self._values: dict[tuple, float] = {}

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        key = tuple(sorted(labels.items()))
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def set(self, value: float, **labels: str) -> None:
        """Absolute update — for snapshot-sourced cumulative totals."""
        key = tuple(sorted(labels.items()))
        with self._lock:
            self._values[key] = float(value)

    def render(self) -> list[str]:
        lines = [
            f"# HELP {self.name} {self.help}",
            f"# TYPE {self.name} {self.kind}",
        ]
        with self._lock:
            items = sorted(self._values.items())
        if not items:
            lines.append(f"{self.name} 0")
        for key, value in items:
            lines.append(
                f"{self.name}{_labels_str(dict(key))} {_fmt_value(value)}"
            )
        return lines


class Gauge(Counter):
    kind = "gauge"

    def dec(self, amount: float = 1.0, **labels: str) -> None:
        self.inc(-amount, **labels)


class Histogram(_Metric):
    kind = "histogram"

    def __init__(
        self,
        name: str,
        help_text: str,
        buckets: Sequence[float] = DEFAULT_BUCKETS,
    ):
        super().__init__(name, help_text)
        self.buckets = tuple(sorted(buckets))
        self._counts: dict[tuple, list[int]] = {}
        self._sums: dict[tuple, float] = {}
        self._totals: dict[tuple, int] = {}

    def observe(self, value: float, **labels: str) -> None:
        key = tuple(sorted(labels.items()))
        with self._lock:
            counts = self._counts.get(key)
            if counts is None:
                counts = self._counts[key] = [0] * len(self.buckets)
                self._sums[key] = 0.0
                self._totals[key] = 0
            for i, bound in enumerate(self.buckets):
                if value <= bound:
                    counts[i] += 1
            self._sums[key] += value
            self._totals[key] += 1

    def render(self) -> list[str]:
        lines = [
            f"# HELP {self.name} {self.help}",
            f"# TYPE {self.name} {self.kind}",
        ]
        with self._lock:
            keys = sorted(self._counts)
            snap = {
                k: (list(self._counts[k]), self._sums[k], self._totals[k])
                for k in keys
            }
        for key in keys:
            counts, total_sum, total = snap[key]
            base = dict(key)
            for bound, count in zip(self.buckets, counts):
                lines.append(
                    f"{self.name}_bucket"
                    f"{_labels_str({**base, 'le': _fmt_value(bound)})} "
                    f"{count}"
                )
            lines.append(
                f"{self.name}_bucket{_labels_str({**base, 'le': '+Inf'})} "
                f"{total}"
            )
            lines.append(
                f"{self.name}_sum{_labels_str(base)} {_fmt_value(total_sum)}"
            )
            lines.append(f"{self.name}_count{_labels_str(base)} {total}")
        return lines


class MetricsRegistry:
    """Ordered collection of metrics, rendered as one exposition page."""

    CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}

    def counter(self, name: str, help_text: str = "") -> Counter:
        return self._get_or_make(Counter, name, help_text)

    def gauge(self, name: str, help_text: str = "") -> Gauge:
        return self._get_or_make(Gauge, name, help_text)

    def histogram(
        self,
        name: str,
        help_text: str = "",
        buckets: Sequence[float] = DEFAULT_BUCKETS,
    ) -> Histogram:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = Histogram(name, help_text, buckets)
            elif not isinstance(m, Histogram):
                raise TypeError(f"{name} already registered as {m.kind}")
            return m

    def _get_or_make(self, cls, name: str, help_text: str):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help_text)
            elif type(m) is not cls:
                raise TypeError(f"{name} already registered as {m.kind}")
            return m

    def render(self) -> str:
        with self._lock:
            metrics = list(self._metrics.values())
        lines: list[str] = []
        for m in metrics:
            lines.extend(m.render())
        return "\n".join(lines) + "\n"
