"""``repro.obs`` — execution tracing, run profiles, and service metrics.

The observability layer promised by the paper's premise that a workflow's
distributed execution trace is a first-class object:

* :class:`TraceRecorder` / :class:`SpanEvent` — low-overhead span capture
  shared by all four backends (``lower(..., trace=True)``);
* :class:`RunProfile` — the structured artifact on every traced result
  (``result.profile``), exportable as Perfetto-loadable Chrome trace JSON;
* :func:`align` / :class:`ProfileReport` — predicted-vs-actual drift
  against the sched simulator (``Plan.profile(result)``);
* :class:`MetricsRegistry` — the Prometheus text registry behind the
  gateway's ``GET /v1/metrics``.
"""

from repro.obs.events import (
    SpanEvent,
    TraceRecorder,
    current_trace_id,
    payload_nbytes,
)
from repro.obs.export import (
    chrome_trace,
    validate_chrome_trace,
    write_chrome_trace,
)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.profile import ProfileReport, RunProfile, StepDrift, align

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ProfileReport",
    "RunProfile",
    "SpanEvent",
    "StepDrift",
    "TraceRecorder",
    "align",
    "chrome_trace",
    "current_trace_id",
    "payload_nbytes",
    "validate_chrome_trace",
    "write_chrome_trace",
]
