"""Typed span events and the low-overhead :class:`TraceRecorder`.

The recorder is the single primitive every backend shares: one append-only
buffer of raw span rows stamped from one monotonic clock, materialised
into :class:`SpanEvent`\\ s off the hot path.  The design constraints (in
priority order):

1. **Zero cost when disabled.**  Every hot-path call site guards on
   ``recorder is None`` (or an ``enabled=False`` recorder short-circuits
   before touching the clock), so an untraced run performs no clock
   reads and no allocations on behalf of tracing.
2. **Identical span schemas across backends.**  The recording helpers in
   :mod:`repro.exec.interp` are the only places that decide *what* a
   span for an ``ExecOp``/``SendOp``/``RecvOp`` looks like; the four
   backends merely decide *when* to call them.  Differential tests
   compare :meth:`SpanEvent.identity` multisets across backends.
3. **Mergeable across processes.**  Multiprocess workers record against
   ``t_zero=0.0`` (absolute worker-monotonic timestamps), ship drained
   batches over the control pipe, and the coordinator :meth:`absorb`\\ s
   them with the clock offset measured on the ready/go handshake.
"""

from __future__ import annotations

import sys
import threading
import time
from contextvars import ContextVar
from typing import Any, Iterable, NamedTuple

__all__ = [
    "SpanEvent",
    "TraceRecorder",
    "current_trace_id",
    "payload_nbytes",
]

#: Per-request trace id, set by the gateway for the duration of a request
#: so service-level log lines can correlate with the HTTP access log.
current_trace_id: ContextVar[str | None] = ContextVar(
    "repro_trace_id", default=None
)

#: Span kinds.  ``exec``/``send``/``recv`` mirror the three exec-IR op
#: types; ``phase`` covers compile-pipeline stages (trace/schedule/lower/
#: compile) recorded by :mod:`repro.api`.
KINDS = ("exec", "send", "recv", "phase")


def payload_nbytes(value: Any) -> int:
    """Best-effort payload size — mirrors ``SizeModel.from_payloads``.

    Sizing runs on the send/recv hot path, so it must never serialize
    the payload: arrays answer via ``nbytes``, buffer-protocol objects
    (bytes, bytearray, mmap, pickle-5 out-of-band buffers) via a
    zero-copy ``memoryview``, and only opaque Python objects fall back
    to ``sys.getsizeof`` — all O(1) in the payload size.
    """
    nbytes = getattr(value, "nbytes", None)
    if nbytes is not None:
        try:
            return int(nbytes)
        except (TypeError, ValueError):
            pass
    try:
        with memoryview(value) as mv:
            return mv.nbytes
    except TypeError:
        pass
    return sys.getsizeof(value)


class SpanEvent(NamedTuple):
    """One recorded interval on one location's track.

    ``name`` is the step name for ``exec`` spans, the datum name for
    ``send`` spans, the port name for ``recv`` spans, and the phase label
    for ``phase`` spans.  ``start``/``end`` are seconds relative to the
    recorder's ``t_zero`` (its creation instant, except in multiprocess
    workers which record absolute monotonic time and are realigned at
    coordinator merge).

    A ``NamedTuple`` rather than a frozen dataclass: traced ``run_many``
    batches materialise thousands of these per second and the tuple
    constructor is ~4x cheaper than ``object.__setattr__``-per-field.
    """

    kind: str
    location: str
    name: str
    start: float
    end: float
    src: str | None = None
    dst: str | None = None
    port: str | None = None
    nbytes: int | None = None

    @property
    def duration(self) -> float:
        return self.end - self.start

    def identity(self) -> tuple:
        """Timing-free identity, for cross-backend schema comparison."""
        return (self.kind, self.location, self.name, self.src, self.dst,
                self.port)


def _discard(row: tuple) -> None:
    """``add`` target for a disabled recorder — drops the row."""


class TraceRecorder:
    """One flat append-only span buffer over one monotonic clock.

    Internally the buffer holds plain tuples in :class:`SpanEvent` field
    order, not event instances: a tuple append costs ~0.2µs where the
    event constructor alone costs ~0.7µs, and on a short-step workload
    that difference is the gap between ~5% and ~20% tracing overhead.
    Rows are materialised into :class:`SpanEvent` (and merge-ordered by
    location) only on :meth:`drain` / :meth:`snapshot`, off the hot path.

    The append path is lock-free *and* frame-free: :attr:`add` is the
    buffer list's bound ``append`` — one C call, atomic under the GIL —
    and the hot recording helpers in :mod:`repro.exec.interp` call it
    directly with a pre-built row.  :meth:`span` is the convenience
    wrapper for cold callers.  The extraction methods swap the buffer
    under a lock to exclude each other; a *recording* that races an
    extraction may land in the swapped-out generation and be dropped, so
    extraction is only complete once recording threads have quiesced —
    which every in-tree caller guarantees (the threaded backend drains
    after joining its location threads; a multiprocess worker records
    and flushes on the same thread).
    """

    __slots__ = ("enabled", "t_zero", "add", "_lock", "_rows")

    def __init__(self, *, enabled: bool = True, t_zero: float | None = None):
        self.enabled = enabled
        self.t_zero = time.monotonic() if t_zero is None else t_zero
        self._lock = threading.Lock()
        # Rows of (kind, location, name, start, end, src, dst, port,
        # nbytes) — exactly SpanEvent field order.
        self._rows: list[tuple] = []
        #: Hot-path entry point: append one raw row (see ``_rows`` above).
        #: ``start``/``end`` are raw ``time.monotonic()`` stamps; ``nbytes``
        #: may be the payload object itself (sized at materialise time).
        self.add = self._rows.append if enabled else _discard

    # -- clock ---------------------------------------------------------------
    def now(self) -> float:
        """Current instant on the recorder clock (relative to ``t_zero``)."""
        return time.monotonic() - self.t_zero

    def rel(self, t_abs: float) -> float:
        """Convert an absolute ``time.monotonic()`` stamp to recorder time."""
        return t_abs - self.t_zero

    # -- recording -----------------------------------------------------------
    def span(
        self,
        kind: str,
        location: str,
        name: str,
        start: float,
        end: float,
        src: str | None = None,
        dst: str | None = None,
        port: str | None = None,
        nbytes: Any = None,
    ) -> None:
        """Record one span.

        ``start``/``end`` are raw ``time.monotonic()`` stamps — call sites
        read the C clock directly and relativisation against ``t_zero``
        happens once, at :meth:`materialise` (a recorder with
        ``t_zero=0.0`` therefore treats stamps as already-relative).
        ``nbytes`` may be an ``int`` or the payload object itself, which
        is sized lazily at materialise time via :func:`payload_nbytes`.
        """
        if not self.enabled:
            return
        self.add((kind, location, name, start, end, src, dst, port, nbytes))

    def materialise(self, rows: list[tuple]) -> list[SpanEvent]:
        """Turn detached raw rows into merge-ordered :class:`SpanEvent`\\ s
        (sorted by location, recording order preserved within each),
        shifting stamps onto the recorder-relative clock and sizing any
        lazily-held payloads."""
        tz = self.t_zero
        out: list[SpanEvent] = []
        for row in sorted(rows, key=lambda r: r[1]):
            nb = row[8]
            if nb is not None and type(nb) is not int:
                nb = payload_nbytes(nb)
            out.append(
                SpanEvent(row[0], row[1], row[2], row[3] - tz, row[4] - tz,
                          row[5], row[6], row[7], nb)
            )
        return out

    # -- extraction ----------------------------------------------------------
    def detach(self) -> list[tuple]:
        """Remove and return the raw row buffer, unmaterialised.

        The cheap half of :meth:`drain` — callers that only need the spans
        later (e.g. a :class:`~repro.obs.RunProfile` built on the serving
        hot path) keep the raw rows and pay :meth:`materialise` on first
        access instead of per run.
        """
        with self._lock:
            rows, self._rows = self._rows, []
            if self.enabled:
                self.add = self._rows.append
        return rows

    def drain(self) -> list[SpanEvent]:
        """Remove and return everything recorded so far (merge-ordered)."""
        return self.materialise(self.detach())

    def absorb(
        self, events: Iterable[SpanEvent], *, offset: float = 0.0
    ) -> None:
        """Merge spans recorded on another clock, shifted by ``offset``.

        ``offset`` is *their* clock's zero expressed on this recorder's
        clock: a worker span at worker-monotonic ``t`` lands here at
        ``t + offset - self.t_zero``... except workers use ``t_zero=0.0``
        so their ``start`` *is* worker-monotonic, and the coordinator
        passes ``offset = coord_monotonic_at_ready - worker_monotonic_at_
        ready - self.t_zero`` pre-combined.  Callers supply the final
        additive shift; this method just applies it.
        """
        # Rows store raw clock stamps that materialise() shifts by
        # -t_zero, so pre-add t_zero to land at exactly start + offset.
        shift = offset + self.t_zero
        with self._lock:
            self._rows.extend(
                (ev.kind, ev.location, ev.name, ev.start + shift,
                 ev.end + shift, ev.src, ev.dst, ev.port, ev.nbytes)
                for ev in events
            )

    def snapshot(self) -> tuple[SpanEvent, ...]:
        """Everything recorded so far, without clearing the buffer."""
        with self._lock:
            rows = list(self._rows)
        return tuple(self.materialise(rows))

    def __len__(self) -> int:
        with self._lock:
            return len(self._rows)
