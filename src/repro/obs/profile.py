"""Structured run profiles and predicted-vs-actual alignment.

:class:`RunProfile` is the structured artifact attached to every traced
``run``/``run_many`` result: the recorded spans, the compile-pipeline
phase timings, and exporters (Chrome trace JSON, per-step duration
digests).  :func:`align` closes the loop the sched simulator opened —
replay the plan's predicted per-location timeline, match each predicted
exec against the recorded spans by step name, and report per-step drift
plus achieved-vs-predicted cross-location bytes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Any

from repro.obs.events import SpanEvent
from repro.obs.export import chrome_trace, write_chrome_trace

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.api import Plan

__all__ = ["ProfileReport", "RunProfile", "StepDrift", "align"]


class RunProfile:
    """Everything one traced execution recorded.

    Constructed either eagerly (``spans=...``) or from a drained recorder
    (:meth:`from_recorder`), which keeps the recorder's raw rows and
    materialises :class:`SpanEvent`\\ s only on first :attr:`spans` access
    — a traced ``run_many`` batch builds one of these per instance on the
    serving hot path, so construction must cost next to nothing.
    """

    __slots__ = ("backend", "phases", "_spans", "_buffers", "_recorder",
                 "_wall")

    def __init__(
        self,
        backend: str,
        spans: tuple[SpanEvent, ...] = (),
        wall_s: float | None = None,
        phases: tuple[tuple[str, float], ...] = (),
    ):
        self.backend = backend
        #: Compile-pipeline ``(label, seconds)`` timings copied off the plan.
        self.phases = tuple(phases)
        self._spans: tuple[SpanEvent, ...] | None = tuple(spans)
        self._buffers: list[tuple] | None = None
        self._recorder = None
        self._wall = wall_s

    @classmethod
    def from_recorder(
        cls, backend: str, recorder, *, wall_s: float | None = None
    ) -> "RunProfile":
        """Detach ``recorder``'s buffers without materialising spans."""
        prof = cls(backend, wall_s=wall_s)
        prof._spans = None
        prof._buffers = recorder.detach()
        prof._recorder = recorder
        return prof

    @property
    def spans(self) -> tuple[SpanEvent, ...]:
        if self._spans is None:
            buffers, self._buffers = self._buffers, None
            rec, self._recorder = self._recorder, None
            self._spans = tuple(rec.materialise(buffers or []))
        return self._spans

    @property
    def wall_s(self) -> float:
        if self._wall is None:
            spans = self.spans
            self._wall = max((s.end for s in spans), default=0.0) - min(
                (s.start for s in spans), default=0.0
            )
        return self._wall

    def with_phases(
        self, phases: tuple[tuple[str, float], ...]
    ) -> "RunProfile":
        """Return ``self`` with the phase timings replaced (in place —
        the profile rides exactly one result and is stamped once)."""
        self.phases = tuple(phases)
        return self

    # -- digests -------------------------------------------------------------
    def by_location(self) -> dict[str, tuple[SpanEvent, ...]]:
        out: dict[str, list[SpanEvent]] = {}
        for ev in self.spans:
            out.setdefault(ev.location, []).append(ev)
        return {
            loc: tuple(sorted(evs, key=lambda e: (e.start, e.end)))
            for loc, evs in out.items()
        }

    def exec_durations(self) -> dict[str, list[float]]:
        """Measured seconds per step (one sample per exec span)."""
        out: dict[str, list[float]] = {}
        for ev in self.spans:
            if ev.kind == "exec":
                out.setdefault(ev.name, []).append(ev.duration)
        return out

    def cross_bytes(self) -> int:
        """Achieved cross-location bytes (sends whose src != dst)."""
        return sum(
            ev.nbytes or 0
            for ev in self.spans
            if ev.kind == "send" and ev.src != ev.dst
        )

    def span_schema(self) -> tuple[tuple, ...]:
        """Sorted timing-free identity multiset — the differential unit."""
        return tuple(sorted(ev.identity() for ev in self.spans))

    # -- exporters -----------------------------------------------------------
    def chrome_trace(self) -> dict:
        return chrome_trace(self.spans, phases=self.phases)

    def save_chrome_trace(self, path: str) -> None:
        write_chrome_trace(path, self.spans, phases=self.phases)

    def summary(self) -> str:
        locs = sorted({ev.location for ev in self.spans})
        n_exec = sum(1 for ev in self.spans if ev.kind == "exec")
        n_comm = sum(1 for ev in self.spans if ev.kind in ("send", "recv"))
        lines = [
            f"profile[{self.backend}]: {len(self.spans)} spans "
            f"({n_exec} exec, {n_comm} comm) over {len(locs)} location(s)",
        ]
        if self.wall_s:
            lines.append(f"wall: {self.wall_s * 1e3:.2f} ms")
        for label, seconds in self.phases:
            lines.append(f"  {label:<24s} {seconds * 1e3:9.3f} ms")
        return "\n".join(lines)


@dataclass(frozen=True)
class StepDrift:
    """Predicted vs measured timing for one step."""

    step: str
    predicted_start: float
    actual_start: float
    predicted_s: float
    actual_s: float

    @property
    def start_drift(self) -> float:
        return self.actual_start - self.predicted_start

    @property
    def duration_ratio(self) -> float:
        if self.predicted_s <= 0.0:
            return float("inf") if self.actual_s > 0 else 1.0
        return self.actual_s / self.predicted_s


@dataclass(frozen=True)
class ProfileReport:
    """The aligned prediction: per-step drift + aggregate comparisons."""

    backend: str
    predicted_makespan: float
    actual_makespan: float
    drifts: tuple[StepDrift, ...]
    predicted_cross_bytes: int
    actual_cross_bytes: int
    unmatched_predicted: tuple[str, ...] = ()
    unmatched_actual: tuple[str, ...] = ()

    def summary(self) -> str:
        lines = [
            f"predicted vs actual [{self.backend}]",
            f"  makespan: {self.predicted_makespan * 1e3:9.2f} ms predicted"
            f" | {self.actual_makespan * 1e3:9.2f} ms actual",
            f"  cross-location bytes: {self.predicted_cross_bytes} predicted"
            f" | {self.actual_cross_bytes} actual",
            f"  {'step':<16s} {'pred start':>10s} {'act start':>10s} "
            f"{'pred ms':>9s} {'act ms':>9s} {'ratio':>7s}",
        ]
        for d in self.drifts:
            ratio = d.duration_ratio
            lines.append(
                f"  {d.step:<16s} {d.predicted_start * 1e3:9.2f}m "
                f"{d.actual_start * 1e3:9.2f}m "
                f"{d.predicted_s * 1e3:9.3f} {d.actual_s * 1e3:9.3f} "
                f"{ratio:7.2f}"
            )
        if self.unmatched_predicted:
            lines.append(
                "  predicted but never recorded: "
                + ", ".join(self.unmatched_predicted)
            )
        if self.unmatched_actual:
            lines.append(
                "  recorded but never predicted: "
                + ", ".join(self.unmatched_actual)
            )
        return "\n".join(lines)


def align(
    plan: "Plan",
    profile: RunProfile,
    *,
    network: Any | None = None,
    sizes: Any | None = None,
    costs: Any | None = None,
    exec_slots: int | None = None,
) -> ProfileReport:
    """Align recorded spans against the simulator's predicted timeline.

    Runs :func:`repro.sched.simulate` on ``plan.system`` under the given
    models, then matches predicted exec events to recorded exec spans by
    step name.  Actual times are normalised so the earliest recorded span
    starts at 0, mirroring the simulation clock.
    """
    from repro.sched.simulate import simulate

    sim = simulate(
        plan.system,
        network=network,
        sizes=sizes,
        costs=costs,
        exec_slots=exec_slots,
    )

    # Predicted: earliest occurrence + duration per step name.
    pred: dict[str, tuple[float, float]] = {}
    for timeline in sim.timelines.values():
        for ev in timeline:
            if ev.kind != "exec" or ev.name is None:
                continue
            cur = pred.get(ev.name)
            if cur is None or ev.start < cur[0]:
                pred[ev.name] = (ev.start, ev.end - ev.start)

    run_spans = [s for s in profile.spans if s.kind != "phase"]
    t0 = min((s.start for s in run_spans), default=0.0)
    actual: dict[str, tuple[float, float]] = {}
    samples: dict[str, list[float]] = {}
    for s in run_spans:
        if s.kind != "exec":
            continue
        samples.setdefault(s.name, []).append(s.duration)
        cur = actual.get(s.name)
        if cur is None or (s.start - t0) < cur[0]:
            actual[s.name] = (s.start - t0, s.duration)
    for step, (start, _) in actual.items():
        vals = samples[step]
        actual[step] = (start, sum(vals) / len(vals))

    drifts = tuple(
        StepDrift(
            step=step,
            predicted_start=pred[step][0],
            actual_start=actual[step][0],
            predicted_s=pred[step][1],
            actual_s=actual[step][1],
        )
        for step in sorted(set(pred) & set(actual),
                           key=lambda s: pred[s][0])
    )
    actual_makespan = max(
        (s.end - t0 for s in run_spans), default=0.0
    )
    return ProfileReport(
        backend=profile.backend,
        predicted_makespan=sim.makespan,
        actual_makespan=actual_makespan,
        drifts=drifts,
        predicted_cross_bytes=sim.cross_bytes,
        actual_cross_bytes=profile.cross_bytes(),
        unmatched_predicted=tuple(sorted(set(pred) - set(actual))),
        unmatched_actual=tuple(sorted(set(actual) - set(pred))),
    )
