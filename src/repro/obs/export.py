"""Chrome trace-event JSON export (Perfetto / ``chrome://tracing``).

One process (``pid=1``) with one thread track per location, named via
``"M"`` metadata events; every span becomes a ``"X"`` complete event with
microsecond ``ts``/``dur``.  Cross-location communication is drawn as
flow arrows: each matched send→recv pair on a ``(src, dst, port)``
channel gets an ``"s"`` (flow start, anchored on the send span) and an
``"f"`` (flow finish, ``bp="e"``, anchored on the recv span) sharing one
flow ``id``.  Compile-pipeline ``phase`` spans land on a separate
``pid=2`` track so run-time and compile-time are visually distinct.

The exporter guarantees monotone non-decreasing ``ts`` within each
``(pid, tid)`` track — the schema test relies on it.
"""

from __future__ import annotations

import json
from typing import Iterable, Mapping, Sequence

from repro.obs.events import SpanEvent

__all__ = ["chrome_trace", "validate_chrome_trace", "write_chrome_trace"]

_US = 1e6  # trace-event timestamps are microseconds


def _tracks(spans: Iterable[SpanEvent]) -> dict[str, list[SpanEvent]]:
    by_loc: dict[str, list[SpanEvent]] = {}
    for ev in spans:
        by_loc.setdefault(ev.location, []).append(ev)
    for loc in by_loc:
        by_loc[loc].sort(key=lambda e: (e.start, e.end))
    return by_loc


def chrome_trace(
    spans: Sequence[SpanEvent],
    *,
    phases: Sequence[tuple[str, float]] = (),
) -> dict:
    """Build a trace-event JSON object from recorded spans.

    ``phases`` are ``(label, seconds)`` compile-pipeline timings laid out
    back-to-back on their own track (they have durations but no recorded
    wall-clock placement).
    """
    by_loc = _tracks(s for s in spans if s.kind != "phase")
    events: list[dict] = []
    tids = {loc: i + 1 for i, loc in enumerate(sorted(by_loc))}

    for loc, tid in tids.items():
        events.append({
            "name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
            "args": {"name": loc},
        })

    # Complete ("X") events, per track in start order → monotone ts.
    for loc, tid in tids.items():
        for ev in by_loc[loc]:
            args: dict = {"kind": ev.kind}
            if ev.src is not None:
                args["src"] = ev.src
            if ev.dst is not None:
                args["dst"] = ev.dst
            if ev.port is not None:
                args["port"] = ev.port
            if ev.nbytes is not None:
                args["nbytes"] = ev.nbytes
            events.append({
                "name": f"{ev.kind}:{ev.name}",
                "ph": "X",
                "pid": 1,
                "tid": tid,
                "ts": round(ev.start * _US, 3),
                "dur": max(round(ev.duration * _US, 3), 0.001),
                "cat": ev.kind,
                "args": args,
            })

    # Flow arrows: pair sends and recvs per (src, dst, port) channel in
    # start order — the exec IR delivers each channel FIFO, so the k-th
    # send on a channel corresponds to the k-th recv.
    sends: dict[tuple, list[SpanEvent]] = {}
    recvs: dict[tuple, list[SpanEvent]] = {}
    for ev in spans:
        if ev.kind == "send" and ev.src != ev.dst:
            sends.setdefault((ev.src, ev.dst, ev.port), []).append(ev)
        elif ev.kind == "recv" and ev.src != ev.dst:
            recvs.setdefault((ev.src, ev.dst, ev.port), []).append(ev)
    flow_id = 0
    for key in sorted(sends, key=str):
        ss = sorted(sends[key], key=lambda e: e.start)
        rr = sorted(recvs.get(key, []), key=lambda e: e.start)
        for s_ev, r_ev in zip(ss, rr):
            flow_id += 1
            events.append({
                "name": f"comm:{s_ev.name}", "ph": "s", "cat": "comm",
                "id": flow_id, "pid": 1, "tid": tids[s_ev.location],
                "ts": round(s_ev.start * _US, 3),
            })
            events.append({
                "name": f"comm:{s_ev.name}", "ph": "f", "cat": "comm",
                "bp": "e", "id": flow_id, "pid": 1,
                "tid": tids[r_ev.location],
                "ts": round(max(r_ev.start, s_ev.start) * _US, 3),
            })

    if phases:
        events.append({
            "name": "thread_name", "ph": "M", "pid": 2, "tid": 1,
            "args": {"name": "compile pipeline"},
        })
        cursor = 0.0
        for label, seconds in phases:
            events.append({
                "name": label, "ph": "X", "pid": 2, "tid": 1,
                "ts": round(cursor * _US, 3),
                "dur": max(round(seconds * _US, 3), 0.001),
                "cat": "phase", "args": {"kind": "phase"},
            })
            cursor += seconds

    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(
    path: str,
    spans: Sequence[SpanEvent],
    *,
    phases: Sequence[tuple[str, float]] = (),
) -> None:
    with open(path, "w") as fh:
        json.dump(chrome_trace(spans, phases=phases), fh)


def validate_chrome_trace(obj: Mapping) -> None:
    """Raise ``ValueError`` unless ``obj`` is schema-valid trace JSON.

    Checks the invariants the exporter promises: required keys per event,
    ``dur`` on complete events, and monotone ``ts`` per ``(pid, tid)``
    track.  Used by tests and available to callers sanity-checking files
    before loading them into Perfetto.
    """
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    last_ts: dict[tuple, float] = {}
    for ev in events:
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"event missing {key!r}: {ev}")
        if ev["ph"] == "X":
            if "ts" not in ev or "dur" not in ev:
                raise ValueError(f"complete event missing ts/dur: {ev}")
            track = (ev["pid"], ev["tid"])
            if ev["ts"] < last_ts.get(track, float("-inf")):
                raise ValueError(
                    f"non-monotone ts on track {track}: {ev}"
                )
            last_ts[track] = ev["ts"]
        elif ev["ph"] in ("s", "f") and "id" not in ev:
            raise ValueError(f"flow event missing id: {ev}")
