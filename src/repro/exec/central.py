"""Centralised dataflow interpreter over the program IR.

:class:`ProgramRuntime` is the engine behind the ``inprocess`` backend: it
drives one :class:`~repro.exec.interp.Cursor` per location and repeatedly
fires the ops the SWIRL semantics enables —

* a matching active ``SendOp``/``RecvOp`` pair with the datum resident at
  the source fires as a (COMM)/(L-COMM) copy;
* an ``ExecOp`` whose occurrence is active on *every* location of ``M(s)``
  with ``In^D(s)`` resident fires the step body once (on the leader) and
  stores ``Out^D(s)`` everywhere —

with real effects on a thread pool, per-step retry, straggler speculation
and heartbeats exactly like the legacy reduction runtime
(:class:`repro.workflow.runtime.Runtime`, kept as the deprecated reference
oracle).  Because op completion flags are a structured program counter,
checkpoints are still *reachable SWIRL terms*: the remaining system is
rebuilt from the not-yet-done ops
(:meth:`~repro.exec.program.ExecProgram.remaining_system`), so snapshots
stay interchangeable with every other checkpointing backend.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import (
    FIRST_COMPLETED,
    Future,
    ThreadPoolExecutor,
    wait,
)
from pathlib import Path
from typing import Any, Mapping

from repro.core.compile import StepMeta
from repro.core.parser import dumps

from .interp import (
    Cursor,
    Deadline,
    call_with_timeout,
    enabled_exec_picks,
    first_enabled_comm,
    record_comm_fire,
    record_exec_fire,
    record_policy_fire,
)
from .policy import FaultPolicy, StepTimeoutError
from .program import ExecOp, ExecProgram

PayloadKey = tuple[str, str]  # (location, data_name)

__all__ = ["ProgramRuntime"]

_MISSING = object()


class ProgramRuntime:
    """Fault-tolerant, checkpointable executor over an :class:`ExecProgram`.

    Parameters mirror the legacy reduction runtime; ``completed`` names
    steps already finished in a restored snapshot — their recorded outputs
    (harvested from ``initial_payloads``) are replayed instead of
    re-executing the step body.
    """

    def __init__(
        self,
        program: ExecProgram,
        steps: Mapping[str, StepMeta],
        *,
        initial_payloads: Mapping[PayloadKey, Any] | None = None,
        expected_s: Mapping[str, float] | None = None,
        retry=None,
        speculation=None,
        max_workers: int = 8,
        checkpoint_every: int = 0,
        checkpoint_path: str | Path | None = None,
        heartbeat=None,
        completed: frozenset[str] = frozenset(),
        recorder=None,
        policy: FaultPolicy | None = None,
    ):
        from repro.workflow.fault import (
            HeartbeatMonitor,
            RetryPolicy,
            SpeculationPolicy,
        )
        from repro.workflow.runtime import RunStats

        self.program = program
        self.steps = dict(steps)
        self.payloads: dict[PayloadKey, Any] = dict(initial_payloads or {})
        self.expected_s = dict(expected_s or {})
        # A uniform FaultPolicy constructs the engines unless the caller
        # passed explicit ones (explicit beats policy beats defaults).
        self.policy = policy
        if policy is not None:
            retry = retry or policy.retry_policy()
            speculation = speculation or policy.speculation_policy()
            heartbeat = heartbeat or policy.heartbeat_monitor()
        self.retry = retry or RetryPolicy()
        self.speculation = speculation or SpeculationPolicy(enabled=False)
        self.max_workers = max_workers
        self.checkpoint_every = checkpoint_every
        self.checkpoint_path = Path(checkpoint_path) if checkpoint_path else None
        # Satellite fix: the documented default now lives in ONE place
        # (fault.DEFAULT_HEARTBEAT_TIMEOUT_S) instead of a 5s dataclass
        # default silently overridden to 60s here.
        self.heartbeat = heartbeat or HeartbeatMonitor()
        self.stats = RunStats()
        self.recorder = recorder
        self.completed_execs: set[str] = set(completed)
        self._replayable = frozenset(completed)
        self._lock = threading.Lock()
        self.cursors: dict[str, Cursor] = {}
        self.data: dict[str, set[str]] = {}
        for lp in program.programs:
            for op in lp.exec_ops():
                if op.step not in self.steps:
                    raise KeyError(
                        f"no step function registered for {op.step!r}"
                    )
            self.cursors[lp.location] = Cursor(lp)
            self.data[lp.location] = set(lp.data)
            self.heartbeat.register(lp.location)
        # Outputs recoverable for replayed (already-completed) steps.
        self._recorded = self._recorded_outputs()

    # -- checkpointing -------------------------------------------------------
    def checkpoint(self):
        from repro.workflow.runtime import Checkpoint

        with self._lock:
            remaining = self.program.remaining_system(
                {l: c.done_flags() for l, c in self.cursors.items()},
                {l: frozenset(d) for l, d in self.data.items()},
            )
            return Checkpoint(
                system_text=dumps(remaining),
                payloads=dict(self.payloads),
                completed_execs=frozenset(self.completed_execs),
            )

    def _recorded_outputs(self) -> dict[str, dict[str, Any]]:
        recorded: dict[str, dict[str, Any]] = {}
        if not self._replayable:
            return recorded
        by_datum: dict[str, Any] = {}
        for (_, d), v in self.payloads.items():
            by_datum.setdefault(d, v)
        for lp in self.program.programs:
            for op in lp.exec_ops():
                if op.step in recorded or op.step not in self._replayable:
                    continue
                out: dict[str, Any] = {}
                complete = True
                for d in op.outputs:
                    hit = next(
                        (
                            self.payloads[(l, d)]
                            for l in sorted(op.locations)
                            if (l, d) in self.payloads
                        ),
                        by_datum.get(d, _MISSING),
                    )
                    if hit is _MISSING:
                        complete = False
                        break
                    out[d] = hit
                if complete:
                    recorded[op.step] = out
        return recorded

    # -- enabled-op matching ---------------------------------------------------
    def _apply_comms(self) -> int:
        """Fire every currently enabled communication (fixpoint)."""
        n = 0
        with self._lock:
            while True:
                hit = first_enabled_comm(self.cursors, self.data)
                if hit is None:
                    return n
                op, src, i, j = hit
                self.cursors[src].complete(i)
                self.cursors[op.dst].complete(j)
                self.data[op.dst].add(op.data)
                payload = self.payloads[(op.src, op.data)]
                self.payloads[(op.dst, op.data)] = payload
                self.stats.comms += 1
                if self.recorder is not None:
                    t = time.monotonic()
                    record_comm_fire(self.recorder, op, t, t, payload)
                n += 1

    def _enabled_execs(self) -> list[tuple[ExecOp, tuple[tuple[str, int], ...]]]:
        """(EXEC)-enabled ops: active on all of ``M(s)``, inputs resident."""
        with self._lock:
            return enabled_exec_picks(self.cursors, self.data)

    # -- effects ---------------------------------------------------------------
    def _run_exec(self, op: ExecOp, pool: ThreadPoolExecutor) -> dict[str, Any]:
        leader = min(op.locations)
        if op.step in self._replayable and op.step in self._recorded:
            # Restored snapshot: replay the recorded outputs, don't redo.
            for l in op.locations:
                self.heartbeat.beat(l)
            return dict(self._recorded[op.step])
        inputs = {d: self.payloads[(leader, d)] for d in op.inputs}
        fn = self.steps[op.step].fn
        timeout_s = self.policy.timeout_s if self.policy is not None else None

        def attempt() -> Mapping[str, Any]:
            if timeout_s is None:
                return fn(inputs)
            try:
                return call_with_timeout(
                    lambda: fn(inputs), timeout_s, op.step
                )
            except StepTimeoutError:
                with self._lock:
                    self.stats.timeouts += 1
                if self.recorder is not None:
                    t = time.monotonic()
                    record_policy_fire(
                        self.recorder, "timeout", leader, op.step,
                        t - timeout_s, t,
                    )
                raise

        def with_retry() -> Mapping[str, Any]:
            return self.retry.run(
                attempt, on_retry=lambda n, e: self._count_retry()
            )

        t0 = time.monotonic()
        out, speculated = self.speculation.run(
            with_retry, self.expected_s.get(op.step), pool
        )
        dt = time.monotonic() - t0
        if speculated:
            with self._lock:
                self.stats.speculations += 1
        missing = set(op.outputs) - set(out)
        if missing:
            raise RuntimeError(
                f"step {op.step!r} did not produce outputs {sorted(missing)}"
            )
        with self._lock:
            self.stats.exec_log.append((op.step, leader, dt))
        if self.recorder is not None:
            record_exec_fire(self.recorder, op, t0, t0 + dt)
        for l in op.locations:
            self.heartbeat.beat(l)
        return {d: out[d] for d in op.outputs}

    def _apply_exec(
        self,
        op: ExecOp,
        picks: tuple[tuple[str, int], ...],
        outputs: dict[str, Any],
    ) -> None:
        with self._lock:
            for l, i in picks:
                self.cursors[l].complete(i)
                self.data[l].update(op.outputs)
                for d, v in outputs.items():
                    self.payloads[(l, d)] = v
            self.stats.execs += 1
            self.completed_execs.add(op.step)

    def _count_retry(self) -> None:
        with self._lock:
            self.stats.retries += 1

    # -- main loop --------------------------------------------------------------
    def run(self, *, max_rounds: int = 1_000_000):
        from repro.workflow.runtime import WorkflowDeadlock

        t_start = time.monotonic()
        since_ckpt = 0
        deadline = Deadline(
            self.policy.deadline_s if self.policy is not None else None
        )
        pool = ThreadPoolExecutor(max_workers=self.max_workers)
        try:
            inflight: dict[tuple, tuple[ExecOp, tuple, Future]] = {}
            for _ in range(max_rounds):
                if deadline.expired():
                    if self.recorder is not None:
                        t = time.monotonic()
                        record_policy_fire(
                            self.recorder, "deadline", "-", "run", t, t
                        )
                    deadline.check()  # raises RunDeadlineExceeded
                progressed = self._apply_comms() > 0

                for op, picks in self._enabled_execs():
                    key = (op.step, op.inputs, op.outputs, op.locations)
                    if key not in inflight:
                        inflight[key] = (
                            op,
                            picks,
                            pool.submit(self._run_exec, op, pool),
                        )
                        progressed = True

                if not inflight:
                    if progressed:
                        continue
                    break  # terminated or deadlocked

                done, _ = wait(
                    [f for _, _, f in inflight.values()],
                    timeout=deadline.remaining(),
                    return_when=FIRST_COMPLETED,
                )
                for key in [
                    k for k, (_, _, f) in inflight.items() if f in done
                ]:
                    op, picks, fut = inflight.pop(key)
                    self._apply_exec(op, picks, fut.result())
                    since_ckpt += 1
                    if (
                        self.checkpoint_every
                        and self.checkpoint_path
                        and since_ckpt >= self.checkpoint_every
                    ):
                        self.checkpoint().save(self.checkpoint_path)
                        self.stats.checkpoints += 1
                        since_ckpt = 0
        finally:
            # Do not block on abandoned speculation losers — they are pure
            # and their results are discarded.
            pool.shutdown(wait=False, cancel_futures=True)

        self.stats.wall_s = time.monotonic() - t_start
        if not all(c.finished() for c in self.cursors.values()):
            remaining = self.program.remaining_system(
                {l: c.done_flags() for l, c in self.cursors.items()},
                {l: frozenset(d) for l, d in self.data.items()},
            )
            raise WorkflowDeadlock(
                "workflow did not terminate; remaining system:\n"
                + remaining.pretty()
            )
        return self.stats

    # -- results -------------------------------------------------------------
    def payload(self, location: str, data: str) -> Any:
        return self.payloads[(location, data)]

    def location_data(self, location: str) -> dict[str, Any]:
        return {
            d: v for (l, d), v in self.payloads.items() if l == location
        }
