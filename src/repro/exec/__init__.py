"""``repro.exec`` — the executable program IR between ``sched`` and backends.

SWIRL is "not designed for human interaction but to serve as a low-level
compilation target"; this package is where that target becomes literal.
:func:`lower_system` turns an (optimised, scheduled) workflow system into an
:class:`ExecProgram`: one :class:`LocationProgram` per location holding a
*program-order array* of resolved ``SEND``/``RECV``/``EXEC`` ops plus a flat
control skeleton (sequence/parallel structure), with channel endpoints, step
bindings, leader election and placement/schedule metadata resolved at
lowering time.  Every in-tree backend is an interpreter over this one form —
no backend walks the recursive trace trees.

Layering::

    core (syntax, flat IR)  →  sched (placement)  →  exec (program IR)  →  backends

The legacy tree interpreters (:class:`repro.workflow.runtime.Runtime`,
:class:`repro.workflow.threaded.ThreadedRuntime`) are kept as deprecated
reference oracles; ``tests/test_differential.py`` checks flat-program
execution against them on random DAGs.
"""

from .program import (
    ExecOp,
    ExecProgram,
    LocationProgram,
    Op,
    RecvOp,
    SendOp,
    lower_flat,
    lower_system,
    to_action,
)
from .interp import Cursor, Deadline, StepGuard
from .policy import FaultPolicy, RunDeadlineExceeded, StepTimeoutError
from .emit import emit_location_source, emit_program_sources
from .elastic import rename_program, resimulate

__all__ = [
    "Deadline",
    "FaultPolicy",
    "RunDeadlineExceeded",
    "StepGuard",
    "StepTimeoutError",
    "ExecOp",
    "SendOp",
    "RecvOp",
    "Op",
    "LocationProgram",
    "ExecProgram",
    "lower_system",
    "lower_flat",
    "to_action",
    "Cursor",
    "emit_location_source",
    "emit_program_sources",
    "rename_program",
    "resimulate",
]
