"""Interpretation machinery over the program IR.

:class:`Cursor` is a *structured program counter* for one
:class:`~repro.exec.program.LocationProgram`: it maintains the set of op
indices that are active **now** (not guarded by an unfinished sequential
prefix — exactly the ``active_occurrences`` notion of
:mod:`repro.core.semantics`, computed incrementally over the flat skeleton
instead of by tree traversal).  Completing an op advances sequence pointers
and parallel join counters in O(depth); the enabled set is always available
in O(1).

Centralised interpreters (the ``inprocess`` dataflow runtime, the
deterministic ``jax`` reducer) drive one cursor per location and fire
matching ops; the decentralised threaded interpreter instead recurses over
the same :class:`~repro.exec.program.ControlSpec` with real threads.
"""

from __future__ import annotations

import threading
import time
from typing import TYPE_CHECKING, Any, Callable, Iterable, Mapping

# .policy is imported lazily inside the helpers below: policy.py pulls in
# repro.workflow.fault, whose package __init__ imports repro.workflow.threaded,
# which imports this module — a top-level import here would close that cycle.
if TYPE_CHECKING:  # pragma: no cover - typing only
    from .policy import FaultPolicy

from .program import (
    K_ACT,
    K_PAR,
    K_SEQ,
    ExecOp,
    LocationProgram,
    RecvOp,
    SendOp,
)

__all__ = [
    "Cursor",
    "Deadline",
    "StepGuard",
    "call_with_timeout",
    "first_enabled_comm",
    "enabled_exec_picks",
    "record_comm_fire",
    "record_exec_fire",
    "record_policy_fire",
    "record_recv_fire",
    "record_send_fire",
]


class Cursor:
    """Incremental enabled-set tracker over one location program."""

    __slots__ = (
        "program",
        "_spec",
        "enabled",
        "_op_done",
        "_seq_ptr",
        "_par_left",
        "_finished",
    )

    def __init__(self, program: LocationProgram):
        self.program = program
        spec = program.control()
        self._spec = spec
        self.enabled: set[int] = set()
        self._op_done = [False] * len(program.ops)
        self._seq_ptr = [0] * len(spec.kind)
        self._par_left = [0] * len(spec.kind)
        self._finished = False
        if spec.root is None:
            self._finished = True
        else:
            self._enter(spec.root)

    # -- state --------------------------------------------------------------
    def finished(self) -> bool:
        return self._finished

    def done_flags(self) -> list[bool]:
        """Per-op completion flags (for remaining-term reconstruction)."""
        return list(self._op_done)

    def enabled_ops(self) -> list[int]:
        """Active op indices in program order (deterministic iteration)."""
        return sorted(self.enabled)

    # -- transitions ---------------------------------------------------------
    def complete(self, op_index: int) -> None:
        """Mark one *enabled* op as executed; exposes its successors."""
        if op_index not in self.enabled:
            raise ValueError(
                f"op {op_index} is not active on {self.program.location!r}"
            )
        self.enabled.discard(op_index)
        self._op_done[op_index] = True
        self._node_done(self._spec.leaf_node[op_index])

    # -- internals -----------------------------------------------------------
    def _enter(self, nid: int) -> None:
        spec = self._spec
        kind = spec.kind[nid]
        if kind == K_ACT:
            self.enabled.add(spec.instr[nid])
            return
        kids = spec.children[nid]
        if not kids:  # cannot happen for compacted programs; be safe
            self._node_done(nid)
            return
        if kind == K_SEQ:
            self._seq_ptr[nid] = 0
            self._enter(kids[0])
        else:  # K_PAR
            self._par_left[nid] = len(kids)
            for k in kids:
                self._enter(k)

    def _node_done(self, nid: int) -> None:
        spec = self._spec
        while True:
            parent = spec.parent[nid]
            if parent < 0:
                self._finished = True
                return
            if spec.kind[parent] == K_SEQ:
                self._seq_ptr[parent] += 1
                kids = spec.children[parent]
                if self._seq_ptr[parent] < len(kids):
                    self._enter(kids[self._seq_ptr[parent]])
                    return
                nid = parent
            else:  # K_PAR
                self._par_left[parent] -= 1
                if self._par_left[parent] > 0:
                    return
                nid = parent


# ---------------------------------------------------------------------------
# Shared enablement matching — one semantics core for every centralised
# interpreter (the inprocess dataflow runtime, the deterministic jax
# reducer).  The predicates here ARE the Fig. 3 premises over cursors.
# ---------------------------------------------------------------------------


def first_enabled_comm(
    cursors: Mapping[str, Cursor],
    data: Mapping[str, set],
    order: Iterable[str] | None = None,
) -> tuple[SendOp, str, int, int] | None:
    """First (COMM)/(L-COMM)-enabled pair, scanning ``order``.

    A send is enabled when active with its datum resident at the source;
    it matches the first active recv on the same ``(port, src, dst)`` at
    the destination (never itself, for local comms).  Returns
    ``(send_op, src_location, send_index, recv_index)`` or ``None``.
    """
    for loc in order if order is not None else cursors:
        cur = cursors[loc]
        for i in cur.enabled_ops():
            op = cur.program.ops[i]
            if not isinstance(op, SendOp):
                continue
            if op.src != loc or op.data not in data[loc]:
                continue
            dst = cursors.get(op.dst)
            if dst is None:
                continue
            for j in dst.enabled_ops():
                r = dst.program.ops[j]
                if (
                    isinstance(r, RecvOp)
                    and r.port == op.port
                    and r.src == op.src
                    and r.dst == op.dst
                    and not (op.src == op.dst and j == i)
                ):
                    return op, loc, i, j
    return None


def enabled_exec_picks(
    cursors: Mapping[str, Cursor],
    data: Mapping[str, set],
    order: Iterable[str] | None = None,
) -> list[tuple[ExecOp, tuple[tuple[str, int], ...]]]:
    """(EXEC)-enabled ops with their per-location occurrence picks.

    An exec fires when every location of ``M(s)`` has an active occurrence
    of the same predicate *and* ``In^D(s)`` is resident on each; the first
    active occurrence per location is picked (occurrences of one predicate
    are interchangeable).  Returns ``[(op, ((location, op_index), ...))]``
    in discovery order — callers impose their own firing order.
    """
    sites: dict[tuple, dict[str, int]] = {}
    for loc in order if order is not None else cursors:
        cur = cursors[loc]
        for i in cur.enabled_ops():
            op = cur.program.ops[i]
            if isinstance(op, ExecOp):
                key = (op.step, op.inputs, op.outputs, op.locations)
                sites.setdefault(key, {}).setdefault(loc, i)
    out: list[tuple[ExecOp, tuple[tuple[str, int], ...]]] = []
    for key, by_loc in sites.items():
        _, inputs, _, locations = key
        if not all(l in by_loc for l in locations):
            continue
        if not all(set(inputs) <= data[l] for l in locations):
            continue
        picks = tuple((l, by_loc[l]) for l in locations)
        op = cursors[picks[0][0]].program.ops[picks[0][1]]
        assert isinstance(op, ExecOp)
        out.append((op, picks))
    return out


# ---------------------------------------------------------------------------
# Shared span recording — the ONE place that decides what a span for an op
# firing looks like, so every backend (centralised or decentralised) emits
# an identical schema for the same program.  Call sites guard on
# ``recorder is None`` themselves, keeping the untraced hot path free of
# function calls; these helpers additionally no-op on None so defensive
# callers pay only the call.
# ---------------------------------------------------------------------------


def record_send_fire(recorder, op, t0: float, t1: float,
                     nbytes=None) -> None:
    """One send span at ``op.src``, named after the datum.

    ``t0``/``t1`` are raw ``time.monotonic()`` stamps; ``nbytes`` is an
    ``int`` or the payload object itself (sized lazily off the hot path).
    The helpers append raw rows via ``TraceRecorder.add`` — the bound
    ``list.append`` fast path — rather than the ``span()`` wrapper; the
    row layout is :class:`~repro.obs.events.SpanEvent` field order."""
    if recorder is None:
        return
    recorder.add(("send", op.src, op.data, t0, t1,
                  op.src, op.dst, op.port, nbytes))


def record_recv_fire(recorder, op, t0: float, t1: float,
                     nbytes=None) -> None:
    """One recv span at ``op.dst``, named after the port."""
    if recorder is None:
        return
    recorder.add(("recv", op.dst, op.port, t0, t1,
                  op.src, op.dst, op.port, nbytes))


def record_comm_fire(recorder, op: SendOp, t0: float, t1: float,
                     nbytes=None) -> None:
    """Record one atomic comm firing (centralised interpreters): the send
    span at ``op.src`` and the matching recv span at ``op.dst`` share the
    interval.  Decentralised interpreters record the two halves
    separately via :func:`record_send_fire` / :func:`record_recv_fire` —
    the identity schema is the same either way."""
    if recorder is None:
        return
    add = recorder.add
    add(("send", op.src, op.data, t0, t1, op.src, op.dst, op.port, nbytes))
    add(("recv", op.dst, op.port, t0, t1, op.src, op.dst, op.port, nbytes))


def record_exec_fire(recorder, op: ExecOp, t0: float, t1: float,
                     locations: Iterable[str] | None = None) -> None:
    """Record one exec firing: one span per location of ``M(s)`` (the
    (EXEC) rule reduces all of them synchronously)."""
    if recorder is None:
        return
    for loc in locations if locations is not None else op.locations:
        recorder.add(("exec", loc, op.step, t0, t1, None, None, None, None))


def record_policy_fire(recorder, kind: str, location: str, step: str,
                       t0: float, t1: float) -> None:
    """One policy-outcome span (``kind`` ∈ retry/timeout/speculation/
    heartbeat_death/deadline), named ``"<kind>:<step>"`` so Perfetto rows
    group by mechanism.  Same None fast-path contract as the fire helpers."""
    if recorder is None:
        return
    recorder.add(("policy", location, f"{kind}:{step}",
                  t0, t1, None, None, None, None))


# ---------------------------------------------------------------------------
# Shared fault-policy enforcement — the ONE implementation of per-step
# timeout + retry + run deadline that every backend wires around its step
# fires (the same single-home pattern as the span helpers above), so the
# conformance suite can demand identical policy semantics from interpreters
# with wildly different architectures.
# ---------------------------------------------------------------------------


def call_with_timeout(fn: Callable[[], Any], timeout_s: float | None,
                      step: str) -> Any:
    """Run ``fn()`` bounded by ``timeout_s`` wall-clock seconds.

    The attempt runs on a fresh daemon thread; on overrun the thread is
    **abandoned** (not killed — Python cannot) and :class:`StepTimeoutError`
    is raised.  Abandonment is sound for SWIRL steps: they are pure, so a
    late-finishing orphan has no observable effect — its result is simply
    never read.
    """
    from .policy import StepTimeoutError

    if timeout_s is None:
        return fn()
    box: list[tuple[str, Any]] = []

    def target() -> None:
        try:
            box.append(("ok", fn()))
        except BaseException as e:  # noqa: BLE001 — re-raised on the caller
            box.append(("err", e))

    t = threading.Thread(target=target, daemon=True, name=f"step-{step}")
    t.start()
    t.join(timeout_s)
    if not box:
        raise StepTimeoutError(step, timeout_s)
    kind, value = box[0]
    if kind == "err":
        raise value
    return value


class StepGuard:
    """Wraps step fires with one :class:`FaultPolicy`'s timeout + retry.

    Thread-safe (location threads and speculation pools share one guard);
    counts outcomes for ``result.stats`` and invokes optional callbacks so
    backends can emit spans / protocol messages per retry or timeout.
    """

    __slots__ = ("policy", "retry", "retries", "timeouts",
                 "_on_retry", "_on_timeout", "_lock")

    def __init__(self, policy: FaultPolicy, *, rng: Any = None,
                 on_retry: Callable[[str, int, Exception], None] | None = None,
                 on_timeout: Callable[[str], None] | None = None):
        self.policy = policy
        self.retry = policy.retry_policy(rng)
        self.retries = 0
        self.timeouts = 0
        self._on_retry = on_retry
        self._on_timeout = on_timeout
        self._lock = threading.Lock()

    def fire(self, step: str, fn: Callable[[], Any]) -> Any:
        """Run one step body under the policy; raises what the policy lets
        escape (:class:`TransientError` after the retry budget,
        :class:`~repro.workflow.fault.PermanentError` immediately)."""
        from .policy import StepTimeoutError

        timeout_s = self.policy.timeout_s

        def attempt() -> Any:
            if timeout_s is None:
                return fn()
            try:
                return call_with_timeout(fn, timeout_s, step)
            except StepTimeoutError:
                with self._lock:
                    self.timeouts += 1
                if self._on_timeout is not None:
                    self._on_timeout(step)
                raise

        if self.retry is None:
            return attempt()

        def note(n: int, e: Exception) -> None:
            with self._lock:
                self.retries += 1
            if self._on_retry is not None:
                self._on_retry(step, n, e)

        return self.retry.run(attempt, on_retry=note)

    def counts(self) -> dict[str, int]:
        """Snapshot for ``result.stats["policy"]``."""
        with self._lock:
            return {"retries": self.retries, "timeouts": self.timeouts}


class Deadline:
    """Whole-run wall-clock budget; inert when ``deadline_s`` is ``None``."""

    __slots__ = ("deadline_s", "_t0", "_clock")

    def __init__(self, deadline_s: float | None,
                 clock: Callable[[], float] = time.monotonic):
        self.deadline_s = deadline_s
        self._clock = clock
        self._t0 = clock()

    def elapsed(self) -> float:
        return self._clock() - self._t0

    def remaining(self) -> float | None:
        """Seconds left (may be ≤ 0), or ``None`` when unbounded — feed it
        straight into a blocking wait's ``timeout=``."""
        if self.deadline_s is None:
            return None
        return self.deadline_s - self.elapsed()

    def expired(self) -> bool:
        return self.deadline_s is not None and self.elapsed() > self.deadline_s

    def check(self) -> None:
        if self.expired():
            from .policy import RunDeadlineExceeded

            raise RunDeadlineExceeded(self.deadline_s, elapsed_s=self.elapsed())
