"""Elastic recovery on the execution IR — renaming without a tree round-trip.

SWIRL's semantics is invariant under location renaming (names are opaque in
Figs. 2-3), and :mod:`repro.workflow.elastic` exploits that at the *tree*
level: rename the checkpointed term, re-encode, resume.  Every backend now
interprets the flat per-location :class:`~repro.exec.program.ExecProgram`,
so a live executor recovering from a dead worker should not detour through
tree reconstruction at all.  This module applies the same substitution
**directly on the op arrays**:

* :func:`rename_program` maps every ``SendOp``/``RecvOp`` endpoint, every
  ``ExecOp`` location set (canonicalised to a sorted, duplicate-free
  tuple), and re-elects every leader flag against the renamed ``M(s)``;
* a *surjective* renaming (fold — scale-down onto a survivor) merges the
  collapsed programs under one parallel root by splicing their flat
  skeletons, exactly what ``par`` does to the tree form, and is then
  normalised by :meth:`~repro.core.flat.FlatTrace.compact`;
* when a fold collapses several locations of one spatial step onto the
  same name, the synchronised occurrences become redundant copies at one
  location — all but the first are dropped.  That weakening is sound: it
  only *adds* interleavings the (L-PAR) congruence already allows, and
  every consumer of the step's outputs is guarded by data residency, not
  by control order.

The resume point is reconstructed from a coordinator-merged checkpoint:
``completed_execs`` says which step bodies must *never* re-run (they replay
recorded outputs instead), and :func:`repro.workflow.elastic.fold_payloads`
moves the payload store under the substitution with the deterministic
survivor-wins precedence.  The tree-level module stays in place as the
semantics oracle — ``rename_program(lower(w)).system`` must agree with
``rename_locations(w)`` — which is exactly what the property tests check.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Mapping

from repro.core.flat import OP_ACT, OP_NIL, OP_PAR, FlatTrace
from repro.core.syntax import Action, Exec, Recv, Send

from .program import (
    ExecOp,
    ExecProgram,
    LocationProgram,
    Op,
    RecvOp,
    SendOp,
    _resolve,
    to_action,
)

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.sched.simulate import Simulation

__all__ = ["rename_program", "resimulate"]


def _rename_op(op: Op, ren: Mapping[str, str], location: str) -> Op:
    """One op under the substitution, leadership re-elected."""
    r = ren.get
    if isinstance(op, ExecOp):
        locs = tuple(sorted({r(l, l) for l in op.locations}))
        return ExecOp(
            step=op.step,
            inputs=op.inputs,
            outputs=op.outputs,
            locations=locs,
            leader=location == locs[0],
        )
    if isinstance(op, SendOp):
        return SendOp(
            data=op.data, port=op.port, src=r(op.src, op.src),
            dst=r(op.dst, op.dst),
        )
    if isinstance(op, RecvOp):
        return RecvOp(port=op.port, src=r(op.src, op.src), dst=r(op.dst, op.dst))
    raise TypeError(f"not a program op: {op!r}")


def _rename_action(a: Action, ren: Mapping[str, str]) -> Action:
    """The action view of :func:`_rename_op` (for the fold/merge path)."""
    r = ren.get
    if isinstance(a, Exec):
        return Exec(
            step=a.step,
            inputs=a.inputs,
            outputs=a.outputs,
            locations=tuple(sorted({r(l, l) for l in a.locations})),
        )
    if isinstance(a, Send):
        return Send(
            data=a.data, port=a.port, src=r(a.src, a.src), dst=r(a.dst, a.dst)
        )
    if isinstance(a, Recv):
        return Recv(port=a.port, src=r(a.src, a.src), dst=r(a.dst, a.dst))
    raise TypeError(f"not an action: {a!r}")


def _is_empty(p: LocationProgram) -> bool:
    return not p.ops and all(code == OP_NIL for code, _ in p.structure)


def _merge_group(
    location: str, group: list[LocationProgram], ren: Mapping[str, str]
) -> LocationProgram:
    """Fold ≥2 collapsed programs onto one location, skeleton-spliced.

    The merged skeleton is one ``PAR`` over the member skeletons with the
    leaf slots re-based onto the concatenated action array — the flat
    analogue of ``par(prev.trace, new_trace)`` — then normalised by
    :meth:`FlatTrace.compact` (nested ``Par`` flattened, units dropped).
    Duplicate occurrences of one step (a spatial ``M(s)`` collapsing onto
    this location) keep only their first copy; see the module docstring
    for why that is sound.
    """
    members = [p for p in group if not _is_empty(p)]
    data = frozenset().union(*(p.data for p in group))
    if not members:
        return LocationProgram(
            location=location,
            data=data,
            structure=((OP_NIL, 0),),
            ops=(),
        )
    skeleton: list[tuple[int, int]] = [(OP_PAR, len(members))]
    actions: list[Action] = []
    for p in members:
        base = len(actions)
        skeleton.extend(
            (code, arg + base) if code == OP_ACT else (code, arg)
            for code, arg in p.structure
        )
        actions.extend(_rename_action(to_action(op), ren) for op in p.ops)
    alive = [True] * len(actions)
    seen_steps: set[str] = set()
    for i, a in enumerate(actions):
        if isinstance(a, Exec):
            if a.step in seen_steps:
                alive[i] = False
            else:
                seen_steps.add(a.step)
    flat = FlatTrace(skeleton, actions, alive).compact()
    return LocationProgram(
        location=location,
        data=data,
        structure=tuple(flat.ops),
        ops=tuple(_resolve(a, location) for a in flat.actions),
    )


def rename_program(
    program: ExecProgram, ren: Mapping[str, str]
) -> ExecProgram:
    """Apply a location substitution to a lowered program, in the arrays.

    Bijective renamings (dead → spare) rewrite each program's op array in
    place-shape — same skeleton, renamed endpoints, re-elected leaders.
    Surjective renamings (fold/scale-down) additionally merge the
    collapsed programs via :func:`_merge_group`.  The attached schedule
    report is dropped: its placement speaks the old location names (use
    :func:`resimulate` for a fresh prediction of the renamed plan).
    """
    groups: dict[str, list[LocationProgram]] = {}
    for p in program.programs:
        groups.setdefault(ren.get(p.location, p.location), []).append(p)
    renamed: list[LocationProgram] = []
    for location in sorted(groups):
        group = groups[location]
        if len(group) == 1:
            p = group[0]
            renamed.append(
                LocationProgram(
                    location=location,
                    data=p.data,
                    structure=p.structure,
                    ops=tuple(_rename_op(op, ren, location) for op in p.ops),
                )
            )
        else:
            renamed.append(_merge_group(location, group, ren))
    return ExecProgram(programs=tuple(renamed), schedule=None)


def resimulate(program: ExecProgram, **kwargs) -> "Simulation":
    """Re-simulate a (renamed) program against the scheduling cost model.

    Recovery changes the location set under a running plan, so any
    makespan the original :class:`~repro.sched.ScheduleReport` predicted
    is stale; this replays the renamed program's term through
    :func:`repro.sched.simulate.simulate` (uniform network unless given)
    so recovery events can report the folded plan's predicted cost.
    """
    from repro.sched.simulate import simulate

    return simulate(program.system, **kwargs)
