"""The uniform fault policy accepted by every backend's ``lower()``.

One frozen :class:`FaultPolicy` names everything a backend may do about a
misbehaving step or run — retry with capped exponential backoff + full
jitter, a per-step wall-clock ``timeout_s``, speculative re-execution of
stragglers, a heartbeat deadline for declaring remote locations dead, and
a whole-run ``deadline_s`` — and is passed as a lowering option::

    exe = plan.lower(backend, policy=FaultPolicy(max_retries=2,
                                                 timeout_s=5.0)).compile(steps)

All four backends honor it (each through the mechanism its architecture
affords — see the README's support matrix):

* ``inprocess`` — the policy constructs the runtime's existing
  :class:`~repro.workflow.fault.RetryPolicy` / ``SpeculationPolicy`` /
  ``HeartbeatMonitor`` engines and adds step timeouts + run deadline;
* ``threaded`` — per-step timeout + retry inside each location thread,
  plus crash recovery: a died location thread is replayed from its
  recorded op log (pure steps make the replay sound);
* ``multiprocess`` — worker-side retry; coordinator-side progress
  heartbeat that maps a silent straggler onto the ``WorkerFailedError``
  path so ``recover="spare"|"fold"`` fires without waiting for SIGKILL;
* ``jax`` — retry/timeout guard around each step fire, deadline per
  reduction round.

The soundness argument is the one :mod:`repro.workflow.fault` documents:
SWIRL steps are pure ``In^D(s) ↦ Out^D(s)`` functions, so re-execution
(retry, speculation, replay after a declared death) cannot corrupt data.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from repro.workflow.fault import (
    DEFAULT_HEARTBEAT_TIMEOUT_S,
    HeartbeatMonitor,
    RetryPolicy,
    SpeculationPolicy,
    TransientError,
)

__all__ = [
    "FaultPolicy",
    "RunDeadlineExceeded",
    "StepTimeoutError",
]


class StepTimeoutError(TransientError):
    """A step exceeded the policy's per-step ``timeout_s``.

    Subclasses :class:`TransientError` because a timed-out pure step is
    retryable by definition — the abandoned attempt cannot have corrupted
    anything the dataflow can observe.
    """

    def __init__(self, step: str, timeout_s: float):
        super().__init__(f"step {step!r} exceeded timeout {timeout_s}s")
        self.step = step
        self.timeout_s = timeout_s


class RunDeadlineExceeded(RuntimeError):
    """The whole run exceeded the policy's ``deadline_s``.

    Deliberately **not** transient: the deadline is the caller's patience,
    not a step fault, so no backend retries past it.  The gateway maps it
    to HTTP 504.
    """

    def __init__(self, deadline_s: float, *, elapsed_s: float | None = None):
        detail = f" (elapsed {elapsed_s:.3f}s)" if elapsed_s is not None else ""
        super().__init__(f"run exceeded deadline {deadline_s}s{detail}")
        self.deadline_s = deadline_s
        self.elapsed_s = elapsed_s


@dataclass(frozen=True)
class FaultPolicy:
    """Uniform per-run fault handling, backend-independent.

    Fields (all optional; the zero policy is a no-op):

    * ``max_retries`` — per-step retry budget for transient failures;
    * ``backoff_s`` / ``backoff_cap_s`` — base and cap of the capped
      exponential full-jitter backoff between retries;
    * ``timeout_s`` — per-step wall-clock limit; an overrun raises
      :class:`StepTimeoutError` (transient, so it consumes a retry);
    * ``speculation_factor`` — launch a backup copy of a step running
      longer than ``factor ×`` its expected duration (backends with a
      central pool and expected durations only);
    * ``max_speculative`` — backup copies per straggling step;
    * ``heartbeat_interval_s`` — how often liveness is (expected to be)
      reported;
    * ``heartbeat_timeout_s`` — silence after which a location/worker is
      declared dead and elastic recovery may fire;
    * ``deadline_s`` — whole-run wall-clock budget; an overrun raises
      :class:`RunDeadlineExceeded`.

    Frozen and picklable — it crosses process boundaries inside the
    multiprocess worker config verbatim.
    """

    max_retries: int = 0
    backoff_s: float = 0.0
    backoff_cap_s: float = 30.0
    timeout_s: float | None = None
    speculation_factor: float | None = None
    max_speculative: int = 1
    heartbeat_interval_s: float = 1.0
    heartbeat_timeout_s: float = DEFAULT_HEARTBEAT_TIMEOUT_S
    deadline_s: float | None = None

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_s < 0:
            raise ValueError(f"backoff_s must be >= 0, got {self.backoff_s}")
        if self.backoff_cap_s < 0:
            raise ValueError(
                f"backoff_cap_s must be >= 0, got {self.backoff_cap_s}"
            )
        for name in ("timeout_s", "speculation_factor", "deadline_s"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be > 0, got {v}")
        if self.max_speculative < 1:
            raise ValueError(
                f"max_speculative must be >= 1, got {self.max_speculative}"
            )
        for name in ("heartbeat_interval_s", "heartbeat_timeout_s"):
            if getattr(self, name) <= 0:
                raise ValueError(f"{name} must be > 0, got {getattr(self, name)}")

    # -- engine constructors --------------------------------------------------
    # The inprocess runtime's existing fault primitives become the policy's
    # engine; other backends reuse the same constructors so semantics (and
    # jitter determinism under an injected rng) match everywhere.

    def retry_policy(self, rng: Any = None) -> RetryPolicy | None:
        """A :class:`RetryPolicy` for this policy, or ``None`` when inert."""
        if self.max_retries <= 0:
            return None
        return RetryPolicy(
            max_retries=self.max_retries,
            backoff_s=self.backoff_s,
            backoff_cap_s=self.backoff_cap_s,
            rng=rng,
        )

    def speculation_policy(self) -> SpeculationPolicy | None:
        if self.speculation_factor is None:
            return None
        return SpeculationPolicy(
            enabled=True,
            factor=self.speculation_factor,
            max_speculative=self.max_speculative,
        )

    def heartbeat_monitor(self) -> HeartbeatMonitor:
        return HeartbeatMonitor(timeout_s=self.heartbeat_timeout_s)

    @property
    def active(self) -> bool:
        """Whether any mechanism is switched on (the zero policy is inert)."""
        return bool(
            self.max_retries
            or self.timeout_s is not None
            or self.speculation_factor is not None
            or self.deadline_s is not None
        )
