"""Per-location executable program IR + the lowering that produces it.

A :class:`LocationProgram` is the unit every backend interprets: the
location's predicates resolved into executable ops —

* :class:`SendOp` / :class:`RecvOp` with their ``(src, dst, port)`` channel
  endpoint resolved,
* :class:`ExecOp` with sorted input/output bindings, the full ``M(s)``
  membership and a pre-computed *leader* flag (the lexicographically first
  location of ``M(s)`` runs the step body; the others synchronise),

stored as a **program-order array** (``ops``) plus a flat preorder control
skeleton (``structure``, the opcodes of :mod:`repro.core.flat`) describing
how the ops compose sequentially/in parallel.  The IR is self-contained and
picklable — the multiprocess backend ships bare ``LocationProgram``s to its
workers — and lossless: :func:`to_action` reconstructs the exact source
predicate of every op, so :meth:`LocationProgram.to_trace` and
:meth:`ExecProgram.system` recover the SWIRL term (used by checkpointing,
which snapshots the *remaining* term by flipping done-flags).

Lowering (:func:`lower_system`) goes through the flat IR of
:mod:`repro.core.flat` — ``tree → FlatSystem → compact() → programs`` — so
it is linear in action count and never re-walks trees per backend.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Iterator, Mapping, Sequence, Union

from repro.core.flat import OP_ACT, OP_NIL, OP_PAR, OP_SEQ, FlatSystem, FlatTrace
from repro.core.syntax import (
    Action,
    Exec,
    LocationConfig,
    Recv,
    Send,
    WorkflowSystem,
)

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.sched.report import ScheduleReport

__all__ = [
    "Endpoint",
    "ExecOp",
    "SendOp",
    "RecvOp",
    "Op",
    "LocationProgram",
    "ExecProgram",
    "ControlSpec",
    "lower_system",
    "lower_flat",
    "to_action",
]

Endpoint = tuple[str, str, str]  # (src, dst, port)


# ---------------------------------------------------------------------------
# Ops — resolved SEND / RECV / EXEC instructions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SendOp:
    """``send(d ↣ p, l, l')`` with its channel endpoint resolved."""

    data: str
    port: str
    src: str
    dst: str

    @property
    def endpoint(self) -> Endpoint:
        return (self.src, self.dst, self.port)

    @property
    def is_local(self) -> bool:
        return self.src == self.dst


@dataclass(frozen=True)
class RecvOp:
    """``recv(p, l, l')`` with its channel endpoint resolved."""

    port: str
    src: str
    dst: str

    @property
    def endpoint(self) -> Endpoint:
        return (self.src, self.dst, self.port)

    @property
    def is_local(self) -> bool:
        return self.src == self.dst


@dataclass(frozen=True)
class ExecOp:
    """``exec(s, F(s), M(s))`` with bindings and leadership resolved.

    ``inputs``/``outputs`` are sorted tuples (deterministic binding order
    for interpreters and emitted source); ``locations`` keeps the source
    predicate's ``M(s)`` tuple verbatim so :func:`to_action` is exact.
    ``leader`` is true on the location whose program this op belongs to iff
    that location is the lexicographically first of ``M(s)`` — the one that
    runs the step body under the (EXEC) rule's synchronised reduction.
    """

    step: str
    inputs: tuple[str, ...]
    outputs: tuple[str, ...]
    locations: tuple[str, ...]
    leader: bool

    @property
    def is_spatial(self) -> bool:
        return len(self.locations) > 1


Op = Union[ExecOp, SendOp, RecvOp]


def to_action(op: Op) -> Action:
    """Reconstruct the exact source predicate of ``op``."""
    if isinstance(op, ExecOp):
        return Exec(
            step=op.step,
            inputs=frozenset(op.inputs),
            outputs=frozenset(op.outputs),
            locations=op.locations,
        )
    if isinstance(op, SendOp):
        return Send(data=op.data, port=op.port, src=op.src, dst=op.dst)
    if isinstance(op, RecvOp):
        return Recv(port=op.port, src=op.src, dst=op.dst)
    raise TypeError(f"not a program op: {op!r}")


def _resolve(action: Action, location: str) -> Op:
    if isinstance(action, Exec):
        return ExecOp(
            step=action.step,
            inputs=tuple(sorted(action.inputs)),
            outputs=tuple(sorted(action.outputs)),
            locations=action.locations,
            leader=location == min(action.locations),
        )
    if isinstance(action, Send):
        return SendOp(
            data=action.data, port=action.port, src=action.src, dst=action.dst
        )
    if isinstance(action, Recv):
        return RecvOp(port=action.port, src=action.src, dst=action.dst)
    raise TypeError(f"not an action: {action!r}")


# ---------------------------------------------------------------------------
# Control skeleton — parsed once per program, shared by every interpreter
# ---------------------------------------------------------------------------

K_ACT = 0
K_SEQ = 1
K_PAR = 2


@dataclass(frozen=True)
class ControlSpec:
    """Immutable node table over one program's control skeleton.

    ``kind[n]``/``children[n]``/``parent[n]`` describe node ``n``;
    ``instr[n]`` is the op index of an ``K_ACT`` leaf (−1 otherwise) and
    ``leaf_node[i]`` the node id of op ``i``.  ``root`` is ``None`` for an
    empty program.  :class:`~repro.exec.interp.Cursor` layers mutable
    per-run state on top; the threaded interpreter recurses over it.
    """

    kind: tuple[int, ...]
    children: tuple[tuple[int, ...], ...]
    parent: tuple[int, ...]
    instr: tuple[int, ...]
    leaf_node: tuple[int, ...]
    root: int | None


def _parse_control(
    structure: Sequence[tuple[int, int]], n_ops: int
) -> ControlSpec:
    kind: list[int] = []
    children: list[tuple[int, ...]] = []
    parent: list[int] = []
    instr: list[int] = []
    leaf_node: list[int] = [-1] * n_ops

    def build(pos: int) -> tuple[int | None, int]:
        code, arg = structure[pos]
        pos += 1
        if code == OP_NIL:
            return None, pos
        nid = len(kind)
        kind.append(K_ACT if code == OP_ACT else K_SEQ if code == OP_SEQ else K_PAR)
        children.append(())
        parent.append(-1)
        instr.append(-1)
        if code == OP_ACT:
            instr[nid] = arg
            leaf_node[arg] = nid
            return nid, pos
        if code not in (OP_SEQ, OP_PAR):
            raise ValueError(f"unknown structure opcode {code}")
        kids: list[int] = []
        for _ in range(arg):
            child, pos = build(pos)
            if child is not None:
                kids.append(child)
                parent[child] = nid
        children[nid] = tuple(kids)
        return nid, pos

    root, end = build(0)
    if end != len(structure):
        raise ValueError("trailing structure ops — corrupt program skeleton")
    return ControlSpec(
        kind=tuple(kind),
        children=tuple(children),
        parent=tuple(parent),
        instr=tuple(instr),
        leaf_node=tuple(leaf_node),
        root=root,
    )


# ---------------------------------------------------------------------------
# LocationProgram / ExecProgram
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LocationProgram:
    """One location's executable program: op array + control skeleton."""

    location: str
    data: frozenset[str]
    structure: tuple[tuple[int, int], ...]
    ops: tuple[Op, ...]

    def control(self) -> ControlSpec:
        spec = self.__dict__.get("_control")
        if spec is None:
            spec = _parse_control(self.structure, len(self.ops))
            self.__dict__["_control"] = spec
        return spec

    def inline_send_branches(self) -> Mapping[int, frozenset[int]]:
        """Per ``Par`` node: branches provably safe to run inline-first.

        A branch qualifies when every op under it is a :class:`SendOp`
        whose datum is *statically available* before the ``Par`` starts —
        initial data or an output of an exec completed earlier in program
        order.  Such a branch never blocks on local progress (its
        ``_wait_data`` is already satisfied and transport acceptance does
        not depend on the peer's workflow progress), so interpreting it
        sequentially before the blocking branches is one of the schedules
        the (L-PAR) congruence already allows — no thread needed.

        Keys are control-node ids of ``Par`` nodes with at least one safe
        branch; values are the safe child node ids.  Cached per program.
        """
        cached = self.__dict__.get("_inline_sends")
        if cached is not None:
            return cached
        spec = self.control()
        ops = self.ops
        result: dict[int, frozenset[int]] = {}

        def produced(nid: int) -> set[str]:
            if spec.kind[nid] == K_ACT:
                op = ops[spec.instr[nid]]
                if isinstance(op, ExecOp):
                    return set(op.outputs)
                return set()  # a recv's datum name is not known statically
            out: set[str] = set()
            for child in spec.children[nid]:
                out |= produced(child)
            return out

        def send_only(nid: int, avail: frozenset[str]) -> bool:
            if spec.kind[nid] == K_ACT:
                op = ops[spec.instr[nid]]
                return isinstance(op, SendOp) and op.data in avail
            return all(
                send_only(child, avail) for child in spec.children[nid]
            )

        def visit(nid: int, avail: frozenset[str]) -> None:
            kind = spec.kind[nid]
            if kind == K_ACT:
                return
            if kind == K_SEQ:
                for child in spec.children[nid]:
                    visit(child, avail)
                    avail = avail | frozenset(produced(child))
                return
            safe = frozenset(
                child
                for child in spec.children[nid]
                if send_only(child, avail)
            )
            if safe:
                result[nid] = safe
            for child in spec.children[nid]:
                visit(child, avail)

        if spec.root is not None:
            visit(spec.root, frozenset(self.data))
        self.__dict__["_inline_sends"] = result
        return result

    # -- views --------------------------------------------------------------
    def exec_ops(self) -> Iterator[ExecOp]:
        for op in self.ops:
            if isinstance(op, ExecOp):
                yield op

    def exec_step_names(self) -> tuple[str, ...]:
        return tuple(op.step for op in self.exec_ops())

    def channels(self) -> tuple[Endpoint, ...]:
        """Every channel endpoint this program communicates over, sorted."""
        return tuple(
            sorted(
                {
                    op.endpoint
                    for op in self.ops
                    if isinstance(op, (SendOp, RecvOp))
                }
            )
        )

    # -- bridges back to the syntax layer ------------------------------------
    def to_trace(self):
        """The SWIRL trace this program lowers (normal-form reconstruction)."""
        return FlatTrace(
            list(self.structure), [to_action(op) for op in self.ops]
        ).rebuild()

    def __len__(self) -> int:
        return len(self.ops)


@dataclass(frozen=True, eq=False)
class ExecProgram:
    """A whole lowered system: one :class:`LocationProgram` per location.

    Carries the placement/schedule metadata resolved at lowering time
    (``schedule`` is the :class:`~repro.sched.ScheduleReport` when the plan
    went through the placement scheduler).  Compile once, interpret — and
    with :meth:`repro.api.Executable.run_many`, run — many times.
    """

    programs: tuple[LocationProgram, ...]
    schedule: "ScheduleReport | None" = field(default=None, repr=False)

    def __post_init__(self) -> None:
        names = [p.location for p in self.programs]
        if len(names) != len(set(names)):
            raise ValueError(f"duplicate location program: {names}")

    # -- accessors ----------------------------------------------------------
    @property
    def by_location(self) -> Mapping[str, LocationProgram]:
        cached = self.__dict__.get("_by_location")
        if cached is None:
            cached = {p.location: p for p in self.programs}
            self.__dict__["_by_location"] = cached
        return cached

    def __getitem__(self, location: str) -> LocationProgram:
        return self.by_location[location]

    def locations(self) -> tuple[str, ...]:
        return tuple(p.location for p in self.programs)

    def placement(self) -> dict[str, tuple[str, ...]]:
        """Step → ``M(s)`` as resolved in the program ops."""
        cached = self.__dict__.get("_placement")
        if cached is None:
            cached = {}
            for p in self.programs:
                for op in p.exec_ops():
                    cached[op.step] = tuple(sorted(op.locations))
            self.__dict__["_placement"] = cached
        return dict(cached)

    def step_names(self) -> frozenset[str]:
        return frozenset(self.placement())

    def channels(self) -> tuple[Endpoint, ...]:
        return tuple(sorted({ep for p in self.programs for ep in p.channels()}))

    def total_ops(self) -> int:
        return sum(len(p) for p in self.programs)

    # -- syntax bridge -------------------------------------------------------
    @property
    def system(self) -> WorkflowSystem:
        """The SWIRL system this program lowers (cached reconstruction)."""
        cached = self.__dict__.get("_system")
        if cached is None:
            cached = WorkflowSystem(
                tuple(
                    LocationConfig(p.location, p.data, p.to_trace())
                    for p in self.programs
                )
            )
            self.__dict__["_system"] = cached
        return cached

    def remaining_system(
        self,
        done: Mapping[str, Sequence[bool]],
        data: Mapping[str, frozenset[str]] | None = None,
    ) -> WorkflowSystem:
        """The SWIRL term left after the ``done`` ops were consumed.

        ``done[location][i]`` marks op ``i`` of that location's program as
        executed; ``data`` optionally overrides each location's (grown)
        data scope.  This is what makes program-IR checkpoints speak the
        same language as the reduction runtime: the remaining term *is* the
        program counter.
        """
        configs = []
        for p in self.programs:
            flags = done.get(p.location)
            alive = (
                [True] * len(p.ops)
                if flags is None
                else [not f for f in flags]
            )
            trace = FlatTrace(
                list(p.structure),
                [to_action(op) for op in p.ops],
                alive,
            ).rebuild()
            scope = (data or {}).get(p.location, p.data)
            configs.append(LocationConfig(p.location, scope, trace))
        return WorkflowSystem(tuple(configs))


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def lower_flat(
    fs: FlatSystem,
    *,
    schedule: "ScheduleReport | None" = None,
    system: WorkflowSystem | None = None,
) -> ExecProgram:
    """Lower a (possibly rewritten-in-place) :class:`FlatSystem`.

    Dead slots are dropped and the skeleton normalised by
    :meth:`FlatTrace.compact`; no tree is ever rebuilt on this path.  When
    the originating ``system`` is known, it seeds the program's cached
    ``.system`` so checkpoint paths skip the reconstruction.
    """
    programs = []
    for cfg in fs.configs:
        flat = cfg.trace.compact()
        programs.append(
            LocationProgram(
                location=cfg.location,
                data=cfg.data,
                structure=tuple(flat.ops),
                ops=tuple(_resolve(a, cfg.location) for a in flat.actions),
            )
        )
    program = ExecProgram(programs=tuple(programs), schedule=schedule)
    if system is not None:
        program.__dict__["_system"] = system
    return program


def lower_system(
    system: WorkflowSystem, *, schedule: "ScheduleReport | None" = None
) -> ExecProgram:
    """Lower a workflow system to per-location executable programs."""
    return lower_flat(
        FlatSystem.from_system(system), schedule=schedule, system=system
    )


def ensure_program(
    source: "ExecProgram | WorkflowSystem", *, schedule: Any = None
) -> ExecProgram:
    """Coerce a backend ``compile`` source into an :class:`ExecProgram`.

    The staged pipeline always hands backends an already-lowered program;
    a bare :class:`WorkflowSystem` (legacy callers, third-party backends
    written against the PR-1 signature) is lowered here.
    """
    if isinstance(source, ExecProgram):
        return source
    if isinstance(source, WorkflowSystem):
        sched = schedule if _is_schedule(schedule) else None
        return lower_system(source, schedule=sched)
    raise TypeError(
        f"cannot lower {type(source).__name__}; expected an ExecProgram "
        "or a WorkflowSystem"
    )


def _is_schedule(obj: Any) -> bool:
    return obj is not None and hasattr(obj, "placement") and hasattr(obj, "network")
