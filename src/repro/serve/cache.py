"""The content-addressed service cache: fingerprint → compiled Executable.

The service-level extension of the :mod:`repro.api` derive cache: where
that LRU absorbs re-derivations *within* one plan's schedule/lower chain,
this one makes whole compiled artifacts addressable *across* submissions —
``submit`` once, then every ``run`` against the returned
:meth:`~repro.api.Plan.fingerprint` skips trace/optimize/lower/compile
entirely.  Two levels of addressing:

* **source digest** — SHA-256 of the canonical submission body.  A
  resubmission of byte-identical source is a cache hit without even
  parsing the workflow.
* **fingerprint** — :meth:`Plan.fingerprint`, the content address of the
  compiled plan.  Different sources that compile to the same plan (e.g. a
  DAG-JSON and the ``.swirl`` text of its encoding) converge on one entry;
  every source digest that led to an entry is kept as an alias and evicted
  with it.

Thread-safe; eviction is LRU on the fingerprint level with hit / miss /
eviction counters exposed via :meth:`PlanCache.stats` (served by the
gateway's ``GET /v1/stats``).
"""

from __future__ import annotations

import logging
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any

from repro.api import Executable, Plan

__all__ = ["CacheEntry", "PlanCache"]

logger = logging.getLogger("repro.serve.cache")


@dataclass
class CacheEntry:
    """One compiled workflow held by the service cache."""

    fingerprint: str
    plan: Plan
    executable: Executable
    meta: dict[str, Any] = field(default_factory=dict)
    compile_seconds: float = 0.0
    created_unix: float = field(default_factory=time.time)
    #: Serialises whole runs when the backend's compiled program does not
    #: support overlapping batches (e.g. ``inprocess``); the threaded
    #: backend never takes it.
    run_lock: threading.Lock = field(default_factory=threading.Lock)
    hits: int = 0

    def summary(self) -> dict[str, Any]:
        return {
            "fingerprint": self.fingerprint,
            "steps": list(self.plan.steps()),
            "locations": sorted(self.plan.system.locations()),
            "actions": self.plan.system.total_actions(),
            "communications": self.plan.system.comm_count(),
            "compile_seconds": round(self.compile_seconds, 6),
            "hits": self.hits,
            **self.meta,
        }


class PlanCache:
    """Bounded LRU of :class:`CacheEntry`, addressed two ways (see module).

    ``capacity`` bounds the number of *compiled plans* held live (each
    entry pins a lowered program and a backend artifact); least recently
    *used* (submitted to or run against) is evicted first.
    """

    def __init__(self, capacity: int = 128):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._entries: "OrderedDict[str, CacheEntry]" = OrderedDict()
        self._by_source: dict[str, str] = {}  # source digest → fingerprint
        self._aliases: dict[str, set[str]] = {}  # fingerprint → digests
        self._stats = {
            "hits": 0,
            "misses": 0,
            "evictions": 0,
            "compile_seconds_saved": 0.0,
        }

    # -- lookups -------------------------------------------------------------
    def get(self, fingerprint: str) -> CacheEntry | None:
        """Entry for ``fingerprint``, counting the hit/miss."""
        with self._lock:
            entry = self._entries.get(fingerprint)
            if entry is None:
                self._stats["misses"] += 1
                return None
            self._entries.move_to_end(fingerprint)
            self._stats["hits"] += 1
            self._stats["compile_seconds_saved"] += entry.compile_seconds
            entry.hits += 1
            return entry

    def peek(self, fingerprint: str) -> CacheEntry | None:
        """Entry for ``fingerprint`` without touching LRU order or stats."""
        with self._lock:
            return self._entries.get(fingerprint)

    def lookup_source(self, source_digest: str) -> CacheEntry | None:
        """Entry previously compiled from this exact source, if any."""
        with self._lock:
            fp = self._by_source.get(source_digest)
            entry = self._entries.get(fp) if fp is not None else None
            if entry is None:
                self._stats["misses"] += 1
                return None
            self._entries.move_to_end(entry.fingerprint)
            self._stats["hits"] += 1
            self._stats["compile_seconds_saved"] += entry.compile_seconds
            entry.hits += 1
            return entry

    # -- insertion -----------------------------------------------------------
    def put(
        self, entry: CacheEntry, *, source_digest: str | None = None
    ) -> CacheEntry:
        """Insert ``entry`` (or alias onto an existing equal fingerprint).

        Returns the entry actually cached — when another source already
        compiled to the same fingerprint, the existing artifact wins and
        the new digest becomes an alias for it.
        """
        with self._lock:
            existing = self._entries.get(entry.fingerprint)
            if existing is not None:
                self._entries.move_to_end(entry.fingerprint)
                entry = existing
            else:
                self._entries[entry.fingerprint] = entry
                while len(self._entries) > self.capacity:
                    fp, _ = self._entries.popitem(last=False)
                    for digest in self._aliases.pop(fp, ()):
                        self._by_source.pop(digest, None)
                    self._stats["evictions"] += 1
                    logger.info("evicted %s (LRU, capacity %d)",
                                fp[:12], self.capacity)
            if source_digest is not None:
                self._by_source[source_digest] = entry.fingerprint
                self._aliases.setdefault(entry.fingerprint, set()).add(
                    source_digest
                )
            return entry

    # -- maintenance ---------------------------------------------------------
    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._by_source.clear()
            self._aliases.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def fingerprints(self) -> list[str]:
        with self._lock:
            return list(self._entries)

    def stats(self) -> dict[str, Any]:
        with self._lock:
            total = self._stats["hits"] + self._stats["misses"]
            return {
                **{
                    k: (round(v, 6) if isinstance(v, float) else v)
                    for k, v in self._stats.items()
                },
                "entries": len(self._entries),
                "capacity": self.capacity,
                "hit_rate": (
                    round(self._stats["hits"] / total, 4) if total else 0.0
                ),
            }
