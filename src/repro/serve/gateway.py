"""The HTTP front door — stdlib ``ThreadingHTTPServer`` over the service.

Endpoints (all JSON; auth via the ``X-API-Key`` header, resolved to a
tenant by the admission controller):

=========================================  =================================
``POST /v1/workflows``                     submit DAG-JSON / ``.swirl`` →
                                           ``{fingerprint, cached,
                                           timings_ms, ...}``
``GET  /v1/workflows/{fp}``                plan metadata + ``explain()``
``POST /v1/workflows/{fp}/run``            one instance: ``{"inputs":
                                           {"loc:datum": v}}`` → ``{data}``
``POST /v1/workflows/{fp}/run_many``       batch: ``{"inputs": [...]}`` →
                                           ``{results: [...]}`` through the
                                           backend's run_many lanes
``GET  /v1/stats``                         cache / admission / throughput
``GET  /v1/healthz``                       liveness + drain state +
                                           per-tenant queue depths (no auth)
``GET  /v1/metrics``                       Prometheus text exposition
                                           (no auth)
=========================================  =================================

Observability: every request carries a **trace id** — the caller's
``X-Trace-Id`` header when present, otherwise a generated one — echoed in
the response's ``X-Trace-Id`` header, embedded in every error body, bound
to :data:`repro.obs.events.current_trace_id` for the request's duration,
and attached to the ``repro.serve.gateway`` log records.  Request counts
and latency histograms accumulate in the gateway's
:class:`~repro.obs.metrics.MetricsRegistry`; ``GET /v1/metrics`` merges
them with a scrape-time snapshot of ``WorkflowService.stats()``
(plan-cache hit rate, per-tenant queue depth and rejection counts).

Error contract: every failure is a JSON body ``{"error": {...}}`` — never
a traceback.  ``400`` malformed submission (typed, with line/column for
``.swirl`` syntax errors), ``401`` unknown API key, ``404`` unknown
fingerprint, ``413`` request body over the gateway's ``max_body_bytes``
(typed ``BodyTooLarge`` with the limit and the declared length; the body
is rejected *unread*, so the response also closes the connection), ``429``
quota exhausted (with ``Retry-After``), ``503`` draining, ``504`` run
deadline exceeded (typed ``DeadlineExceeded``; the run is abandoned and
its admission slot released — set a deadline per request with
``"deadline_s"`` in the run/run_many body or the ``X-Deadline-S``
header, body winning when both are present).  HTTP/1.1 with
correct ``Content-Length``, so client connections stay alive across
requests (which is what makes cache-hit serving fast enough to
benchmark).

The server itself is deliberately boring: one thread per connection
(``ThreadingHTTPServer``), all real behaviour lives in
:class:`~repro.serve.service.WorkflowService`.  Shutdown is graceful —
:meth:`Gateway.close` flips the service into draining mode (new work →
``503``/``429``), waits for admitted work to finish, then stops the
accept loop.
"""

from __future__ import annotations

import json
import logging
import re
import threading
import time
import uuid
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any

import numpy as np

from repro.obs.events import current_trace_id
from repro.obs.metrics import MetricsRegistry
from repro.serve.admission import AdmissionRejected, UnknownTenantError
from repro.serve.service import (
    DeadlineExceeded,
    ServiceDraining,
    UnknownWorkflowError,
    WorkflowService,
)
from repro.serve.submission import SubmissionError

__all__ = ["BodyTooLarge", "DEFAULT_MAX_BODY_BYTES", "Gateway"]

logger = logging.getLogger("repro.serve.gateway")

#: Default request-body cap.  Submissions and payloads whose declared
#: ``Content-Length`` exceeds the gateway's ``max_body_bytes`` are
#: rejected with a 413 *before a single body byte is read* — the cap runs
#: ahead of auth and admission, so an oversized request can never buffer
#: unbounded memory.  Per-gateway override via ``Gateway(max_body_bytes=…)``.
DEFAULT_MAX_BODY_BYTES = 8 * 1024 * 1024


class BodyTooLarge(ValueError):
    """A request body over the gateway's cap — mapped to HTTP 413."""

    def __init__(self, content_length: int, limit: int):
        super().__init__(
            f"request body of {content_length} bytes exceeds the gateway's "
            f"{limit}-byte limit"
        )
        self.content_length = content_length
        self.limit = limit

    def to_json(self) -> dict[str, Any]:
        return {
            "type": "BodyTooLarge",
            "message": str(self),
            "limit_bytes": self.limit,
            "content_length": self.content_length,
        }

_ROUTES = {
    ("POST", re.compile(r"/v1/workflows\Z")): "submit",
    ("GET", re.compile(r"/v1/workflows/(?P<fp>[0-9a-f]{64})\Z")): "describe",
    ("POST", re.compile(r"/v1/workflows/(?P<fp>[0-9a-f]{64})/run\Z")): "run",
    (
        "POST",
        re.compile(r"/v1/workflows/(?P<fp>[0-9a-f]{64})/run_many\Z"),
    ): "run_many",
    ("GET", re.compile(r"/v1/stats\Z")): "stats",
    ("GET", re.compile(r"/v1/healthz\Z")): "healthz",
    ("GET", re.compile(r"/v1/metrics\Z")): "metrics",
}


def _jsonable(obj: Any) -> Any:
    """JSON fallback for payload values (numpy first, then ``str``)."""
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, (set, frozenset)):
        return sorted(obj)
    return str(obj)


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "swirl-gateway/0.1"

    # -- plumbing -------------------------------------------------------------
    #: Set per request by ``_dispatch``; read back for metrics / logging.
    _trace_id = ""
    _last_status = 0

    def log_message(self, fmt: str, *args: Any) -> None:
        pass  # request logging goes through the module logger instead

    @property
    def gateway(self) -> "Gateway":
        return self.server.gateway  # type: ignore[attr-defined]

    def _send_payload(
        self,
        status: int,
        payload: bytes,
        *,
        content_type: str,
        headers: dict[str, str] | None = None,
    ) -> None:
        self._last_status = status
        self.send_response(status)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(payload)))
        if self._trace_id:
            self.send_header("X-Trace-Id", self._trace_id)
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(payload)

    def _reply(
        self,
        status: int,
        body: dict[str, Any],
        *,
        headers: dict[str, str] | None = None,
    ) -> None:
        payload = json.dumps(body, default=_jsonable).encode()
        self._send_payload(
            status, payload, content_type="application/json", headers=headers
        )

    def _reply_text(
        self,
        status: int,
        text: str,
        *,
        content_type: str = "text/plain; charset=utf-8",
        headers: dict[str, str] | None = None,
    ) -> None:
        self._send_payload(
            status, text.encode(), content_type=content_type, headers=headers
        )

    def _error(
        self,
        status: int,
        error: dict[str, Any],
        *,
        headers: dict[str, str] | None = None,
    ) -> None:
        if self._trace_id:
            error = {**error, "trace_id": self._trace_id}
        self._reply(status, {"error": error}, headers=headers)

    def _deadline_of(self, body: dict[str, Any]) -> Any:
        """The request's deadline: body ``deadline_s``, else the
        ``X-Deadline-S`` header (body wins).  Returned raw — the service
        validates and maps garbage to a typed 400."""
        if "deadline_s" in body:
            return body["deadline_s"]
        header = (self.headers.get("X-Deadline-S") or "").strip()
        return header or None

    def _read_body(self) -> Any:
        length = int(self.headers.get("Content-Length") or 0)
        limit = self.gateway.max_body_bytes
        if length > limit:
            raise BodyTooLarge(length, limit)
        raw = self.rfile.read(length) if length else b""
        ctype = (self.headers.get("Content-Type") or "").split(";")[0].strip()
        if ctype in ("text/plain", "application/x-swirl"):
            return raw.decode("utf-8", errors="replace")
        if not raw:
            return None
        try:
            return json.loads(raw)
        except json.JSONDecodeError as e:
            raise SubmissionError(
                f"request body is not valid JSON: {e}",
                kind="json",
                line=e.lineno,
                column=e.colno,
            ) from e

    # -- dispatch -------------------------------------------------------------
    def do_GET(self) -> None:  # noqa: N802 (http.server naming)
        self._dispatch("GET")

    def do_POST(self) -> None:  # noqa: N802
        self._dispatch("POST")

    def _dispatch(self, method: str) -> None:
        path = self.path.split("?", 1)[0]
        self._trace_id = (
            (self.headers.get("X-Trace-Id") or "").strip()
            or uuid.uuid4().hex[:16]
        )
        self._last_status = 0
        token = current_trace_id.set(self._trace_id)
        route = "unmatched"
        t0 = time.perf_counter()
        try:
            for (m, pattern), name in _ROUTES.items():
                if m != method:
                    continue
                match = pattern.match(path)
                if match:
                    route = name
                    self._handle(name, match.groupdict())
                    return
            self._error(
                404,
                {
                    "type": "NotFound",
                    "message": f"no route {method} {path}",
                    "routes": sorted(
                        f"{m} {p.pattern}" for (m, p) in _ROUTES
                    ),
                },
            )
        finally:
            current_trace_id.reset(token)
            elapsed = time.perf_counter() - t0
            self.gateway.observe_request(
                route, method, self._last_status, elapsed
            )
            logger.info(
                "%s %s -> %d in %.3fms [trace_id=%s]",
                method,
                path,
                self._last_status,
                elapsed * 1e3,
                self._trace_id,
            )

    def _handle(self, name: str, params: dict[str, str]) -> None:
        service = self.gateway.service
        if name == "healthz":
            # Unauthenticated on purpose: load balancers poll this to
            # drain-aware route, so it must never require a tenant key.
            draining = service.admission.draining
            self._reply(
                200,
                {
                    "status": "draining" if draining else "ok",
                    "draining": draining,
                    "tenants": service.admission.queue_depths(),
                },
            )
            return
        if name == "metrics":
            # Also unauthenticated — the Prometheus scrape convention.
            self._reply_text(
                200,
                self.gateway.render_metrics(),
                content_type=MetricsRegistry.CONTENT_TYPE,
            )
            return
        try:
            tenant = service.admission.authenticate(
                self.headers.get("X-API-Key", "")
            )
        except UnknownTenantError:
            self._error(
                401,
                {
                    "type": "Unauthorized",
                    "message": "unknown API key (set the X-API-Key header)",
                },
            )
            return
        try:
            if name == "submit":
                self._reply(200, service.submit(self._read_body()))
            elif name == "describe":
                self._reply(200, service.describe(params["fp"]))
            elif name == "run":
                body = self._read_body() or {}
                if not isinstance(body, dict):
                    raise SubmissionError(
                        "run body must be a JSON object", kind="inputs"
                    )
                self._reply(
                    200,
                    service.run(
                        params["fp"],
                        body.get("inputs"),
                        tenant=tenant,
                        deadline_s=self._deadline_of(body),
                    ),
                )
            elif name == "run_many":
                body = self._read_body() or {}
                if not isinstance(body, dict) or "inputs" not in body:
                    raise SubmissionError(
                        "run_many body must be a JSON object with 'inputs' "
                        "(a list, one entry per instance)",
                        kind="inputs",
                    )
                self._reply(
                    200,
                    service.run_many(
                        params["fp"],
                        body["inputs"],
                        tenant=tenant,
                        max_concurrent=body.get("max_concurrent"),
                        deadline_s=self._deadline_of(body),
                    ),
                )
            elif name == "stats":
                self._reply(200, service.stats())
        except BodyTooLarge as e:
            # The oversized body was never read off the socket, so the
            # connection cannot be reused for a next request — close it.
            self.close_connection = True
            self._error(413, e.to_json(), headers={"Connection": "close"})
        except SubmissionError as e:
            self._error(400, e.to_json())
        except UnknownWorkflowError as e:
            self._error(
                404,
                {
                    "type": "UnknownWorkflow",
                    "message": (
                        f"no cached workflow {e.fingerprint!r}; submit it "
                        "first (POST /v1/workflows)"
                    ),
                },
            )
        except AdmissionRejected as e:
            if e.reason == "draining":
                self._error(
                    503,
                    {"type": "Draining", "message": str(e)},
                    headers={"Retry-After": str(e.retry_after)},
                )
            else:
                service.record_rejection()
                self._error(
                    429,
                    {
                        "type": "AdmissionRejected",
                        "message": str(e),
                        "tenant": e.tenant,
                        "reason": e.reason,
                        "retry_after": e.retry_after,
                    },
                    headers={"Retry-After": str(e.retry_after)},
                )
        except ServiceDraining as e:
            self._error(
                503,
                {"type": "Draining", "message": str(e)},
                headers={"Retry-After": "1"},
            )
        except DeadlineExceeded as e:
            self._error(504, e.to_json())
        except BrokenPipeError:
            raise  # client went away mid-reply; nothing to report to it
        except Exception as e:  # noqa: BLE001 — the no-traceback contract
            logger.exception(
                "unhandled %s in %s [trace_id=%s]",
                type(e).__name__,
                name,
                self._trace_id,
            )
            self._error(
                500,
                {"type": type(e).__name__, "message": str(e)},
            )


class Gateway:
    """Own one HTTP server around a :class:`WorkflowService`.

    ``port=0`` (the default) binds an ephemeral port — read
    :attr:`Gateway.url` after construction.  Use as a context manager or
    call :meth:`start` / :meth:`close` explicitly; :meth:`close` drains
    admitted work before stopping the accept loop, so in-flight
    executions are never dropped.
    """

    def __init__(
        self,
        service: WorkflowService,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        max_body_bytes: int = DEFAULT_MAX_BODY_BYTES,
    ):
        if max_body_bytes < 1:
            raise ValueError(
                f"max_body_bytes must be >= 1, got {max_body_bytes}"
            )
        self.max_body_bytes = max_body_bytes
        self.service = service
        self.metrics = MetricsRegistry()
        self._requests = self.metrics.counter(
            "gateway_requests_total",
            "HTTP requests handled, by route / method / status.",
        )
        self._latency = self.metrics.histogram(
            "gateway_request_seconds",
            "Wall-clock request latency in seconds, by route.",
        )
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd.gateway = self  # type: ignore[attr-defined]
        self._thread: threading.Thread | None = None

    # -- addresses ------------------------------------------------------------
    @property
    def address(self) -> tuple[str, int]:
        host, port = self._httpd.server_address[:2]
        return str(host), int(port)

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}"

    # -- observability ---------------------------------------------------------
    def observe_request(
        self, route: str, method: str, status: int, seconds: float
    ) -> None:
        """Handler hook: record one finished request in the registry."""
        self._requests.inc(route=route, method=method, status=str(status))
        self._latency.observe(seconds, route=route)

    def render_metrics(self) -> str:
        """Prometheus text page: request metrics + a service snapshot.

        Snapshot-sourced families (cache, admission, counters) are set
        absolutely at scrape time from :meth:`WorkflowService.stats`, so
        the service keeps its single source of truth and the exposition
        never drifts from ``GET /v1/stats``.
        """
        stats = self.service.stats()
        m = self.metrics
        m.gauge(
            "gateway_uptime_seconds", "Seconds since the service started."
        ).set(stats["uptime_s"])
        counters = m.counter(
            "service_operations_total",
            "Service-level operation counters, by kind.",
        )
        for kind, value in stats["counters"].items():
            counters.set(value, kind=kind)
        cache = stats["cache"]
        for key in ("hits", "misses", "evictions"):
            m.counter(
                f"plan_cache_{key}_total", f"Plan-cache {key}."
            ).set(cache.get(key, 0))
        m.gauge(
            "plan_cache_hit_rate", "Plan-cache hit rate over its lifetime."
        ).set(cache.get("hit_rate", 0.0))
        m.gauge("plan_cache_entries", "Compiled plans resident.").set(
            cache.get("entries", 0)
        )
        m.gauge(
            "plan_cache_compile_seconds_saved",
            "Compile time avoided by cache hits.",
        ).set(cache.get("compile_seconds_saved", 0.0))
        admission = stats["admission"]
        m.gauge(
            "gateway_draining", "1 while the gateway drains, else 0."
        ).set(1.0 if admission["draining"] else 0.0)
        queued = m.gauge(
            "tenant_queue_depth", "Requests waiting for a slot, per tenant."
        )
        active = m.gauge(
            "tenant_active_runs", "Admitted in-flight runs, per tenant."
        )
        rejected = m.counter(
            "tenant_rejected_total",
            "Admission rejections (HTTP 429), per tenant.",
        )
        for tenant, snap in admission["tenants"].items():
            queued.set(snap["queued"], tenant=tenant)
            active.set(snap["active"], tenant=tenant)
            rejected.set(snap["rejected"], tenant=tenant)
        return m.render()

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "Gateway":
        """Serve on a daemon thread; returns immediately."""
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._httpd.serve_forever,
                name="swirl-gateway",
                daemon=True,
            )
            self._thread.start()
        return self

    def serve_forever(self) -> None:
        """Serve on the calling thread (blocks until :meth:`close`)."""
        self._httpd.serve_forever()

    def close(self, *, drain_timeout_s: float = 30.0) -> bool:
        """Graceful shutdown: drain admitted work, then stop accepting.

        Returns ``True`` when every admitted run finished inside the
        timeout (the in-flight guarantee the overload benchmark asserts).
        """
        drained = self.service.drain(timeout_s=drain_timeout_s)
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(5)
            self._thread = None
        return drained

    def __enter__(self) -> "Gateway":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.close()
