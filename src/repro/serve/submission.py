"""Submission decoding: DAG-JSON / ``.swirl`` bodies → :class:`Plan`.

Every malformed submission surfaces as a typed :class:`SubmissionError` —
the gateway turns it into a ``400`` with a JSON error body carrying the
error ``kind`` and, for ``.swirl`` syntax errors, the 1-based
``line``/``column`` from :mod:`repro.core.parser`.  A raw traceback never
crosses the HTTP boundary.

Accepted submission bodies (JSON object unless noted):

* ``{"swirl": "<text>", "rules": [...]}`` — ``.swirl`` surface syntax;
* ``{"dag": {"edges": {...}, "mapping": {...}, "initial_data": {...}},
  "rules": [...]}`` — the step-adjacency DAG-JSON of
  :class:`repro.core.translate.DagTranslator`;
* a plain string (``Content-Type: text/plain`` at the gateway) —
  shorthand for ``{"swirl": <body>}``.

``rules`` defaults to the paper's ``("R1R2",)`` and must name entries of
:data:`repro.core.optimizer.REWRITE_RULES`.
"""

from __future__ import annotations

import re
from typing import Any, Mapping

from repro.api import Plan, trace
from repro.core.optimizer import REWRITE_RULES
from repro.core.parser import SwirlSyntaxError, parse_system
from repro.core.translate import DagTranslator

__all__ = ["SubmissionError", "compile_submission", "parse_payload_keys"]

DEFAULT_RULES = ("R1R2",)

#: The ``.swirl`` identifier alphabet.  Enforced on DAG-JSON names too so
#: the canonical text round-trips and the gateway's ``location:datum``
#: payload keys / ``#tag`` endpoint namespaces can never be ambiguous.
_IDENT = re.compile(r"[A-Za-z0-9_^$]+\Z")


class SubmissionError(ValueError):
    """A workflow submission the gateway must reject with a 400.

    ``kind`` classifies the failure (``"json"``, ``"schema"``,
    ``"swirl-syntax"``, ``"dag"``, ``"rules"``, ``"steps"``,
    ``"inputs"``); ``line``/``column`` are 1-based positions for
    ``.swirl`` syntax errors (``None`` otherwise).
    """

    def __init__(
        self,
        message: str,
        *,
        kind: str = "schema",
        line: int | None = None,
        column: int | None = None,
    ):
        super().__init__(message)
        self.kind = kind
        self.line = line
        self.column = column

    def to_json(self) -> dict[str, Any]:
        body: dict[str, Any] = {
            "type": "SubmissionError",
            "kind": self.kind,
            "message": str(self),
        }
        if self.line is not None:
            body["line"] = self.line
        if self.column is not None:
            body["column"] = self.column
        return body


def _require(cond: bool, message: str, *, kind: str = "schema") -> None:
    if not cond:
        raise SubmissionError(message, kind=kind)


def _check_ident(name: Any, what: str, *, kind: str) -> str:
    _require(
        isinstance(name, str) and bool(_IDENT.match(name)),
        f"{what} {name!r} is not a valid identifier "
        "([A-Za-z0-9_^$]+, no dots/colons)",
        kind=kind,
    )
    return name


def _validate_rules(rules: Any) -> tuple[str, ...]:
    if rules is None:
        return DEFAULT_RULES
    _require(
        isinstance(rules, (list, tuple))
        and all(isinstance(r, str) for r in rules),
        "'rules' must be a list of rule names",
        kind="rules",
    )
    unknown = [r for r in rules if r not in REWRITE_RULES]
    _require(
        not unknown,
        f"unknown rewrite rules {unknown}; known: {sorted(REWRITE_RULES)}",
        kind="rules",
    )
    return tuple(rules)


def _dag_instance(dag: Any) -> DagTranslator:
    _require(
        isinstance(dag, Mapping),
        "'dag' must be an object with 'edges' and 'mapping'",
        kind="dag",
    )
    unknown = set(dag) - {"edges", "mapping", "initial_data"}
    _require(
        not unknown,
        f"unknown 'dag' fields {sorted(unknown)}; "
        "allowed: edges, mapping, initial_data",
        kind="dag",
    )
    edges = dag.get("edges")
    mapping = dag.get("mapping")
    _require(
        isinstance(edges, Mapping) and len(edges) > 0,
        "'dag.edges' must be a non-empty object {step: [successor, ...]}",
        kind="dag",
    )
    _require(
        isinstance(mapping, Mapping) and len(mapping) > 0,
        "'dag.mapping' must be a non-empty object {step: [location, ...]}",
        kind="dag",
    )
    steps: set[str] = set()
    for s, succs in edges.items():
        _check_ident(s, "step", kind="dag")
        _require(
            isinstance(succs, (list, tuple)),
            f"'dag.edges[{s!r}]' must be a list of successor steps",
            kind="dag",
        )
        steps.add(s)
        for t in succs:
            steps.add(_check_ident(t, "step", kind="dag"))
    placed: set[str] = set()
    locations: set[str] = set()
    for s, locs in mapping.items():
        _check_ident(s, "step", kind="dag")
        _require(
            isinstance(locs, (list, tuple)) and len(locs) > 0,
            f"'dag.mapping[{s!r}]' must be a non-empty list of locations",
            kind="dag",
        )
        placed.add(s)
        for l in locs:
            locations.add(_check_ident(l, "location", kind="dag"))
    unplaced = steps - placed
    _require(
        not unplaced,
        f"steps {sorted(unplaced)} appear in 'edges' but have no "
        "'mapping' entry (every step needs M(s))",
        kind="dag",
    )
    extra = placed - steps
    _require(
        not extra,
        f"'mapping' names steps {sorted(extra)} that never appear in "
        "'edges'",
        kind="dag",
    )
    initial = dag.get("initial_data") or {}
    _require(
        isinstance(initial, Mapping),
        "'dag.initial_data' must be an object {location: [datum, ...]}",
        kind="dag",
    )
    # The translator materialises exactly one datum d^s per producer step;
    # initial_data may only seed those (anything else fails deep in the
    # graph model — catch it here with an explanation instead).
    produced = sorted(f"d^{s}" for s, succs in edges.items() if succs)
    for l, ds in initial.items():
        _require(
            l in locations,
            f"'initial_data' location {l!r} is not used by any step "
            f"(locations: {sorted(locations)})",
            kind="dag",
        )
        _require(
            isinstance(ds, (list, tuple)),
            f"'dag.initial_data[{l!r}]' must be a list of data elements",
            kind="dag",
        )
        for d in ds:
            _check_ident(d, "datum", kind="dag")
            _require(
                d in produced,
                f"'initial_data' datum {d!r} is not produced by any step; "
                f"this DAG's data elements are {produced}",
                kind="dag",
            )
    translator = DagTranslator(
        edges={s: tuple(ts) for s, ts in edges.items()},
        mapping={s: tuple(ls) for s, ls in mapping.items()},
        initial_data={l: tuple(ds) for l, ds in initial.items()},
    )
    try:
        translator.instance()
    except ValueError as e:
        # Any residual graph-model validation failure is still the
        # submitter's problem, not a server error.
        raise SubmissionError(str(e), kind="dag") from e
    return translator


def compile_submission(body: Any) -> tuple[Plan, dict[str, Any]]:
    """Decode one submission body into an optimised :class:`Plan`.

    Returns ``(plan, meta)`` where ``meta`` records the source format and
    the rule list applied.  Raises :class:`SubmissionError` on any
    malformed input.
    """
    if isinstance(body, str):
        body = {"swirl": body}
    _require(
        isinstance(body, Mapping),
        "submission must be a JSON object (or raw .swirl text)",
        kind="schema",
    )
    unknown = set(body) - {"swirl", "dag", "rules"}
    _require(
        not unknown,
        f"unknown submission fields {sorted(unknown)}; "
        "allowed: swirl, dag, rules",
        kind="schema",
    )
    rules = _validate_rules(body.get("rules"))
    has_swirl = "swirl" in body
    has_dag = "dag" in body
    _require(
        has_swirl != has_dag,
        "submission needs exactly one of 'swirl' (surface text) or 'dag' "
        "(edges + mapping)",
        kind="schema",
    )
    if has_swirl:
        text = body["swirl"]
        _require(
            isinstance(text, str) and text.strip(),
            "'swirl' must be non-empty .swirl source text",
            kind="schema",
        )
        try:
            system = parse_system(text)
        except SwirlSyntaxError as e:
            raise SubmissionError(
                str(e), kind="swirl-syntax", line=e.line, column=e.column
            ) from e
        plan = trace(system)
        fmt = "swirl"
    else:
        plan = trace(_dag_instance(body["dag"]).instance())
        fmt = "dag"
    if rules:
        plan = plan.optimize(rules)
    return plan, {"format": fmt, "rules": list(rules)}


def parse_payload_keys(
    inputs: Any, locations: frozenset[str] | set[str]
) -> dict[tuple[str, str], Any]:
    """``{"location:datum": value}`` → ``{(location, datum): value}``.

    The colon separator can never appear inside an identifier, so the
    split is unambiguous.  Unknown locations are rejected (a typo would
    otherwise silently strand the payload and the run would time out).
    """
    if inputs is None:
        return {}
    if not isinstance(inputs, Mapping):
        raise SubmissionError(
            "'inputs' must be an object {\"location:datum\": value}",
            kind="inputs",
        )
    payloads: dict[tuple[str, str], Any] = {}
    for key, value in inputs.items():
        loc, sep, datum = str(key).partition(":")
        if not sep or not loc or not datum:
            raise SubmissionError(
                f"payload key {key!r} must be 'location:datum'",
                kind="inputs",
            )
        if loc not in locations:
            raise SubmissionError(
                f"payload key {key!r} names unknown location {loc!r} "
                f"(locations: {sorted(locations)})",
                kind="inputs",
            )
        payloads[(loc, datum)] = value
    return payloads
