"""A small keep-alive client for the gateway (stdlib :mod:`http.client`).

Used by the benchmark harness, the CI smoke example and tests; also the
reference for writing clients in other languages.  One
:class:`GatewayClient` holds one persistent HTTP/1.1 connection — reuse
it from a single thread (create one per worker thread for load
generation); it reconnects transparently when the server closes the
connection between requests.

Non-2xx responses raise :class:`GatewayError` carrying the decoded JSON
error body and, for 429/503, the server's ``Retry-After``.
"""

from __future__ import annotations

import http.client
import json
import time
from typing import Any, Mapping, Sequence

__all__ = ["GatewayClient", "GatewayError"]


class GatewayError(RuntimeError):
    """A non-2xx gateway response, with the decoded JSON error body."""

    def __init__(self, status: int, payload: Any, *, retry_after: int = 0):
        error = (
            payload.get("error", payload) if isinstance(payload, dict)
            else payload
        )
        message = (
            error.get("message", str(error)) if isinstance(error, dict)
            else str(error)
        )
        super().__init__(f"HTTP {status}: {message}")
        self.status = status
        self.payload = payload
        self.error = error if isinstance(error, dict) else {}
        #: Server-suggested back-off in seconds (0 when absent).
        self.retry_after = retry_after


class GatewayClient:
    """One keep-alive connection to a gateway at ``http://host:port``."""

    def __init__(
        self,
        url: str,
        *,
        api_key: str = "",
        timeout_s: float = 60.0,
    ):
        if url.startswith("http://"):
            url = url[len("http://"):]
        elif url.startswith("https://"):
            raise ValueError("the gateway speaks plain HTTP")
        self._netloc = url.rstrip("/")
        self.api_key = api_key
        self.timeout_s = timeout_s
        self._conn: http.client.HTTPConnection | None = None

    # -- transport ------------------------------------------------------------
    def _connection(self) -> http.client.HTTPConnection:
        if self._conn is None:
            self._conn = http.client.HTTPConnection(
                self._netloc, timeout=self.timeout_s
            )
        return self._conn

    def close(self) -> None:
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def __enter__(self) -> "GatewayClient":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    def _request(
        self,
        method: str,
        path: str,
        body: Any = None,
        *,
        content_type: str = "application/json",
        raw: bool = False,
    ) -> Any:
        if isinstance(body, (str, bytes)):
            payload = body.encode() if isinstance(body, str) else body
        elif body is not None:
            payload = json.dumps(body).encode()
        else:
            payload = None
        headers = {"X-API-Key": self.api_key}
        if payload is not None:
            headers["Content-Type"] = content_type
        for attempt in (0, 1):
            conn = self._connection()
            try:
                conn.request(method, path, body=payload, headers=headers)
                resp = conn.getresponse()
                break
            except (
                http.client.RemoteDisconnected,
                BrokenPipeError,
                ConnectionResetError,
            ):
                # Stale keep-alive connection: reconnect once.
                self.close()
                if attempt:
                    raise
        data = resp.read()
        if resp.status >= 400:
            try:
                decoded = json.loads(data) if data else None
            except json.JSONDecodeError:
                decoded = data.decode("utf-8", errors="replace")
            retry_after = int(resp.getheader("Retry-After") or 0)
            raise GatewayError(resp.status, decoded, retry_after=retry_after)
        if raw:
            return data.decode("utf-8", errors="replace")
        return json.loads(data) if data else None

    # -- API ------------------------------------------------------------------
    def submit(self, body: Any) -> dict[str, Any]:
        """POST a workflow (DAG-JSON object or raw ``.swirl`` text)."""
        if isinstance(body, str):
            return self._request(
                "POST", "/v1/workflows", body, content_type="text/plain"
            )
        return self._request("POST", "/v1/workflows", body)

    def describe(self, fingerprint: str) -> dict[str, Any]:
        return self._request("GET", f"/v1/workflows/{fingerprint}")

    def run(
        self,
        fingerprint: str,
        inputs: Mapping[str, Any] | None = None,
        *,
        deadline_s: float | None = None,
    ) -> dict[str, Any]:
        """One instance.  ``deadline_s`` caps the server-side run — on
        overrun the gateway answers a typed 504 (:class:`GatewayError`
        with ``status == 504``)."""
        body: dict[str, Any] = {"inputs": dict(inputs or {})}
        if deadline_s is not None:
            body["deadline_s"] = deadline_s
        return self._request(
            "POST", f"/v1/workflows/{fingerprint}/run", body
        )

    def run_many(
        self,
        fingerprint: str,
        inputs: Sequence[Mapping[str, Any]],
        *,
        max_concurrent: int | None = None,
        deadline_s: float | None = None,
    ) -> dict[str, Any]:
        body: dict[str, Any] = {"inputs": [dict(i) for i in inputs]}
        if max_concurrent is not None:
            body["max_concurrent"] = max_concurrent
        if deadline_s is not None:
            body["deadline_s"] = deadline_s
        return self._request(
            "POST", f"/v1/workflows/{fingerprint}/run_many", body
        )

    def run_with_backoff(
        self,
        fingerprint: str,
        inputs: Mapping[str, Any] | None = None,
        *,
        max_attempts: int = 5,
        max_sleep_s: float = 5.0,
    ) -> dict[str, Any]:
        """Like :meth:`run`, but honours ``Retry-After`` on 429 responses."""
        for attempt in range(max_attempts):
            try:
                return self.run(fingerprint, inputs)
            except GatewayError as e:
                if e.status != 429 or attempt == max_attempts - 1:
                    raise
                time.sleep(min(max_sleep_s, e.retry_after or 1))
        raise AssertionError("unreachable")

    def stats(self) -> dict[str, Any]:
        return self._request("GET", "/v1/stats")

    def healthz(self) -> dict[str, Any]:
        return self._request("GET", "/v1/healthz")

    def metrics(self) -> str:
        """The Prometheus text exposition page, verbatim."""
        return self._request("GET", "/v1/metrics", raw=True)
