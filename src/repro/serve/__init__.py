"""``repro.serve`` — the workflow-as-a-service gateway.

The HTTP front door to the staged pipeline: accept DAG-JSON and ``.swirl``
submissions, compile them through ``trace → optimize → lower → compile``
once, and serve execution requests against a **content-addressed plan
cache** keyed by :meth:`repro.api.Plan.fingerprint`.  Stdlib-only — the
server is a :class:`http.server.ThreadingHTTPServer`, the client keeps one
``http.client`` connection alive — so serving needs no dependencies the
toolchain does not already have.

Layering (each importable and testable without HTTP):

====================  ======================================================
:mod:`.submission`    DAG-JSON / ``.swirl`` bodies → :class:`repro.api.Plan`
                      with typed :class:`SubmissionError`\\ s (never a raw
                      traceback past the gateway)
:mod:`.cache`         fingerprint → compiled-Executable LRU with
                      hit/miss/eviction stats (the service-level extension
                      of the :mod:`repro.api` derive cache)
:mod:`.admission`     API-key → tenant map, per-tenant concurrency quotas,
                      bounded FIFO queues with backpressure, graceful drain
:mod:`.service`       the backend-agnostic core: submit / run / run_many /
                      stats against the cache under admission control
:mod:`.gateway`       the HTTP surface (``POST /v1/workflows``, ``…/run``,
                      ``…/run_many``, ``GET /v1/workflows/{fp}``,
                      ``GET /v1/stats``)
:mod:`.client`        keep-alive :class:`GatewayClient` for examples,
                      benchmarks and tests
====================  ======================================================

Quickstart::

    from repro.serve import Gateway, TenantConfig, WorkflowService

    service = WorkflowService(
        steps={"ingest": ingest_fn, "merge": merge_fn},
        tenants=[TenantConfig("team-a", api_key="ka", max_concurrent=8)],
    )
    with Gateway(service) as gw:
        print(gw.url)          # e.g. http://127.0.0.1:43117
        gw.serve_forever()     # or use GatewayClient against gw.url
"""

from .admission import (  # noqa: F401
    AdmissionController,
    AdmissionRejected,
    TenantConfig,
    UnknownTenantError,
)
from .cache import CacheEntry, PlanCache  # noqa: F401
from .client import GatewayClient, GatewayError  # noqa: F401
from .gateway import Gateway  # noqa: F401
from .service import (  # noqa: F401
    DeadlineExceeded,
    ServiceDraining,
    WorkflowService,
)
from .submission import SubmissionError, compile_submission  # noqa: F401

__all__ = [
    "AdmissionController",
    "AdmissionRejected",
    "CacheEntry",
    "DeadlineExceeded",
    "Gateway",
    "GatewayClient",
    "GatewayError",
    "PlanCache",
    "ServiceDraining",
    "SubmissionError",
    "TenantConfig",
    "UnknownTenantError",
    "WorkflowService",
    "compile_submission",
]
