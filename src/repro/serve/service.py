"""The backend-agnostic service core behind the HTTP gateway.

:class:`WorkflowService` owns the four serving concerns and exposes them as
plain methods the gateway (or tests, or an embedding application) calls:

* **submit** — decode a DAG-JSON / ``.swirl`` body
  (:mod:`repro.serve.submission`), compile it through the staged pipeline
  ``trace → optimize → [schedule] → lower → compile`` against the
  service's step registry (the schedule stage runs when the operator
  deploys with a ``network`` cost model — submissions then get
  auto-placement instead of their author's static mapping), and store
  the artifact in the content-addressed cache
  (:mod:`repro.serve.cache`).  Returns the plan fingerprint — the handle
  every later request uses.
* **run / run_many** — execute instances against a cached artifact under
  admission control (:mod:`repro.serve.admission`).  On backends that
  advertise concurrent batches (``threaded``, the default) many requests
  share one compiled Executable; batches stream through the backend's
  persistent ``run_many`` lanes.
* **describe / stats** — :meth:`Plan.explain` output for one fingerprint;
  cache + derive-cache + admission + throughput counters for operators.

Step bodies cannot travel over HTTP: the operator deploys the service with
a **step registry** (name → callable / :class:`StepMeta`), and submissions
may only reference registered steps — an unknown step is a 400-class
:class:`SubmissionError`, caught at submit time, never at run time.
"""

from __future__ import annotations

import hashlib
import json
import logging
import threading
import time
from typing import Any, Mapping, Sequence

from repro import api
from repro.core.compile import StepFn, StepMeta
from repro.serve.admission import (
    AdmissionController,
    TenantConfig,
)
from repro.serve.cache import CacheEntry, PlanCache
from repro.serve.submission import (
    SubmissionError,
    compile_submission,
    parse_payload_keys,
)

__all__ = [
    "DeadlineExceeded",
    "ServiceDraining",
    "WorkflowService",
    "UnknownWorkflowError",
]

logger = logging.getLogger("repro.serve.service")


def _trace_tag() -> str:
    """The request's trace id (bound by the gateway), or ``"-"``."""
    from repro.obs.events import current_trace_id

    return current_trace_id.get() or "-"


def _recoveries_of(result: Any) -> int:
    """Elastic recoveries a backend reported for one instance (0 if none)."""
    stats = getattr(result, "stats", None)
    if isinstance(stats, Mapping):
        return len(stats.get("recoveries") or ())
    return 0

#: The open single-tenant default: embedding apps and quickstarts that do
#: not care about multi-tenancy authenticate with an empty API key.
DEFAULT_TENANTS = (
    TenantConfig("anonymous", api_key="", max_concurrent=32, max_queue=128),
)


class UnknownWorkflowError(KeyError):
    """No cached workflow under the requested fingerprint (HTTP 404)."""

    def __init__(self, fingerprint: str):
        super().__init__(fingerprint)
        self.fingerprint = fingerprint


class ServiceDraining(RuntimeError):
    """The service is shutting down and admits no new work (HTTP 503)."""


class DeadlineExceeded(RuntimeError):
    """A request's ``deadline_s`` elapsed before its run finished (HTTP 504).

    The service **abandons** the run: the worker thread executing it is a
    daemon and its eventual result is never read — sound because SWIRL
    steps are pure, so an orphaned run has no observable effect beyond its
    own (discarded) store.  The admission slot is released immediately, so
    a deadline abort can never leak an in-flight quota unit.
    """

    def __init__(self, deadline_s: float, *, fingerprint: str = ""):
        tag = f" of workflow {fingerprint[:12]}" if fingerprint else ""
        super().__init__(
            f"run{tag} abandoned after its {deadline_s}s deadline"
        )
        self.deadline_s = deadline_s
        self.fingerprint = fingerprint

    def to_json(self) -> dict[str, Any]:
        return {
            "type": "DeadlineExceeded",
            "message": str(self),
            "deadline_s": self.deadline_s,
            "fingerprint": self.fingerprint,
        }


def _recoverable(exc: BaseException) -> bool:
    """Is this failure worth a server-side re-run (tenant ``max_retries``)?

    Worker-process deaths and transient step failures are recoverable —
    the run may succeed on a fresh attempt.  Everything else (permanent
    step errors, submission bugs, deadlocks) is deterministic and retrying
    would only burn the tenant's slot.
    """
    from repro.backends.multiprocess import WorkerFailedError
    from repro.workflow.fault import TransientError

    seen: set[int] = set()
    cur: BaseException | None = exc
    while cur is not None and id(cur) not in seen:
        seen.add(id(cur))
        if isinstance(cur, (WorkerFailedError, TransientError)):
            return True
        # Backends wrap the failing step's error (e.g. the threaded
        # runtime's "location X failed: ..." RuntimeError) — walk the
        # cause chain to the root.
        cur = cur.__cause__ or cur.__context__
    return False


def _source_digest(body: Any) -> str:
    """Canonical digest of a submission body (dict key order insensitive)."""
    canon = json.dumps(
        body, sort_keys=True, separators=(",", ":"), default=repr
    )
    return hashlib.sha256(canon.encode()).hexdigest()


class WorkflowService:
    """Compile-once/run-many workflow serving (see module docstring).

    ``steps`` is the server-side step registry; ``backend`` defaults to
    ``threaded`` (the one backend whose compiled programs serve concurrent
    batches); ``network`` (a :class:`repro.sched.NetworkModel`) enables the
    optional schedule stage — every compiled submission is auto-placed
    against the cost model via :meth:`Plan.schedule` before lowering;
    ``lower_options`` are passed to :meth:`Plan.lower` verbatim
    (e.g. ``{"timeout_s": 30}``).  ``batch_max_concurrent`` caps the
    *internal* parallelism of any one ``run_many`` batch, independently of
    the per-tenant admission quota (which counts whole requests).
    """

    def __init__(
        self,
        steps: Mapping[str, StepFn | StepMeta],
        *,
        backend: str = "threaded",
        rules: Sequence[str] = ("R1R2",),
        network: Any | None = None,
        tenants: Sequence[TenantConfig] | None = None,
        cache_capacity: int = 128,
        batch_max_concurrent: int = 8,
        admission_timeout_s: float = 120.0,
        lower_options: Mapping[str, Any] | None = None,
    ):
        self.steps = dict(steps)
        self.backend = backend
        self.default_rules = tuple(rules)
        self.network = network
        self.cache = PlanCache(cache_capacity)
        self.admission = AdmissionController(
            tuple(tenants) if tenants is not None else DEFAULT_TENANTS
        )
        self.batch_max_concurrent = batch_max_concurrent
        self.admission_timeout_s = admission_timeout_s
        self.lower_options = dict(lower_options or {})
        self.started_unix = time.time()
        self._counters_lock = threading.Lock()
        self._counters = {
            "submissions": 0,
            "compiles": 0,
            "runs": 0,
            "batches": 0,
            "instances_completed": 0,
            "instances_failed": 0,
            "rejected": 0,
            "recoveries": 0,
            "run_retries": 0,
            "deadline_aborts": 0,
        }

    def _count(self, **deltas: int) -> None:
        with self._counters_lock:
            for key, d in deltas.items():
                self._counters[key] += d

    # -- submit ---------------------------------------------------------------
    def submit(self, body: Any) -> dict[str, Any]:
        """Compile one submission (or hit the cache) → receipt with fingerprint.

        The receipt carries ``cached`` (no compile happened), the plan's
        compile ``timings`` (from :attr:`Plan.timings`, milliseconds) and
        enough metadata for the client to build run payloads.
        """
        self._count(submissions=1)
        if self.admission.draining:
            raise ServiceDraining("service is draining; not accepting work")
        digest = _source_digest(body)
        entry = self.cache.lookup_source(digest)
        if entry is not None:
            return self._receipt(entry, cached=True)
        t0 = time.perf_counter()
        if isinstance(body, Mapping) and "rules" not in body:
            body = dict(body, rules=list(self.default_rules))
        plan, meta = compile_submission(body)
        missing = sorted(set(plan.steps()) - set(self.steps))
        if missing:
            raise SubmissionError(
                f"workflow references steps with no registered body: "
                f"{missing}; registered: {sorted(self.steps)}",
                kind="steps",
            )
        if self.network is not None:
            # Operator-configured auto-placement: re-map steps against the
            # deployment's cost model, then fingerprint the *scheduled*
            # plan so placement-equivalent submissions share one artifact.
            plan = plan.schedule(self.network, steps=self.steps)
        fingerprint = plan.fingerprint()
        existing = self.cache.peek(fingerprint)
        if existing is not None:
            # Same artifact reached from different source text: alias the
            # digest onto it, skip the lower/compile.
            entry = self.cache.put(existing, source_digest=digest)
            return self._receipt(entry, cached=True)
        executable = (
            plan.lower(self.backend, **self.lower_options).compile(self.steps)
        )
        entry = CacheEntry(
            fingerprint=fingerprint,
            plan=plan,
            executable=executable,
            meta=meta,
            compile_seconds=time.perf_counter() - t0,
        )
        entry = self.cache.put(entry, source_digest=digest)
        self._count(compiles=1)
        logger.info(
            "compiled %s in %.1fms [trace_id=%s]",
            fingerprint[:12],
            entry.compile_seconds * 1e3,
            _trace_tag(),
        )
        return self._receipt(entry, cached=False)

    def _receipt(self, entry: CacheEntry, *, cached: bool) -> dict[str, Any]:
        return {
            **entry.summary(),
            "cached": cached,
            "backend": self.backend,
            "timings_ms": {
                label: round(seconds * 1e3, 3)
                for label, seconds in entry.plan.timings
            },
        }

    # -- execute --------------------------------------------------------------
    def _entry(self, fingerprint: str) -> CacheEntry:
        entry = self.cache.get(fingerprint)
        if entry is None:
            raise UnknownWorkflowError(fingerprint)
        return entry

    def _tenant_name(self, tenant: TenantConfig | str | None) -> str:
        name = tenant.name if isinstance(tenant, TenantConfig) else tenant
        if name is None:
            name = self.admission.tenant_names()[0]
        return name

    def _admitted(self, tenant: TenantConfig | str | None):
        return self.admission.admit(
            self._tenant_name(tenant), timeout_s=self.admission_timeout_s
        )

    @staticmethod
    def _check_deadline_s(deadline_s: float | None) -> float | None:
        if deadline_s is None:
            return None
        try:
            deadline_s = float(deadline_s)
        except (TypeError, ValueError):
            raise SubmissionError(
                f"deadline_s must be a positive number, "
                f"got {deadline_s!r}",
                kind="deadline",
            ) from None
        if not deadline_s > 0:
            raise SubmissionError(
                f"deadline_s must be a positive number, got {deadline_s!r}",
                kind="deadline",
            )
        return deadline_s

    def run(
        self,
        fingerprint: str,
        inputs: Any = None,
        *,
        tenant: TenantConfig | str | None = None,
        deadline_s: float | None = None,
    ) -> dict[str, Any]:
        """Execute one instance of a cached workflow; returns its data.

        ``deadline_s`` bounds the request end-to-end (all server-side
        retry attempts included): on overrun the run is abandoned and
        :class:`DeadlineExceeded` raised — the gateway's 504.
        """
        entry = self._entry(fingerprint)
        deadline_s = self._check_deadline_s(deadline_s)
        payloads = parse_payload_keys(
            inputs, entry.plan.system.locations()
        )
        self._count(runs=1)
        with self._admitted(tenant):
            try:
                result = self._run_guarded(
                    entry,
                    lambda exe: exe.run(initial_payloads=payloads),
                    tenant=tenant,
                    deadline_s=deadline_s,
                )
            except Exception as e:
                self._count(instances_failed=1)
                logger.warning(
                    "run %s failed: %s [trace_id=%s]",
                    fingerprint[:12],
                    e,
                    _trace_tag(),
                )
                raise
        self._count(
            instances_completed=1, recoveries=_recoveries_of(result)
        )
        return {"fingerprint": fingerprint, "data": result.data}

    def run_many(
        self,
        fingerprint: str,
        inputs: Sequence[Any],
        *,
        tenant: TenantConfig | str | None = None,
        max_concurrent: int | None = None,
        deadline_s: float | None = None,
    ) -> dict[str, Any]:
        """Execute a batch through the backend's persistent run_many lanes.

        One admission slot covers the whole batch (a tenant cannot inflate
        its quota by batching); internal parallelism is capped by the
        service's ``batch_max_concurrent``.  ``deadline_s`` bounds the
        whole batch, like :meth:`run`.
        """
        entry = self._entry(fingerprint)
        deadline_s = self._check_deadline_s(deadline_s)
        if not isinstance(inputs, Sequence) or isinstance(inputs, (str, bytes)):
            raise SubmissionError(
                "'inputs' must be a list (one object per instance)",
                kind="inputs",
            )
        locations = entry.plan.system.locations()
        payloads = [parse_payload_keys(item, locations) for item in inputs]
        lanes = min(
            self.batch_max_concurrent,
            max_concurrent or self.batch_max_concurrent,
        )
        self._count(batches=1)
        with self._admitted(tenant):
            try:
                results = self._run_guarded(
                    entry,
                    lambda exe: exe.run_many(payloads, max_concurrent=lanes),
                    tenant=tenant,
                    deadline_s=deadline_s,
                )
            except Exception:
                self._count(instances_failed=len(payloads))
                raise
        self._count(
            instances_completed=len(results),
            recoveries=sum(_recoveries_of(r) for r in results),
        )
        return {
            "fingerprint": fingerprint,
            "results": [{"data": r.data} for r in results],
        }

    def _run_guarded(
        self,
        entry: CacheEntry,
        op,
        *,
        tenant: TenantConfig | str | None = None,
        deadline_s: float | None = None,
    ):
        """Run ``op(executable)``, serialising when the backend needs it.

        Backends advertising concurrent batches take no lock — that is the
        cache-hit hot path.  The others (``inprocess``/``multiprocess``/
        ``jax``) are serialised per entry so a burst of requests queues
        instead of tripping :class:`repro.api.ConcurrentRunError`.

        Two per-request fault policies layer on top:

        * the tenant's ``max_retries`` re-runs **recoverable** failures
          (worker death, exhausted transient budget) inside the same
          admission slot, and
        * ``deadline_s`` bounds the request wall-clock; on overrun the
          attempt thread is abandoned (steps are pure — see
          :class:`DeadlineExceeded`) and the slot released at once.  An
          abandoned attempt on a serialised backend may hold the entry's
          run lock until it peters out; only same-fingerprint requests
          queue behind it, never the admission quota.
        """
        exe = entry.executable
        max_retries = self.admission.tenant_config(
            self._tenant_name(tenant)
        ).max_retries

        def locked_op():
            if exe.concurrent_runs:
                return op(exe)
            with entry.run_lock:
                return op(exe)

        def attempt_all(abandoned: threading.Event | None):
            for attempt in range(max_retries + 1):
                try:
                    return locked_op()
                except Exception as e:  # noqa: BLE001 — filtered below
                    last_attempt = attempt == max_retries
                    gone = abandoned is not None and abandoned.is_set()
                    if last_attempt or gone or not _recoverable(e):
                        raise
                    self._count(run_retries=1)
                    logger.warning(
                        "retrying %s after recoverable %s "
                        "(attempt %d/%d) [trace_id=%s]",
                        entry.fingerprint[:12],
                        type(e).__name__,
                        attempt + 1,
                        max_retries,
                        _trace_tag(),
                    )

        if deadline_s is None:
            return attempt_all(None)

        abandoned = threading.Event()
        box: list[tuple[str, Any]] = []
        done = threading.Event()

        def target() -> None:
            try:
                box.append(("ok", attempt_all(abandoned)))
            except BaseException as e:  # noqa: BLE001 — re-raised below
                box.append(("err", e))
            finally:
                done.set()

        worker = threading.Thread(
            target=target,
            daemon=True,
            name=f"svc-run-{entry.fingerprint[:12]}",
        )
        worker.start()
        if not done.wait(deadline_s):
            abandoned.set()  # stop any further server-side retries
            self._count(deadline_aborts=1)
            raise DeadlineExceeded(
                deadline_s, fingerprint=entry.fingerprint
            )
        kind, value = box[0]
        if kind == "err":
            raise value
        return value

    # -- introspection ---------------------------------------------------------
    def describe(self, fingerprint: str) -> dict[str, Any]:
        entry = self.cache.peek(fingerprint)
        if entry is None:
            raise UnknownWorkflowError(fingerprint)
        return {
            **entry.summary(),
            "backend": self.backend,
            "placement": {
                s: list(ls) for s, ls in entry.plan.placement().items()
            },
            "explain": entry.plan.explain(),
        }

    def stats(self) -> dict[str, Any]:
        with self._counters_lock:
            counters = dict(self._counters)
        return {
            "uptime_s": round(time.time() - self.started_unix, 3),
            "backend": self.backend,
            "counters": counters,
            "cache": self.cache.stats(),
            "derive_cache": api.compile_cache_stats(),
            "admission": self.admission.stats(),
        }

    def record_rejection(self) -> None:
        """Gateway hook: count a 429 in the service-level counters."""
        self._count(rejected=1)

    # -- shutdown --------------------------------------------------------------
    def drain(self, *, timeout_s: float = 30.0) -> bool:
        """Graceful shutdown: reject new work, wait for admitted work."""
        return self.admission.drain(timeout_s=timeout_s)
