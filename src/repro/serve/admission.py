"""Multi-tenant admission control: quotas, FIFO queues, backpressure.

Every execution request enters through :meth:`AdmissionController.admit`:

* each tenant (authenticated by API key) holds at most ``max_concurrent``
  runs *in flight*;
* up to ``max_queue`` further requests wait in a strict **per-tenant FIFO**
  (a waiter is only granted a slot when every earlier waiter of the same
  tenant has been granted one);
* beyond that the request is rejected immediately with
  :class:`AdmissionRejected` — the gateway maps it to ``429`` with a
  ``Retry-After`` computed from the tenant's recent run durations and
  current backlog;
* :meth:`AdmissionController.drain` flips the controller into draining
  mode (new requests rejected, mapped to ``503``) and waits for every
  admitted run — active *and* already queued — to finish, which is what
  makes gateway shutdown graceful: accepted work is never dropped.

Pure :mod:`threading`; no HTTP concepts leak in (the gateway owns status
codes and headers).
"""

from __future__ import annotations

import math
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Iterable, Iterator
from contextlib import contextmanager

__all__ = [
    "AdmissionController",
    "AdmissionRejected",
    "TenantConfig",
    "UnknownTenantError",
]


class UnknownTenantError(KeyError):
    """No tenant is registered under the presented API key / name."""


class AdmissionRejected(RuntimeError):
    """The tenant's quota and queue are exhausted (or the service drains).

    ``retry_after`` is the suggested client back-off in whole seconds;
    ``reason`` is ``"quota"`` (queue full), ``"timeout"`` (queued longer
    than the caller's patience) or ``"draining"``.
    """

    def __init__(self, tenant: str, *, retry_after: int, reason: str):
        super().__init__(
            f"tenant {tenant!r} admission rejected ({reason}); "
            f"retry after {retry_after}s"
        )
        self.tenant = tenant
        self.retry_after = retry_after
        self.reason = reason


@dataclass(frozen=True)
class TenantConfig:
    """One tenant's identity and quotas.

    ``max_concurrent`` bounds in-flight runs; ``max_queue`` bounds the
    backlog waiting for a slot (0 = reject as soon as the quota is full).
    A :meth:`run_many` batch counts as **one** admission — its internal
    instance parallelism is bounded separately by the service's batch
    concurrency, so a tenant cannot multiply its quota by batching.

    ``max_retries`` is the tenant's *server-side* fault policy: the service
    transparently re-runs a request that failed with a **recoverable**
    backend error (a worker crash or a ``TransientError`` that exhausted
    the backend's own budget) up to this many extra times, all inside the
    tenant's single admission slot.  0 (the default) means failures
    surface to the client immediately.
    """

    name: str
    api_key: str
    max_concurrent: int = 8
    max_queue: int = 16
    max_retries: int = 0

    def __post_init__(self) -> None:
        if self.max_concurrent < 1:
            raise ValueError(
                f"max_concurrent must be >= 1, got {self.max_concurrent}"
            )
        if self.max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {self.max_queue}")
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}"
            )


class _Ticket:
    __slots__ = ("event", "granted")

    def __init__(self) -> None:
        self.event = threading.Event()
        self.granted = False


@dataclass
class _TenantState:
    cfg: TenantConfig
    lock: threading.Lock = field(default_factory=threading.Lock)
    active: int = 0
    queue: "deque[_Ticket]" = field(default_factory=deque)
    admitted: int = 0
    rejected: int = 0
    completed: int = 0
    queued_peak: int = 0
    #: EWMA of recent run durations — the Retry-After estimator.
    run_seconds_avg: float = 0.0

    def snapshot(self) -> dict[str, Any]:
        with self.lock:
            return {
                "active": self.active,
                "queued": len(self.queue),
                "queued_peak": self.queued_peak,
                "admitted": self.admitted,
                "rejected": self.rejected,
                "completed": self.completed,
                "max_concurrent": self.cfg.max_concurrent,
                "max_queue": self.cfg.max_queue,
                "run_seconds_avg": round(self.run_seconds_avg, 6),
            }


class AdmissionController:
    """Admission across a fixed tenant set (see module docstring)."""

    def __init__(self, tenants: Iterable[TenantConfig]):
        self._tenants: dict[str, _TenantState] = {}
        self._by_key: dict[str, TenantConfig] = {}
        for cfg in tenants:
            if cfg.name in self._tenants:
                raise ValueError(f"duplicate tenant name {cfg.name!r}")
            if cfg.api_key in self._by_key:
                raise ValueError(
                    f"tenants {self._by_key[cfg.api_key].name!r} and "
                    f"{cfg.name!r} share an API key"
                )
            self._tenants[cfg.name] = _TenantState(cfg)
            self._by_key[cfg.api_key] = cfg
        if not self._tenants:
            raise ValueError("admission needs at least one tenant")
        self._draining = False
        self._drain_lock = threading.Lock()
        #: Signalled whenever admitted work shrinks (a slot released or a
        #: queued ticket abandoned) — what :meth:`drain` sleeps on.  Never
        #: held together with a tenant lock from the notifying side; the
        #: waiting side acquires tenant locks only *inside* it, so the lock
        #: order is always ``_idle`` → ``st.lock``.
        self._idle = threading.Condition()

    # -- identity ------------------------------------------------------------
    def authenticate(self, api_key: str) -> TenantConfig:
        cfg = self._by_key.get(api_key)
        if cfg is None:
            raise UnknownTenantError("unknown API key")
        return cfg

    def tenant_names(self) -> list[str]:
        return list(self._tenants)

    def tenant_config(self, name: str) -> TenantConfig:
        """The registered :class:`TenantConfig` for ``name``."""
        st = self._tenants.get(name)
        if st is None:
            raise UnknownTenantError(name)
        return st.cfg

    @property
    def draining(self) -> bool:
        return self._draining

    # -- admission -----------------------------------------------------------
    def _retry_after(self, st: _TenantState) -> int:
        """Estimated seconds until a queue slot frees (clamped 1..60).

        Backlog ahead of a new arrival is ``active + queued`` runs over
        ``max_concurrent`` servers; each takes ~the tenant's EWMA run
        duration (1s floor when nothing has completed yet).
        """
        per_run = st.run_seconds_avg or 1.0
        backlog = st.active + len(st.queue)
        return max(
            1, min(60, math.ceil(per_run * backlog / st.cfg.max_concurrent))
        )

    def acquire(self, tenant: str, *, timeout_s: float = 120.0) -> None:
        """Take one run slot for ``tenant``, waiting in FIFO if saturated."""
        st = self._tenants[tenant]
        with st.lock:
            if self._draining:
                raise AdmissionRejected(
                    tenant, retry_after=1, reason="draining"
                )
            # A free slot is only taken directly when nobody is queued —
            # otherwise a late arrival would overtake the FIFO.
            if st.active < st.cfg.max_concurrent and not st.queue:
                st.active += 1
                st.admitted += 1
                return
            if len(st.queue) >= st.cfg.max_queue:
                st.rejected += 1
                raise AdmissionRejected(
                    tenant,
                    retry_after=self._retry_after(st),
                    reason="quota",
                )
            ticket = _Ticket()
            st.queue.append(ticket)
            st.queued_peak = max(st.queued_peak, len(st.queue))
        if ticket.event.wait(timeout_s):
            return
        with st.lock:
            if ticket.granted:
                # Granted in the race between timeout and re-lock: keep it.
                return
            st.queue.remove(ticket)
            st.rejected += 1
            rejection = AdmissionRejected(
                tenant, retry_after=self._retry_after(st), reason="timeout"
            )
        with self._idle:
            self._idle.notify_all()  # the abandoned ticket shrank the queue
        raise rejection

    def release(self, tenant: str, *, run_seconds: float = 0.0) -> None:
        """Return a slot; the longest-waiting queued request gets it."""
        st = self._tenants[tenant]
        with st.lock:
            st.active -= 1
            st.completed += 1
            if run_seconds > 0:
                st.run_seconds_avg = (
                    run_seconds
                    if st.run_seconds_avg == 0.0
                    else 0.8 * st.run_seconds_avg + 0.2 * run_seconds
                )
            if st.queue and st.active < st.cfg.max_concurrent:
                ticket = st.queue.popleft()
                ticket.granted = True
                st.active += 1
                st.admitted += 1
                ticket.event.set()
        with self._idle:
            self._idle.notify_all()  # admitted work shrank (or handed over)

    @contextmanager
    def admit(
        self, tenant: str, *, timeout_s: float = 120.0
    ) -> Iterator[None]:
        """``with admission.admit(name): run(...)`` — acquire + timed release."""
        self.acquire(tenant, timeout_s=timeout_s)
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.release(tenant, run_seconds=time.perf_counter() - t0)

    # -- shutdown ------------------------------------------------------------
    def drain(self, *, timeout_s: float = 30.0) -> bool:
        """Reject new work, wait for admitted work (active + queued) to end.

        Returns ``True`` when everything finished within ``timeout_s``.
        Idempotent; there is deliberately no un-drain — a drained
        controller belongs to a gateway that is shutting down.
        """
        with self._drain_lock:
            self._draining = True
        deadline = time.monotonic() + timeout_s
        # Event-driven rather than a 10ms busy-poll: `release`/the queue-
        # timeout path notify `_idle` whenever admitted work shrinks, and
        # every tenant read below happens under that tenant's lock (the
        # same discipline as `queue_depths`).  Holding `_idle` across the
        # predicate check closes the check-then-wait race: a notify cannot
        # slip between seeing work outstanding and going to sleep.
        with self._idle:
            while not self._all_idle():
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return self._all_idle()
                self._idle.wait(remaining)
        return True

    def _all_idle(self) -> bool:
        """Locked read: no tenant has active or queued admitted work."""
        for st in self._tenants.values():
            with st.lock:
                if st.active or st.queue:
                    return False
        return True

    # -- introspection ---------------------------------------------------------
    def queue_depths(self) -> dict[str, dict[str, int]]:
        """Per-tenant ``{queued, active}`` — the drain-aware routing view.

        A strict subset of :meth:`stats`, cheap enough for load balancers
        to poll through the unauthenticated health endpoint.
        """
        depths: dict[str, dict[str, int]] = {}
        for name, st in self._tenants.items():
            with st.lock:
                depths[name] = {"queued": len(st.queue), "active": st.active}
        return depths

    def stats(self) -> dict[str, Any]:
        return {
            "draining": self._draining,
            "tenants": {
                name: st.snapshot() for name, st in self._tenants.items()
            },
        }
