"""Pallas TPU kernels for the compute hot-spots (+ jnp oracles in ref.py)."""

from . import ops, ref
from .flash_attention import flash_attention
from .decode_attention import decode_attention
from .rmsnorm import rmsnorm

__all__ = ["ops", "ref", "flash_attention", "decode_attention", "rmsnorm"]
