"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode; on a
real TPU platform they compile to Mosaic.  The interpret switch is decided
once per process from the default backend.
"""

from __future__ import annotations

from functools import partial

import jax

from .decode_attention import decode_attention as _decode
from .flash_attention import flash_attention as _flash
from .rmsnorm import rmsnorm as _rmsnorm


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q, k, v, *, causal=True, window=0, softcap=0.0,
    block_q=128, block_k=128, interpret=None,
):
    """q: [B, Lq, Hq, d]; k/v: [B, Lk, Hkv, d] (model layout) → [B, Lq, Hq, d]."""
    interp = _interpret_default() if interpret is None else interpret
    out = _flash(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interp,
    )
    return out.transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, kv_len, *, block_k=512, interpret=None):
    """q: [B, 1, Hq, d]; k/v cache: [B, M, Hkv, d] → [B, 1, Hq, d]."""
    interp = _interpret_default() if interpret is None else interpret
    out = _decode(
        q[:, 0],
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        kv_len,
        block_k=block_k, interpret=interp,
    )
    return out[:, None]


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps=1e-6, block_rows=256, interpret=None):
    interp = _interpret_default() if interpret is None else interpret
    return _rmsnorm(x, w, eps=eps, block_rows=block_rows, interpret=interp)
