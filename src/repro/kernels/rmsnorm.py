"""Fused RMSNorm — Pallas TPU kernel.

A bandwidth-bound elementwise+reduction op: fusing the mean-square
reduction, rsqrt and scale into one kernel reads/writes each row exactly
once (XLA sometimes splits the fp32 upcast path into two HBM round-trips).
Rows are processed in ``[br, d]`` VMEM tiles; the feature dim stays whole so
the row reduction never crosses tiles (all assigned d_model ≤ 8192 ⇒ a
``[256, 8192]`` fp32 tile is 8 MiB — comfortably inside the 16 MiB/core
VMEM budget together with the weight row).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [br, d]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def rmsnorm(
    x: jax.Array,  # [..., d]
    w: jax.Array,  # [d]
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    # Pad rows up to a block multiple (masked rows are normalised garbage
    # that is sliced away — no correctness impact).
    pad = (-rows) % br
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, d), x2.dtype)], axis=0)
    grid = (x2.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name="swirl_rmsnorm",
    )(x2, w)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
