"""Decode attention (flash-decode) — Pallas TPU kernel.

Single-token decode is *memory-bound*: the entire KV cache is streamed once
per step.  The kernel splits the KV sequence into blocks (split-K) and
accumulates the online-softmax partials in VMEM scratch, so the only HBM
traffic is the one mandatory KV read — the roofline optimum.

Queries for all ``G = Hq/Hkv`` heads of one KV group are processed together
as a ``[G, d]`` tile: the score matmul ``[G, d] × [d, bk]`` feeds the MXU a
tall-thin-but-batched operand instead of ``G`` rank-1 products, and the KV
block is read once per *group* rather than once per query head (the GQA
bandwidth saving is the whole point of GQA at decode time).

The valid-length mask makes rows beyond ``kv_len`` contribute zero, so a
static-shape ring cache can be over-allocated (serving pads to the shape
bucket and the kernel reads only what is valid — rounded up to the block).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _decode_kernel(
    kvlen_ref,  # scalar prefetch: [1] int32 — valid KV rows
    q_ref,  # [1, 1, G, d]
    k_ref,  # [1, 1, bk, d]
    v_ref,  # [1, 1, bk, d]
    o_ref,  # [1, 1, G, d]
    m_scr,  # [G, 1]
    l_scr,  # [G, 1]
    acc_scr,  # [G, d]
    *,
    sm_scale: float,
    bk: int,
):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    kv_len = kvlen_ref[0]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ik * bk

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # [G, d]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [G, bk]
        s_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(s_idx < kv_len, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,  # [B, Hq, d] — one new token per sequence
    k: jax.Array,  # [B, Hkv, Lk, d] — cache (possibly over-allocated)
    v: jax.Array,  # [B, Hkv, Lk, d]
    kv_len: jax.Array | int,  # valid rows, dynamic scalar
    *,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, hq, d = q.shape
    _, hkv, lk, _ = k.shape
    g = hq // hkv
    bk = min(block_k, lk)
    assert lk % bk == 0, (lk, bk)

    qg = q.reshape(b, hkv, g, d)
    kv_len_arr = jnp.asarray([kv_len], jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, lk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h, ik, *_: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, ik, *_: (b_, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, ik, *_: (b_, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h, ik, *_: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _decode_kernel, sm_scale=1.0 / math.sqrt(d), bk=bk
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="swirl_decode_attention",
    )(kv_len_arr, qg, k, v)
    return out.reshape(b, hq, d)
