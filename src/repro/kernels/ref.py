"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array,  # [B, Hq, Lq, d]
    k: jax.Array,  # [B, Hkv, Lk, d]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    kv_len: int | None = None,
) -> jax.Array:
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    g = hq // hkv
    kq = jnp.repeat(k, g, axis=1)
    vq = jnp.repeat(v, g, axis=1)
    s = jnp.einsum(
        "bhtd,bhsd->bhts", q.astype(jnp.float32), kq.astype(jnp.float32)
    ) / math.sqrt(d)
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    t_idx = jnp.arange(lq)[:, None]
    s_idx = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= t_idx >= s_idx
    if window > 0:
        mask &= t_idx - s_idx < window
    if kv_len is not None:
        mask &= s_idx < kv_len
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p, vq.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # [B, Hq, d]
    k: jax.Array,  # [B, Hkv, Lk, d]
    v: jax.Array,
    kv_len,
) -> jax.Array:
    b, hq, d = q.shape
    _, hkv, lk, _ = k.shape
    g = hq // hkv
    kq = jnp.repeat(k, g, axis=1)
    vq = jnp.repeat(v, g, axis=1)
    s = jnp.einsum(
        "bhd,bhsd->bhs", q.astype(jnp.float32), kq.astype(jnp.float32)
    ) / math.sqrt(d)
    mask = jnp.arange(lk)[None, None, :] < kv_len
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, vq.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x: jax.Array, w: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)
