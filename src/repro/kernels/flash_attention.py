"""Flash attention (prefill/train) — Pallas TPU kernel.

Online-softmax attention with explicit VMEM tiling:

* grid ``(B, Hq, Lq/bq, Lk/bk)`` — the last axis is ``arbitrary`` (sequential)
  so the running max ``m``, denominator ``l`` and accumulator ``acc`` live in
  VMEM scratch across KV blocks;
* Q blocks ``[bq, d]`` and KV blocks ``[bk, d]`` are staged HBM→VMEM by the
  BlockSpec pipeline; the two matmuls per block hit the MXU with
  ``d = head_dim`` padded to the 128-lane register width by construction
  (all assigned archs use head_dim ∈ {64, 128, 192});
* GQA is folded into the index map: query head ``h`` reads KV head
  ``h // (Hq/Hkv)`` — no KV replication in HBM;
* causal masking skips fully-masked KV blocks via ``pl.when`` (no FLOPs,
  no VMEM traffic beyond the prefetch);
* optional sliding-window and tanh soft-capping (Gemma-2) are fused.

Validated against :mod:`repro.kernels.ref` in ``interpret=True`` mode (this
container has no TPU); selected on real TPUs via ``set_attn_impl("pallas")``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # [1, 1, bq, d] VMEM
    k_ref,  # [1, 1, bk, d]
    v_ref,  # [1, 1, bk, d]
    o_ref,  # [1, 1, bq, d]
    m_scr,  # [bq, 1] fp32 scratch
    l_scr,  # [bq, 1] fp32 scratch
    acc_scr,  # [bq, d] fp32 scratch
    *,
    sm_scale: float,
    causal: bool,
    window: int,
    softcap: float,
    bq: int,
    bk: int,
    kv_len: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk

    # Causal / window block-level skip: block is live iff some (t, s) pair
    # with t ≥ s (causal) and t − s < window (if windowed) exists.
    live = True
    if causal:
        live = q_start + bq - 1 >= k_start
    if window > 0:
        live = jnp.logical_and(live, q_start - (k_start + bk - 1) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [bq, bk]
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap

        t_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        s_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = s_idx < kv_len
        if causal:
            mask &= t_idx >= s_idx
        if window > 0:
            mask &= t_idx - s_idx < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # [B, Hq, Lq, d]
    k: jax.Array,  # [B, Hkv, Lk, d]
    v: jax.Array,  # [B, Hkv, Lk, d]
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    kv_len: int | None = None,  # valid KV rows (≤ Lk), static
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    bq = min(block_q, lq)
    bk = min(block_k, lk)
    assert lq % bq == 0 and lk % bk == 0, (lq, bq, lk, bk)
    kv_len = lk if kv_len is None else kv_len

    grid = (b, hq, lq // bq, lk // bk)
    kernel = functools.partial(
        _flash_kernel,
        sm_scale=1.0 / math.sqrt(d),
        causal=causal,
        window=window,
        softcap=softcap,
        bq=bq,
        bk=bk,
        kv_len=kv_len,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, iq, ik, g=g: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, iq, ik, g=g: (b_, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="swirl_flash_attention",
    )(q, k, v)
