"""Mesh-agnostic checkpoints: logical arrays + manifest, reshard on load.

Layout of one step directory::

    <dir>/step_000123/
        manifest.json       # tree structure, leaf shapes/dtypes, step
        <leaf-id>.npy       # one file per leaf (written last-to-first,
                            # manifest committed atomically at the end)

Arrays are stored *logically* (full shape, no mesh info), so a checkpoint
written on a ``(16,16)`` mesh restores onto ``(2,16,16)`` or a degraded
elastic mesh: ``load_checkpoint(..., shardings=...)`` device_puts each leaf
with the target sharding.  ``async_save`` snapshots to host memory
synchronously (cheap) and writes in a background thread, overlapping I/O
with the next training step.  A ``step_*`` directory without a manifest is
an interrupted write and is ignored by ``latest_step`` — crash-safe.
"""

from __future__ import annotations

import json
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Callable

import jax
import ml_dtypes
import numpy as np

PyTree = Any

_SEP = "/"

# numpy cannot serialise ml_dtypes natively — store as same-width ints and
# record the logical dtype in the manifest.
_EXOTIC: dict[str, tuple[Any, Any]] = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _encode_arr(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name][1]), name
    return arr, name


def _decode_arr(raw: np.ndarray, name: str) -> np.ndarray:
    if name in _EXOTIC:
        return raw.view(_EXOTIC[name][0])
    return raw


def _flatten_with_names(tree: PyTree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = _SEP.join(_key_str(k) for k in path)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def save_checkpoint(
    directory: str | Path,
    step: int,
    tree: PyTree,
    *,
    keep: int = 3,
) -> Path:
    """Synchronous save; returns the step directory."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    return _write(Path(directory), step, host_tree, keep=keep)


def _write(root: Path, step: int, host_tree: PyTree, *, keep: int) -> Path:
    sdir = root / f"step_{step:09d}"
    tmp = root / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves = _flatten_with_names(host_tree)
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        raw, dtype_name = _encode_arr(arr)
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, raw)
        manifest["leaves"].append(
            {
                "name": name,
                "file": fname,
                "shape": list(arr.shape),
                "dtype": dtype_name,
            }
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if sdir.exists():
        shutil.rmtree(sdir)
    tmp.rename(sdir)  # atomic commit
    _gc(root, keep)
    return sdir


def _gc(root: Path, keep: int) -> None:
    steps = sorted(
        (p for p in root.glob("step_*") if (p / "manifest.json").exists()),
        key=lambda p: p.name,
    )
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str | Path) -> int | None:
    root = Path(directory)
    if not root.exists():
        return None
    best = None
    for p in root.glob("step_*"):
        if not (p / "manifest.json").exists():
            continue  # interrupted write
        m = re.match(r"step_(\d+)", p.name)
        if m:
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


def load_checkpoint(
    directory: str | Path,
    step: int,
    like: PyTree,
    *,
    shardings: PyTree | None = None,
) -> PyTree:
    """Restore into the structure of ``like``; reshard if ``shardings`` given.

    ``shardings`` may be a pytree of ``jax.sharding.Sharding`` matching
    ``like`` (elastic restart onto a different mesh) or ``None`` (host
    arrays placed with default device placement).
    """
    sdir = Path(directory) / f"step_{step:09d}"
    manifest = json.loads((sdir / "manifest.json").read_text())
    by_name = {e["name"]: e for e in manifest["leaves"]}

    names = [n for n, _ in _flatten_with_names(like)]
    missing = [n for n in names if n not in by_name]
    if missing:
        raise KeyError(f"checkpoint is missing leaves: {missing[:5]} ...")

    leaves = []
    flat_sh = (
        [s for _, s in _flatten_with_names(shardings)] if shardings else None
    )
    for i, name in enumerate(names):
        entry = by_name[name]
        arr = _decode_arr(np.load(sdir / entry["file"]), entry["dtype"])
        if flat_sh is not None:
            leaves.append(jax.device_put(arr, flat_sh[i]))
        else:
            leaves.append(arr)
    treedef = jax.tree.structure(like)
    return jax.tree.unflatten(treedef, leaves)


class async_save:
    """Snapshot now, write in the background; ``wait()`` to join.

    Usage::

        saver = async_save(dir, step, {"params": params, "opt": opt_state})
        ...next train step...
        saver.wait()
    """

    def __init__(self, directory: str | Path, step: int, tree: PyTree, *, keep: int = 3):
        # Device→host copy happens synchronously (consistent snapshot)…
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.result: Path | None = None
        self._exc: BaseException | None = None

        def work():
            try:
                self.result = _write(Path(directory), step, host_tree, keep=keep)
            except BaseException as e:  # noqa: BLE001
                self._exc = e

        # …the serialisation/IO overlaps the next step.
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self, timeout: float | None = None) -> Path:
        self._thread.join(timeout)
        if self._exc is not None:
            raise self._exc
        assert self.result is not None
        return self.result
