"""Mesh-agnostic checkpointing."""

from .checkpoint import (
    async_save,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step", "async_save"]
