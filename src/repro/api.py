"""The staged-compilation pipeline — the single front door to the toolchain.

Modeled on JAX's AOT flow (``jit(f).trace(...).lower(...).compile()``), the
SWIRL toolchain is staged as::

    trace   front-end description  → Plan        (encode ⟦·⟧, §3.2)
    optimize Plan                  → Plan        (rewriting ⟦·⟧, §4 + R3)
    lower   Plan × backend/placement → Lowered   (program IR + backend)
    compile Lowered × step bodies  → Executable  (runnable artifact)
    run     Executable             → ExecutionResult
    run_many Executable × [inputs] → [ExecutionResult]  (compile-once serving)

End to end::

    from repro import swirl

    result = (
        swirl.trace(edges, mapping=mapping)
        .optimize()
        .lower("threaded")
        .compile(step_fns)
        .run()
    )

Every stage is a value: a :class:`Plan` can be optimised twice with
different rule sets, lowered to several backends, explained
(:meth:`Plan.explain`), or certified against the original system with the
weak-barbed-bisimulation checker of :mod:`repro.core.bisim` (Thm. 1).

Backends resolve by name through :mod:`repro.backends`; ``inprocess``,
``threaded``, ``multiprocess`` and ``jax`` ship in-tree.  The
``multiprocess`` backend runs every location (group) in its own OS process
over the pluggable transport layer of :mod:`repro.workflow.transport` —
``Plan.lower("multiprocess", workers=..., transport=...)`` selects the
process count and the wire.
"""

from __future__ import annotations

import hashlib
import os
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Mapping, Sequence

from repro.backends import get_backend
from repro.backends.base import (
    BackendProgram,
    ExecutionResult,
    PayloadKey,
)
from repro.core.compile import StepFn, StepMeta
from repro.core.encoding import encode
from repro.core.graph import DistributedWorkflowInstance
from repro.core.optimizer import REWRITE_RULES, OptimizationStats
from repro.core.parser import parse_system
from repro.core.syntax import Exec, WorkflowSystem, actions
from repro.core.translate import DagTranslator, SWIRLTranslator
from repro.sched import CostModel, NetworkModel, SizeModel, auto_placement
from repro.sched.report import ScheduleReport

__all__ = [
    "trace",
    "Plan",
    "Lowered",
    "Executable",
    "AppliedRewrite",
    "BisimCertificate",
    "ExecutionResult",
    "ConcurrentRunError",
    "clear_compile_cache",
    "compile_cache_stats",
]


class ConcurrentRunError(RuntimeError):
    """A second run was started while the Executable was still running.

    Applies to whole runs: a :meth:`Executable.run` or a whole
    :meth:`Executable.run_many` *batch* — the batch's internal instance
    parallelism is not a re-entry and is never rejected.
    """


# ---------------------------------------------------------------------------
# trace — front-end → Plan
# ---------------------------------------------------------------------------


def trace(
    source: (
        SWIRLTranslator
        | DistributedWorkflowInstance
        | WorkflowSystem
        | Mapping[str, Sequence[str]]
        | str
        | os.PathLike
    ),
    *,
    mapping: Mapping[str, Sequence[str]] | None = None,
    initial_data: Mapping[str, Any] | None = None,
) -> "Plan":
    """Stage a front-end workflow description into a :class:`Plan`.

    Accepted sources:

    * a :class:`~repro.core.translate.SWIRLTranslator` (its
      :meth:`~repro.core.translate.SWIRLTranslator.instance` is encoded);
    * a :class:`~repro.core.graph.DistributedWorkflowInstance`;
    * an already-encoded :class:`~repro.core.syntax.WorkflowSystem`;
    * a step-adjacency DAG ``{step: [successors]}`` plus the required
      ``mapping=`` step→locations (sugar for :class:`DagTranslator`);
    * ``.swirl`` surface syntax — a path to a ``.swirl`` file, or source
      text containing a location configuration.
    """
    if isinstance(source, SWIRLTranslator):
        return _traced(source.instance())
    if isinstance(source, DistributedWorkflowInstance):
        return _traced(source)
    if isinstance(source, WorkflowSystem):
        return Plan(system=source)
    if isinstance(source, Mapping):
        if mapping is None:
            raise TypeError(
                "trace(edges) needs mapping= (step → locations) to place "
                "the DAG"
            )
        translator = DagTranslator(
            edges=source,
            mapping=mapping,
            initial_data=initial_data or {},
        )
        return _traced(translator.instance())
    if isinstance(source, (str, os.PathLike)):
        text = os.fspath(source)
        if isinstance(source, os.PathLike) or text.endswith(".swirl"):
            # A filesystem path: a missing file is an error, never
            # silently re-interpreted as source text.
            with open(text, encoding="utf-8") as f:
                text = f.read()
        return Plan(system=parse_system(text))
    raise TypeError(f"cannot trace {type(source).__name__}")


def _traced(inst: DistributedWorkflowInstance) -> "Plan":
    t0 = time.perf_counter()
    system = encode(inst)
    return Plan(
        system=system,
        instance=inst,
        timings=(("encode", time.perf_counter() - t0),),
    )


# ---------------------------------------------------------------------------
# Compile cache — re-derivations keyed by (instance hash, rules, placement)
# ---------------------------------------------------------------------------
#
# Scheduling and placement overrides re-derive the plan (re-encode under the
# new M, re-apply the recorded rewrites).  The search that *chose* the
# placement already proved the result; repeating the derivation for every
# ``lower()``/``schedule()`` of the same (instance, rules, placement) triple
# is pure waste at 10k-step scale, so the outcome is cached in a small LRU.
# Everything stored is immutable and shared safely between plans.

_DERIVE_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
#: Small on purpose: each entry pins a full rewritten system plus its
#: pre-optimisation origin, which at 10k-step scale is tens of MB.  The
#: cache exists to absorb repeated derivations of the *same* plan
#: (schedule → lower → explain chains), not to memoise sweeps.
_DERIVE_CACHE_MAX = 32
#: Plans are immutable and freely shared across threads, so the cache they
#: all consult must be too: every get/move_to_end/insert/evict happens
#: under this lock (an unlocked hit could be evicted by a concurrent
#: insert between ``get`` and ``move_to_end``).
_DERIVE_CACHE_LOCK = threading.Lock()
#: Hit/miss/eviction counters for the derive cache, reported by
#: :func:`compile_cache_stats` (and the serving gateway's ``/v1/stats``).
_DERIVE_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0, "clears": 0}
#: Bumped by :func:`clear_compile_cache`.  Live :class:`Plan` values stamp
#: their memoised ``exec_program()`` with the generation it was computed
#: under; a stale stamp means the user asked for the memory back, so the
#: program is re-derived instead of served from the plan's own cache —
#: the module LRU and the per-plan memos stay coherent.
_CACHE_GENERATION = 0


def clear_compile_cache() -> None:
    """Drop every cached derivation — the module LRU *and* per-plan memos.

    Useful in long-running processes that sweep many large distinct plans
    and want the memory back deterministically.  Also invalidates the
    cached :meth:`Plan.exec_program` of every live plan (they re-derive on
    next use), so clearing really does release the lowered programs too.
    """
    global _CACHE_GENERATION
    with _DERIVE_CACHE_LOCK:
        _DERIVE_CACHE.clear()
        _CACHE_GENERATION += 1
        _DERIVE_CACHE_STATS["clears"] += 1


def compile_cache_stats() -> dict[str, int]:
    """Snapshot of the derive-cache counters (entries, hits, misses, …)."""
    with _DERIVE_CACHE_LOCK:
        return dict(_DERIVE_CACHE_STATS, entries=len(_DERIVE_CACHE))


def _instance_key(inst: DistributedWorkflowInstance) -> tuple:
    """Stable hashable fingerprint of everything but the step mapping."""
    return (
        inst.workflow,
        inst.data,
        tuple(sorted(inst.placement.items())),
        tuple(sorted(inst.initial_data.items())),
        inst.locations,
    )


def _placement_key(mapping: Mapping[str, Sequence[str]]) -> tuple:
    return tuple(sorted((s, tuple(ls)) for s, ls in mapping.items()))


def _derive_plan(
    inst: DistributedWorkflowInstance,
    rules: Sequence[str],
    *,
    schedule_report: "ScheduleReport | None" = None,
) -> "Plan":
    """Encode ``inst`` and apply ``rules``, through the compile cache."""
    t0 = time.perf_counter()
    key = (_instance_key(inst), tuple(rules), _placement_key(inst.mapping))
    with _DERIVE_CACHE_LOCK:
        hit = _DERIVE_CACHE.get(key)
        if hit is not None:
            _DERIVE_CACHE.move_to_end(key)
            _DERIVE_CACHE_STATS["hits"] += 1
        else:
            _DERIVE_CACHE_STATS["misses"] += 1
    if hit is not None:
        system, origin, rewrites = hit
        return Plan(
            system=system,
            instance=inst,
            origin=origin,
            rewrites=rewrites,
            schedule_report=schedule_report,
            timings=(("derive (cached)", time.perf_counter() - t0),),
        )
    plan = _traced(inst)
    if rules:
        plan = plan.optimize(rules)
    if schedule_report is not None:
        plan = replace(plan, schedule_report=schedule_report)
    with _DERIVE_CACHE_LOCK:
        _DERIVE_CACHE[key] = (plan.system, plan.origin, plan.rewrites)
        while len(_DERIVE_CACHE) > _DERIVE_CACHE_MAX:
            _DERIVE_CACHE.popitem(last=False)
            _DERIVE_CACHE_STATS["evictions"] += 1
    return plan


# ---------------------------------------------------------------------------
# Plan — the traced (and possibly optimised) SWIRL system
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AppliedRewrite:
    """One optimisation rule application with its removal statistics."""

    rule: str
    stats: OptimizationStats


@dataclass(frozen=True)
class BisimCertificate:
    """Mechanical Thm.-1 evidence that optimisation preserved behaviour."""

    equivalent: bool
    method: str = "weak-barbed-bisimulation"
    states_original: int = 0
    states_optimized: int = 0


@dataclass(frozen=True)
class Plan:
    """A traced SWIRL system, optionally rewritten, ready to lower.

    Immutable: :meth:`optimize` and :meth:`lower` return new values, so one
    trace can fan out to many backends/rule sets.
    """

    system: WorkflowSystem
    instance: DistributedWorkflowInstance | None = None
    origin: WorkflowSystem | None = None  # pre-optimisation system
    rewrites: tuple[AppliedRewrite, ...] = ()
    certificate: BisimCertificate | None = None
    schedule_report: ScheduleReport | None = None
    #: Per-phase wall-clock durations ``(label, seconds)`` in the order the
    #: phases ran — rendered by :meth:`explain`.
    timings: tuple[tuple[str, float], ...] = ()

    # -- optimisation -------------------------------------------------------
    def optimize(
        self,
        rules: Sequence[str] = ("R1R2",),
        *,
        certify: bool = False,
        max_states: int = 20_000,
    ) -> "Plan":
        """Apply rewriting rules (Def. 15 and beyond) in order.

        ``rules`` names entries of
        :data:`repro.core.optimizer.REWRITE_RULES` — ``"R1R2"`` is the
        paper's local+duplicate communication removal, ``"R3"`` the
        spatial-constraint deduplication.  With ``certify=True`` the result
        carries a :class:`BisimCertificate` checking ``W ≈ ⟦W⟧`` exactly
        (exponential in system size — keep certified systems small).

        Rules with a flat-engine implementation run as one pipeline over
        the flat IR (one flatten, one tree reconstruction for the whole
        list); anything else falls back to per-rule tree rewriting.
        """
        from repro.core.flat import FLAT_RULES, rewrite_flat_pipeline

        for rule in rules:
            if rule not in REWRITE_RULES:
                raise ValueError(
                    f"unknown rewrite rule {rule!r}; "
                    f"known: {sorted(REWRITE_RULES)}"
                )
        system = self.system
        applied = list(self.rewrites)
        timings = list(self.timings)
        rules = tuple(rules)
        if rules and all(r in FLAT_RULES for r in rules):
            t0 = time.perf_counter()
            system, stats_list = rewrite_flat_pipeline(system, rules)
            timings.append(
                (f"rewrite:{'+'.join(rules)}", time.perf_counter() - t0)
            )
            applied.extend(
                AppliedRewrite(rule, stats)
                for rule, stats in zip(rules, stats_list)
            )
        else:
            for rule in rules:
                t0 = time.perf_counter()
                system, stats = REWRITE_RULES[rule](system)
                timings.append(
                    (f"rewrite:{rule}", time.perf_counter() - t0)
                )
                applied.append(AppliedRewrite(rule, stats))
        plan = replace(
            self,
            system=system,
            origin=self.origin if self.origin is not None else self.system,
            rewrites=tuple(applied),
            certificate=None,
            timings=tuple(timings),
        )
        return plan.certify(max_states=max_states) if certify else plan

    def certify(self, *, max_states: int = 20_000) -> "Plan":
        """Attach Thm.-1 evidence that this plan ≈ its unoptimised origin."""
        from repro.core.bisim import weak_barbed_bisimilar
        from repro.core.semantics import reachable_states

        origin = self.origin if self.origin is not None else self.system
        cert = BisimCertificate(
            equivalent=weak_barbed_bisimilar(
                origin, self.system, max_states=max_states
            ),
            states_original=len(
                reachable_states(origin, max_states=max_states)
            ),
            states_optimized=len(
                reachable_states(self.system, max_states=max_states)
            ),
        )
        if not cert.equivalent:
            raise AssertionError(
                "optimisation broke weak barbed bisimilarity — this is a "
                "bug in the rewrite rules"
            )
        return replace(self, certificate=cert)

    # -- aggregates ---------------------------------------------------------
    @property
    def stats(self) -> OptimizationStats:
        """Merged removal statistics across every applied rewrite."""
        total = OptimizationStats()
        for r in self.rewrites:
            total.removed_local += r.stats.removed_local
            total.removed_duplicate += r.stats.removed_duplicate
            total.kept += r.stats.kept
            for loc, n in r.stats.by_location.items():
                total.by_location[loc] = total.by_location.get(loc, 0) + n
        return total

    def steps(self) -> tuple[str, ...]:
        """Every step name executed anywhere in the system."""
        cached = self.__dict__.get("_steps")
        if cached is None:
            cached = tuple(sorted(self.placement()))
            self.__dict__["_steps"] = cached
        return cached

    def placement(self) -> dict[str, tuple[str, ...]]:
        """Step → locations, from the exec predicates (``M`` reconstructed)."""
        cached = self.__dict__.get("_placement")
        if cached is None:
            cached = {}
            for cfg in self.system.configs:
                for a in actions(cfg.trace):
                    if isinstance(a, Exec):
                        cached[a.step] = tuple(sorted(a.locations))
            self.__dict__["_placement"] = cached
        return dict(cached)

    def fingerprint(self) -> str:
        """Content-addressed identity of this plan (stable public API).

        A hex SHA-256 digest of the canonical ``.swirl`` text of the
        (possibly rewritten) system plus the names of the rewrite rules
        applied to reach it.  The contract:

        * **Equality** — two plans whose systems are equal (same traces,
          same placement ``M``, same data scopes) and that were optimised
          with the same rule list have equal fingerprints, across
          processes and sessions (no ``PYTHONHASHSEED`` dependence).
        * **Sensitivity** — anything that changes the lowered artifact
          changes the fingerprint: a different step→location placement, a
          rewrite that removes communications, added/removed steps or
          data.  Applying a rule that happens to be a no-op still changes
          the fingerprint (the rule list is part of the identity), so a
          fingerprint names one *pipeline output*, not an equivalence
          class.
        * **Versioning** — stable within a release of this package; the
          leading ``swirl-plan-v1`` tag is bumped if the canonical text or
          encoding ever changes, so digests from different contracts can
          never collide silently.

        This is the key of the serving gateway's content-addressed plan
        cache (:mod:`repro.serve`): submit once, then address the compiled
        artifact by fingerprint.
        """
        cached = self.__dict__.get("_fingerprint")
        if cached is None:
            from repro.core.parser import dumps

            h = hashlib.sha256()
            h.update(b"swirl-plan-v1\n")
            h.update(",".join(r.rule for r in self.rewrites).encode())
            h.update(b"\n")
            h.update(dumps(self.system).encode())
            cached = h.hexdigest()
            self.__dict__["_fingerprint"] = cached
        return cached

    def exec_program(self):
        """The plan lowered to the execution IR (:mod:`repro.exec`).

        Computed once per plan and shared by every backend lowered from it
        (the per-location op arrays are backend-agnostic), so fanning one
        plan out to several backends — or compiling several Executables —
        never re-derives the programs.  :func:`clear_compile_cache`
        invalidates the memo (the stored generation stamp goes stale) so
        the module LRU and per-plan caches release memory together.
        """
        from repro.exec.program import lower_system

        cached = self.__dict__.get("_exec_program")
        if cached is not None and cached[0] == _CACHE_GENERATION:
            return cached[1]
        t0 = time.perf_counter()
        program = lower_system(self.system, schedule=self.schedule_report)
        self._record_phase("lower", time.perf_counter() - t0)
        self.__dict__["_exec_program"] = (_CACHE_GENERATION, program)
        return program

    def _record_phase(self, label: str, seconds: float) -> None:
        """Memoised side-channel for post-construction phase timings.

        ``timings`` is frozen at derive time; the lower/compile stages run
        later (and at most once each, thanks to memoisation), so they land
        in a mutable memo rendered by :meth:`explain` and
        :meth:`phase_timings` alongside the frozen entries.
        """
        self.__dict__.setdefault("_phase_timings", {})[label] = seconds

    def phase_timings(self) -> tuple[tuple[str, float], ...]:
        """Every recorded pipeline phase: derive-time + lower/compile."""
        extra = self.__dict__.get("_phase_timings") or {}
        return self.timings + tuple(extra.items())

    # -- scheduling ---------------------------------------------------------
    def schedule(
        self,
        network: NetworkModel | None = None,
        *,
        objective: str = "makespan",
        steps: Mapping[str, StepFn | StepMeta] | None = None,
        sizes: SizeModel | None = None,
        costs: CostModel | None = None,
        refine: bool = True,
        pin: Sequence[str] = (),
        max_evals: int | None = None,
    ) -> "Plan":
        """Choose ``M(s)`` against a network cost model (``repro.sched``).

        Runs critical-path greedy placement plus local-search refinement,
        re-encodes the instance under the chosen mapping, and re-runs the
        optimiser (the recorded rewrite rules, or the paper's ``R1R2`` for
        a never-optimised plan) — the scheduler co-locates producers with
        consumers, which turns remote sends into local ones that R1 then
        deletes.  ``objective`` is ``"makespan"`` (simulated completion
        time; default) or ``"bytes"`` (cross-location traffic).

        Size/cost estimates come from ``sizes=``/``costs=`` or are
        harvested from ``steps=`` (the same registry handed to
        :meth:`Lowered.compile` — :class:`StepMeta.output_bytes` and
        :class:`StepMeta.expected_seconds`).  Spatially-constrained steps
        (``|M(s)| > 1``) and steps named in ``pin=`` are never moved.

        The result carries a :class:`~repro.sched.ScheduleReport`
        (``plan.schedule_report``, rendered by :meth:`explain`) comparing
        the chosen placement against round-robin.
        """
        if self.instance is None:
            raise ValueError(
                "schedule() needs a Plan traced from a front-end instance "
                "(not raw .swirl text or a WorkflowSystem)"
            )
        metas = {
            name: spec
            for name, spec in (steps or {}).items()
            if isinstance(spec, StepMeta)
        }
        if sizes is None:
            sizes = SizeModel.from_step_metas(metas) if metas else SizeModel()
        if costs is None:
            costs = CostModel.from_step_metas(metas) if metas else CostModel()
        # Re-run the optimiser on the scheduled plan: co-location turns
        # remote sends into local ones that R1 deletes.  The same rule list
        # is passed to the search so candidates are scored on exactly the
        # system that will be lowered; a never-optimised plan gets the
        # paper's default rule set.
        rules = tuple(r.rule for r in self.rewrites) or ("R1R2",)
        t0 = time.perf_counter()
        report = auto_placement(
            self.instance,
            network,
            objective=objective,
            sizes=sizes,
            costs=costs,
            refine=refine,
            pin=pin,
            rules=rules,
            max_evals=max_evals,
        )
        sched_dt = time.perf_counter() - t0
        inst = replace(self.instance, mapping=dict(report.placement))
        plan = _derive_plan(inst, rules, schedule_report=report)
        return replace(
            plan, timings=(("schedule", sched_dt),) + plan.timings
        )

    # -- lowering -----------------------------------------------------------
    def lower(
        self,
        backend: str = "threaded",
        *,
        placement: Mapping[str, Sequence[str]] | str | None = None,
        network: NetworkModel | None = None,
        objective: str = "makespan",
        **options: Any,
    ) -> "Lowered":
        """Select an execution backend (and optionally re-place steps).

        ``placement`` overrides the step→locations mapping ``M`` and
        re-derives the plan (re-encode + re-apply the recorded rewrites) —
        the Jaradat-style separation of plan construction from placement.
        ``placement="auto"`` instead runs the cost-model-driven scheduler
        (:meth:`schedule`) against ``network=`` (default: the ``uniform``
        preset) and ``objective=``.  Backend-specific ``options`` (channel
        fault injection, retry policies, device lists, the ``multiprocess``
        backend's ``workers=``/``transport=``/``start_method=``…) are
        validated here, before any execution; a schedule report, when
        present, is handed down to every backend as the uniform
        ``schedule`` option (the multiprocess backend pins each network
        group's locations to one worker process).
        """
        if isinstance(placement, str):
            if placement != "auto":
                raise ValueError(
                    "placement must be a mapping or the string 'auto', "
                    f"got {placement!r}"
                )
            plan = self.schedule(network, objective=objective)
        else:
            if network is not None or objective != "makespan":
                raise TypeError(
                    "network=/objective= are only meaningful with "
                    "placement='auto' (or use Plan.schedule directly)"
                )
            plan = self._replaced(placement) if placement else self
        b = get_backend(backend)
        if (
            plan.schedule_report is not None
            and "schedule" in b.known_options()
        ):
            # Uniform hand-down; skipped for backends whose known_options
            # override predates (or deliberately excludes) the scheduler.
            options.setdefault("schedule", plan.schedule_report)
        b.validate_options(options)
        return Lowered(plan=plan, backend_name=backend, options=dict(options))

    def _replaced(
        self, placement: Mapping[str, Sequence[str]]
    ) -> "Plan":
        if self.instance is None:
            raise ValueError(
                "placement override needs a Plan traced from a front-end "
                "instance (not raw .swirl text or a WorkflowSystem)"
            )
        unknown = set(placement) - set(self.instance.mapping)
        if unknown:
            raise ValueError(
                f"placement names unknown steps {sorted(unknown)}; "
                f"steps are {sorted(self.instance.mapping)}"
            )
        new_mapping = {
            s: tuple(placement.get(s, ls))
            for s, ls in self.instance.mapping.items()
        }
        locations = frozenset(l for ls in new_mapping.values() for l in ls)
        inst = replace(
            self.instance,
            locations=locations,
            mapping=new_mapping,
            initial_data={
                l: ds
                for l, ds in self.instance.initial_data.items()
                if l in locations
            },
        )
        return _derive_plan(inst, tuple(r.rule for r in self.rewrites))

    # -- introspection ------------------------------------------------------
    def profile(
        self,
        result: Any,
        *,
        network: NetworkModel | None = None,
        sizes: SizeModel | None = None,
        costs: CostModel | None = None,
        exec_slots: int | None = None,
    ) -> "Any":
        """Align a traced run against this plan's predicted timeline.

        ``result`` is a traced :class:`~repro.backends.base.ExecutionResult`
        (from an Executable lowered with ``trace=True``) or a bare
        :class:`repro.obs.RunProfile`.  Replays the plan through the sched
        simulator under the given models (defaults match
        :func:`repro.sched.simulate`) and returns a
        :class:`repro.obs.ProfileReport` with per-step predicted-vs-actual
        drift and achieved-vs-predicted cross-location bytes.
        """
        from repro.obs.profile import RunProfile, align

        prof = getattr(result, "profile", result)
        if not isinstance(prof, RunProfile):
            raise ValueError(
                "result carries no RunProfile — run it on an Executable "
                'lowered with trace=True (e.g. plan.lower("threaded", '
                "trace=True))"
            )
        return align(
            self,
            prof,
            network=network,
            sizes=sizes,
            costs=costs,
            exec_slots=exec_slots,
        )

    def explain(self) -> str:
        """Human-readable report: trace, rewrites applied, placement."""
        lines = ["== SWIRL plan =="]
        lines.append(
            f"locations: {len(self.system.locations())}  "
            f"actions: {self.system.total_actions()}  "
            f"communications: {self.system.comm_count()}"
        )
        lines.append("")
        lines.append("-- placement (step -> M(s)) --")
        for s, locs in sorted(self.placement().items()):
            lines.append(f"  {s:<24} {', '.join(locs)}")
        lines.append("")
        lines.append("-- rewrites applied --")
        if not self.rewrites:
            lines.append("  (none — unoptimised plan)")
        for r in self.rewrites:
            lines.append(
                f"  {r.rule:<6} removed {r.stats.removed:>4} "
                f"(local {r.stats.removed_local}, "
                f"duplicate {r.stats.removed_duplicate})"
            )
        if self.certificate is not None:
            c = self.certificate
            lines.append(
                f"  certificate: {c.method} equivalent={c.equivalent} "
                f"({c.states_original} -> {c.states_optimized} states)"
            )
        if self.schedule_report is not None:
            lines.append("")
            lines.append("-- schedule --")
            for row in self.schedule_report.summary().splitlines():
                lines.append(f"  {row}")
        lines.append("")
        lines.append("-- timings --")
        timings = self.phase_timings()
        if not timings:
            lines.append("  (none recorded — plan built from raw syntax)")
        for label, seconds in timings:
            lines.append(f"  {label:<24} {seconds * 1e3:9.2f} ms")
        lines.append("")
        lines.append("-- per-location traces --")
        lines.append(self.system.pretty())
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Lowered — plan × backend, awaiting step bodies
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Lowered:
    """A plan bound to a backend; :meth:`compile` attaches step bodies.

    The plan's per-location program IR (:meth:`Plan.exec_program`) is
    shared by every ``Lowered``/``Executable`` derived from the same plan —
    lowering is paid once, backends only attach their interpreter.
    """

    plan: Plan
    backend_name: str
    options: dict[str, Any] = field(default_factory=dict)

    def compile(
        self, steps: Mapping[str, StepFn | StepMeta]
    ) -> "Executable":
        """Attach step bodies (callables or :class:`StepMeta`) and compile.

        ``steps`` must cover every exec predicate in the plan; extra
        entries are ignored (one registry can serve many plans).
        """
        metas: dict[str, StepMeta] = {}
        needed = set(self.plan.steps())
        missing = needed - set(steps)
        if missing:
            raise KeyError(
                f"no step function registered for {sorted(missing)}"
            )
        for name in sorted(needed):
            spec = steps[name]
            metas[name] = (
                spec if isinstance(spec, StepMeta) else StepMeta(fn=spec)
            )
        backend = get_backend(self.backend_name)
        exec_program = self.plan.exec_program()  # memoised; times "lower"
        t0 = time.perf_counter()
        program = backend.compile(exec_program, metas, self.options)
        self.plan._record_phase(
            f"compile[{self.backend_name}]", time.perf_counter() - t0
        )
        return Executable(
            plan=self.plan,
            backend_name=self.backend_name,
            program=program,
        )


# ---------------------------------------------------------------------------
# Executable — the runnable artifact
# ---------------------------------------------------------------------------


@dataclass
class Executable:
    """A compiled workflow: run it (once or in batches), snapshot, resume.

    One Executable owns one :class:`BackendProgram`.  Whether *whole runs*
    may overlap is the backend's call
    (:meth:`~repro.backends.base.BackendProgram.concurrent_batches`):

    * backends whose runs are fully isolated (the ``threaded`` backend —
      fresh per-run transports, per-batch/per-instance endpoint
      namespaces) serve any number of concurrent :meth:`run`/
      :meth:`run_many` calls on one compiled Executable, which is what
      the serving gateway's cache-hit hot path relies on;
    * backends whose runs mutate program-level state (``inprocess``
      snapshot slots, the ``multiprocess`` worker fleet, ``jax`` device
      buffers) keep the exclusive guard — a second overlapping run raises
      :class:`ConcurrentRunError` (compile another Executable from the
      same :class:`Lowered` to run concurrently).

    In both regimes a :meth:`run_many` batch counts as one run — its
    *internal* instance parallelism happens below the guard and is never
    rejected.
    """

    plan: Plan
    backend_name: str
    program: BackendProgram
    _run_lock: threading.Lock = field(
        default_factory=threading.Lock, repr=False, compare=False
    )
    _active_runs: int = field(default=0, repr=False, compare=False)

    @property
    def concurrent_runs(self) -> bool:
        """True when whole runs on this Executable may safely overlap."""
        return self.program.concurrent_batches()

    @property
    def active_runs(self) -> int:
        """Whole runs currently in flight (introspection/drain support)."""
        with self._run_lock:
            return self._active_runs

    def _enter_run(self, what: str) -> None:
        with self._run_lock:
            if self._active_runs and not self.program.concurrent_batches():
                raise ConcurrentRunError(
                    f"this Executable ({self.backend_name!r}) is already "
                    f"running; an overlapping {what} would share one "
                    "mutable BackendProgram — wait for the in-flight run, "
                    "or compile() another Executable from the same Lowered"
                )
            self._active_runs += 1

    def _exit_run(self) -> None:
        with self._run_lock:
            self._active_runs -= 1

    def run(
        self,
        *,
        initial_payloads: Mapping[PayloadKey, Any] | None = None,
    ) -> ExecutionResult:
        self._enter_run("run")
        try:
            return self._with_phases(self.program.run(initial_payloads))
        finally:
            self._exit_run()

    def _with_phases(self, result: ExecutionResult) -> ExecutionResult:
        """Stamp a traced result's profile with the plan's phase timings."""
        if result.profile is not None:
            result.profile = result.profile.with_phases(
                self.plan.phase_timings()
            )
        return result

    def run_many(
        self,
        inputs: Sequence[Mapping[PayloadKey, Any] | None],
        *,
        max_concurrent: int = 8,
    ) -> list[ExecutionResult]:
        """Run one workflow instance per entry of ``inputs``, compile-once.

        Every instance executes against this Executable's already-lowered
        program — encode, rewrite, lower and compile are amortised across
        the batch, transports are shared where the backend supports it, and
        at most ``max_concurrent`` instances are in flight at a time.
        Results come back in input order.  The whole batch holds the
        re-entry guard: concurrent ``run_many`` batches (or a concurrent
        :meth:`run`) on one Executable raise :class:`ConcurrentRunError`;
        the batch's internal concurrency does not.
        """
        self._enter_run("run_many batch")
        try:
            return [
                self._with_phases(r)
                for r in self.program.run_many(
                    list(inputs), max_concurrent=max_concurrent
                )
            ]
        finally:
            self._exit_run()

    def run_async(
        self,
        *,
        initial_payloads: Mapping[PayloadKey, Any] | None = None,
    ) -> Future:
        """Run on a daemon thread; the returned future yields the result.

        Daemon so an abandoned (hung) run never blocks interpreter exit.
        """
        fut: Future = Future()

        def worker() -> None:
            if not fut.set_running_or_notify_cancel():
                return
            try:
                fut.set_result(self.run(initial_payloads=initial_payloads))
            except BaseException as e:  # noqa: BLE001
                fut.set_exception(e)

        threading.Thread(
            target=worker, name="swirl-run-async", daemon=True
        ).start()
        return fut

    def checkpoint(self):
        """Consistent snapshot (backends advertising ``"checkpoint"``)."""
        return self.program.checkpoint()

    def restore(self, ckpt) -> "Executable":
        """Resume from a snapshot: the next :meth:`run` continues it."""
        self.program.restore(ckpt)
        return self
