"""Mixture-of-Experts FFN: token-choice top-k routing with capacity.

Dispatch is scatter-based (TPU-friendly, EP-shardable):

1. router logits → top-k (expert, weight) choices per token;
2. each choice gets a *slot* inside its expert's capacity buffer, computed
   with a running count (cumsum over the flattened choice list) — choices
   beyond capacity ``C = ceil(T·k/E · capacity_factor)`` are dropped (their
   tokens fall through the residual, standard Switch behaviour);
3. ``x`` rows are scattered into the ``[E, C, d]`` buffer, experts run as one
   batched gated-MLP einsum (sharded on the expert axis = EP), and results
   are gathered back and combined with the routing weights.

The auxiliary load-balance loss (Switch §2.2 form) is returned so the train
step can add ``router_aux_weight ×`` it.
"""

from __future__ import annotations

import inspect
import math
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import init_linear, linear

Params = dict[str, Any]


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p: Params = {
        "router": init_linear(ks[0], d, m.n_experts, jnp.float32),
        "gate": (jax.random.normal(ks[1], (m.n_experts, d, m.d_expert)) * scale).astype(dtype),
        "up": (jax.random.normal(ks[2], (m.n_experts, d, m.d_expert)) * scale).astype(dtype),
        "down": (
            jax.random.normal(ks[3], (m.n_experts, m.d_expert, d))
            * (1.0 / math.sqrt(m.d_expert))
        ).astype(dtype),
    }
    if m.n_shared:
        d_sh = m.n_shared * m.d_expert
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "gate": init_linear(kss[0], d, d_sh, dtype),
            "up": init_linear(kss[1], d, d_sh, dtype),
            "down": init_linear(kss[2], d_sh, d, dtype),
        }
    return p


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = math.ceil(n_tokens * m.top_k / m.n_experts * m.capacity_factor)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def apply_moe(
    cfg: ModelConfig, p: Params, x: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """x: [B, L, d] → (y, aux_loss)."""
    m = cfg.moe
    b, l, d = x.shape
    t = b * l
    k = m.top_k
    xf = x.reshape(t, d)

    logits = linear(p["router"], xf.astype(jnp.float32))  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, k)  # [T, k]
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # Load-balance auxiliary loss (Switch): E · Σ_e f_e · P_e
    density = jnp.mean(
        jnp.sum(jax.nn.one_hot(experts, m.n_experts), axis=1), axis=0
    )  # fraction of tokens whose choice set includes e (×k)
    mean_prob = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(density / k * mean_prob)

    cap = moe_capacity(cfg, t)

    # Slot assignment: choice (t, j) takes the next free slot of its expert.
    flat_e = experts.reshape(t * k)  # [T·k]
    onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)  # [T·k, E]
    pos = jnp.cumsum(onehot, axis=0) - onehot  # prior same-expert choices
    slot = jnp.sum(pos * onehot, axis=-1)  # [T·k]
    keep = slot < cap
    slot_c = jnp.where(keep, slot, cap - 1)

    # Scatter tokens into [E, C, d] (dropped rows contribute zero).
    xk = jnp.repeat(xf, k, axis=0)  # [T·k, d] (choice-major: token t rows t·k..)
    contrib = jnp.where(keep[:, None], xk, 0).astype(x.dtype)
    buf = jnp.zeros((m.n_experts, cap, d), x.dtype)
    buf = buf.at[flat_e, slot_c].add(contrib, mode="drop")

    # Batched expert gated-MLP (EP: leading expert axis shards on "model").
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["up"]
    )
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["down"])  # [E, C, d]

    # Gather back and combine.
    fetched = out_buf[flat_e, slot_c]  # [T·k, d]
    fetched = jnp.where(keep[:, None], fetched, 0)
    yk = fetched.reshape(t, k, d) * weights[..., None].astype(x.dtype)
    y = jnp.sum(yk, axis=1)

    if "shared" in p:
        sh = p["shared"]
        y = y + linear(
            sh["down"],
            jax.nn.silu(linear(sh["gate"], xf)) * linear(sh["up"], xf),
        )
    return y.reshape(b, l, d), aux


# ---------------------------------------------------------------------------
# H2 (hints): expert-local dispatch under shard_map
# ---------------------------------------------------------------------------
#
# Under pure GSPMD the capacity buffer is a GLOBAL [E, C_glob, d] tensor and
# the token→slot cumsum runs across the data-sharded token axis; XLA lowers
# the scatter/gather through whole-buffer all-reduces (~75 GB/layer on
# deepseek-moe-16b × train_4k).  But with TP-replicated activations no
# cross-shard dispatch is needed at all: each (dp, tp) device routes its
# LOCAL tokens, keeps only the choices owned by its LOCAL experts, runs a
# purely local scatter→expert-matmul→gather, and the partial outputs are
# summed with one psum over the TP axis.  Link traffic per layer drops from
# ~75 GB to one [B_loc, L, d] all-reduce.


def _local_moe_body(
    cfg: ModelConfig, tp_axis: str, tp_size: int, dp_axes, *, scatter_out: bool
):
    m = cfg.moe
    e_local = m.n_experts // tp_size

    def body(x_l, router, gate, up, down, shared):
        # x_l: [B_loc, L, d] (replicated over tp); gate/up/down: local experts
        b, l, d = x_l.shape
        t = b * l
        k = m.top_k
        xf = x_l.reshape(t, d)

        logits = xf.astype(jnp.float32) @ router  # router replicated [d, E]
        probs = jax.nn.softmax(logits, axis=-1)
        weights, experts = jax.lax.top_k(probs, k)
        weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

        # aux loss from globally-reduced router statistics
        density_l = jnp.mean(
            jnp.sum(jax.nn.one_hot(experts, m.n_experts), axis=1), axis=0
        )
        mean_prob_l = jnp.mean(probs, axis=0)
        # tokens are sharded over dp only; tp shards see identical stats.
        density = jax.lax.pmean(density_l, dp_axes)
        mean_prob = jax.lax.pmean(mean_prob_l, dp_axes)
        aux = m.n_experts * jnp.sum(density / k * mean_prob)

        # my expert range on this tp shard
        tp_idx = jax.lax.axis_index(tp_axis)
        e_start = tp_idx * e_local

        cap = moe_capacity(cfg, t)
        flat_e = experts.reshape(t * k)
        local_e = flat_e - e_start  # [T·k] in [0, e_local) if mine
        mine = (local_e >= 0) & (local_e < e_local)
        local_e_c = jnp.where(mine, local_e, 0)

        onehot = jax.nn.one_hot(local_e_c, e_local, dtype=jnp.int32)
        onehot = onehot * mine[:, None].astype(jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        slot = jnp.sum(pos * onehot, axis=-1)
        keep = mine & (slot < cap)
        slot_c = jnp.where(keep, slot, cap - 1)

        xk = jnp.repeat(xf, k, axis=0)
        contrib = jnp.where(keep[:, None], xk, 0).astype(x_l.dtype)
        buf = jnp.zeros((e_local, cap, d), x_l.dtype)
        buf = buf.at[local_e_c, slot_c].add(contrib, mode="drop")

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, gate)) * jnp.einsum(
            "ecd,edf->ecf", buf, up
        )
        out_buf = jnp.einsum("ecf,efd->ecd", h, down)

        fetched = out_buf[local_e_c, slot_c]
        fetched = jnp.where(keep[:, None], fetched, 0)
        yk = fetched.reshape(t, k, d) * weights[..., None].astype(x_l.dtype)
        y = jnp.sum(yk, axis=1)

        if shared is not None:
            # shared experts: column-parallel gate/up, row-parallel down —
            # their partial sum rides the same psum as the routed experts.
            sh_gate, sh_up, sh_down = shared
            hs = jax.nn.silu(xf @ sh_gate) * (xf @ sh_up)
            y = y + hs @ sh_down

        # One collective over TP for the whole MoE layer.  With an
        # SP residual stream the output is consumed sequence-sharded, so a
        # reduce-scatter over the token axis halves the traffic vs psum
        # (§Perf deepseek iter. 3).
        if scatter_out:
            y = jax.lax.psum_scatter(
                y.reshape(b, l, d), tp_axis, scatter_dimension=1, tiled=True
            )
            return y, aux
        y = jax.lax.psum(y, tp_axis)
        return y.reshape(b, l, d), aux

    return body


def apply_moe_sharded(
    cfg: ModelConfig, p: Params, x: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Expert-local MoE dispatch (requires installed ShardHints)."""
    try:
        from jax import shard_map
    except ImportError:  # older jax exposes it under experimental
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from .hints import get_hints

    h = get_hints()
    m = cfg.moe
    assert h is not None
    mesh = h.mesh
    tp, dp = h.tp_axis, h.dp_spec()
    none2 = P(None, None)

    shared = None
    shared_specs = (
        (P(None, tp), P(None, tp), P(tp, None)) if "shared" in p else None
    )
    if "shared" in p:
        shared = (
            p["shared"]["gate"]["w"],
            p["shared"]["up"]["w"],
            p["shared"]["down"]["w"],
        )

    dp_axes = h.dp_axes if len(h.dp_axes) > 1 else h.dp_axes[0]
    scatter_out = (
        h.seq_parallel_residual and x.shape[1] % (h.tp_size * h.tp_size) == 0
    )
    body = _local_moe_body(
        cfg, tp, h.tp_size, dp_axes, scatter_out=scatter_out
    )
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(dp, None, None),  # x
            none2,  # router (replicated)
            P(tp, None, None),  # gate [E, d, de] expert-sharded
            P(tp, None, None),  # up
            P(tp, None, None),  # down
            shared_specs,  # shared expert weights (column/row parallel)
        ),
        out_specs=(
            P(dp, tp if scatter_out else None, None),
            P(),
        ),
        # replication checking was renamed check_rep -> check_vma
        **(
            {"check_vma": False}
            if "check_vma" in inspect.signature(shard_map).parameters
            else {"check_rep": False}
        ),
    )
    return fn(
        x, p["router"]["w"], p["gate"], p["up"], p["down"], shared
    )
