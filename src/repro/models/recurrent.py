"""Recurrent mixers: Mamba selective scan, xLSTM mLSTM/sLSTM.

All sequence recurrences are *chunked*: within a chunk the recurrence is
evaluated with ``associative_scan``/``cummax``-based parallel forms (every
FLOP visible to ``cost_analysis``, no while-loops), and chunks are chained
through a small carried state — the same state used verbatim for O(1)
decoding at 500k context.  sLSTM is the one strictly sequential cell
(scalar memory with recurrent weights); its ``lax.scan`` is noted in the
roofline layer with an analytical FLOP correction.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import init_linear, linear

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Mamba (selective state-space) block
# ---------------------------------------------------------------------------


class MambaState(NamedTuple):
    conv: jax.Array  # [B, d_conv-1, d_inner] — trailing inputs
    ssm: jax.Array  # [B, d_inner, d_state]


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


def init_mamba(key, cfg: ModelConfig, dtype) -> Params:
    di, ds, dtr = d_inner(cfg), cfg.ssm.d_state, dt_rank(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialisation for A; dt bias init for softplus ≈ [1e-3, 1e-1]
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": init_linear(ks[0], cfg.d_model, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm.d_conv, di)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": init_linear(ks[2], di, dtr + 2 * ds, dtype),
        "dt_proj": {
            "w": (jax.random.normal(ks[3], (dtr, di)) / math.sqrt(dtr)).astype(dtype),
            "b": jnp.log(
                jnp.exp(
                    jnp.exp(
                        jax.random.uniform(ks[4], (di,))
                        * (math.log(0.1) - math.log(1e-3))
                        + math.log(1e-3)
                    )
                )
                - 1.0
            ).astype(jnp.float32),
        },
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": init_linear(ks[5], di, cfg.d_model, dtype),
    }


def _ssm_chunk_scan(
    abar_log: jax.Array,  # [B, c, di, ds] — log of decay exp(dt·A) (≤ 0)
    bu: jax.Array,  # [B, c, di, ds] — dt·B_t·u_t
    h0: jax.Array,  # [B, di, ds]
) -> tuple[jax.Array, jax.Array]:
    """h_t = exp(abar_log_t)·h_{t-1} + bu_t within one chunk.

    Parallel via associative scan on (decay, value) pairs.
    Returns (h per step [B, c, di, ds], final h).
    """

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al + ar, jnp.exp(ar) * bl + br

    a_acc, b_acc = jax.lax.associative_scan(combine, (abar_log, bu), axis=1)
    h = jnp.exp(a_acc) * h0[:, None] + b_acc
    return h, h[:, -1]


def mamba_mix(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,  # [B, L, d]
    state: MambaState | None = None,
) -> tuple[jax.Array, MambaState]:
    """Apply the Mamba mixer; returns (y [B, L, d], new state)."""
    b, l, _ = x.shape
    di, ds, dtr = d_inner(cfg), cfg.ssm.d_state, dt_rank(cfg)
    dc = cfg.ssm.d_conv

    xz = linear(p["in_proj"], x)  # [B, L, 2·di]
    u, z = jnp.split(xz, 2, axis=-1)

    # Depthwise causal conv over time (kernel dc), carrying dc-1 inputs.
    if state is None:
        conv_carry = jnp.zeros((b, dc - 1, di), u.dtype)
    else:
        conv_carry = state.conv
    u_ext = jnp.concatenate([conv_carry, u], axis=1)  # [B, L+dc-1, di]
    conv = sum(
        u_ext[:, i : i + l] * p["conv_w"][i][None, None, :] for i in range(dc)
    )
    u = jax.nn.silu(conv + p["conv_b"])
    new_conv_carry = u_ext[:, -(dc - 1) :] if dc > 1 else conv_carry

    # Input-dependent SSM parameters.
    xp = linear(p["x_proj"], u)  # [B, L, dtr+2·ds]
    dt_in, bmat, cmat = jnp.split(xp, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        dt_in.astype(jnp.float32) @ p["dt_proj"]["w"].astype(jnp.float32)
        + p["dt_proj"]["b"]
    )  # [B, L, di]
    a = -jnp.exp(p["A_log"])  # [di, ds]

    abar_log = dt[..., None] * a[None, None]  # [B, L, di, ds]  (≤ 0)
    bu = (dt * u.astype(jnp.float32))[..., None] * bmat.astype(jnp.float32)[
        :, :, None, :
    ]  # [B, L, di, ds]

    h0 = (
        jnp.zeros((b, di, ds), jnp.float32)
        if state is None
        else state.ssm.astype(jnp.float32)
    )
    chunk = min(cfg.ssm.chunk, l)
    ys = []
    for s in range(0, l, chunk):
        e = min(s + chunk, l)
        h, h0 = _ssm_chunk_scan(abar_log[:, s:e], bu[:, s:e], h0)
        ys.append(jnp.einsum("bcds,bcs->bcd", h, cmat[:, s:e].astype(jnp.float32)))
    y = jnp.concatenate(ys, axis=1) + p["D"] * u.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = linear(p["out_proj"], y)
    return out, MambaState(conv=new_conv_carry, ssm=h0.astype(x.dtype))


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    return MambaState(
        conv=jnp.zeros((batch, cfg.ssm.d_conv - 1, d_inner(cfg)), dtype),
        ssm=jnp.zeros((batch, d_inner(cfg), cfg.ssm.d_state), dtype),
    )


# ---------------------------------------------------------------------------
# xLSTM — mLSTM (matrix memory, parallel/chunked) and sLSTM (scalar memory)
# ---------------------------------------------------------------------------


class MLSTMState(NamedTuple):
    c: jax.Array  # [B, H, dk, dv] matrix memory
    n: jax.Array  # [B, H, dk] normalizer
    m: jax.Array  # [B, H] log-space stabilizer


class SLSTMState(NamedTuple):
    c: jax.Array  # [B, d]
    n: jax.Array  # [B, d]
    h: jax.Array  # [B, d]
    m: jax.Array  # [B, d]


def init_mlstm(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 6)
    d, qd = cfg.d_model, cfg.q_dim
    return {
        "q": init_linear(ks[0], d, qd, dtype),
        "k": init_linear(ks[1], d, qd, dtype),
        "v": init_linear(ks[2], d, qd, dtype),
        "i_gate": init_linear(ks[3], d, cfg.n_heads, jnp.float32),
        "f_gate": init_linear(ks[4], d, cfg.n_heads, jnp.float32),
        "o": init_linear(ks[5], qd, d, dtype),
    }


def _mlstm_chunk(
    q, k, v,  # [B, c, H, dh] (q pre-scaled by 1/sqrt(dh))
    li, lf,  # [B, c, H] log input gate preact / log-sigmoid forget
    state: MLSTMState,
) -> tuple[jax.Array, MLSTMState]:
    """Stabilised chunk-parallel mLSTM (xLSTM eqs. 19-27, chunked).

    For target t and source s ≤ t the contribution weight is
    ``exp(Σ_{r=s+1..t} lf_r + li_s − m_t)``; the carry from earlier chunks
    enters with weight ``exp(Σ_{r≤t} lf_r + m_prev − m_t)``.  ``m_t`` is the
    running log-max that keeps every exponent ≤ 0 (exactly the flash-
    attention trick applied to exponential gating).
    """
    b, c, h, dh = q.shape
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)

    cum = jnp.cumsum(lf, axis=1)  # [B, c, H] — Σ_{r≤t} lf_r
    g = li - cum  # per-source log weight −cum_s + li_s
    m_intra = jax.lax.cummax(g, axis=1) + cum  # max_{s≤t}(g_s) + cum_t
    m_inter = cum + state.m[:, None]
    m = jnp.maximum(m_intra, m_inter)  # [B, c, H]

    # Intra-chunk pairwise term.
    logits = jnp.einsum("bthd,bshd->bhts", q, k)  # [B, H, t, s]
    cum_t = cum.transpose(0, 2, 1)  # [B, H, c]
    g_s = g.transpose(0, 2, 1)
    m_t = m.transpose(0, 2, 1)
    w_log = cum_t[:, :, :, None] + g_s[:, :, None, :] - m_t[:, :, :, None]
    causal = jnp.tril(jnp.ones((c, c), bool))
    w = jnp.where(causal[None, None], jnp.exp(w_log), 0.0)
    scores = logits * w  # [B, H, t, s]

    num_intra = jnp.einsum("bhts,bshd->bthd", scores, v)  # [B, c, H, dh]
    den_intra = jnp.sum(scores, axis=-1).transpose(0, 2, 1)  # [B, c, H]

    # Inter-chunk (carry) term.
    w_inter = jnp.exp(jnp.minimum(cum + state.m[:, None] - m, 0.0))  # [B, c, H]
    num_inter = jnp.einsum("bthd,bhde->bthe", q, state.c) * w_inter[..., None]
    den_inter = jnp.einsum("bthd,bhd->bth", q, state.n) * w_inter

    num = num_intra + num_inter
    den = den_intra + den_inter
    y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]

    # Carry for the next chunk, stabilised at m_carry = m at the last step.
    m_carry = m[:, -1]  # [B, H]
    last_cum = cum[:, -1]
    w_old = jnp.exp(jnp.minimum(state.m + last_cum - m_carry, 0.0))
    w_src = jnp.exp(jnp.minimum(last_cum[:, None] + g - m_carry[:, None], 0.0))
    c_new = state.c * w_old[..., None, None] + jnp.einsum(
        "bshd,bshe,bsh->bhde", k, v, w_src
    )
    n_new = state.n * w_old[..., None] + jnp.einsum("bshd,bsh->bhd", k, w_src)
    return y, MLSTMState(c=c_new, n=n_new, m=m_carry)


def mlstm_mix(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    state: MLSTMState | None = None,
) -> tuple[jax.Array, MLSTMState]:
    b, l, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = linear(p["q"], x).reshape(b, l, h, dh) / math.sqrt(dh)
    k = linear(p["k"], x).reshape(b, l, h, dh)
    v = linear(p["v"], x).reshape(b, l, h, dh)
    li = linear(p["i_gate"], x.astype(jnp.float32))  # [B, L, H] log-space
    lf = jax.nn.log_sigmoid(linear(p["f_gate"], x.astype(jnp.float32)))

    if state is None:
        state = MLSTMState(
            c=jnp.zeros((b, h, dh, dh), jnp.float32),
            n=jnp.zeros((b, h, dh), jnp.float32),
            m=jnp.full((b, h), -1e30, jnp.float32),
        )
    chunk = min(cfg.ssm.chunk, l)
    ys = []
    for s in range(0, l, chunk):
        e = min(s + chunk, l)
        y, state = _mlstm_chunk(
            q[:, s:e], k[:, s:e], v[:, s:e], li[:, s:e], lf[:, s:e], state
        )
        ys.append(y)
    y = jnp.concatenate(ys, axis=1).astype(x.dtype).reshape(b, l, h * dh)
    return linear(p["o"], y), state


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    h, dh = cfg.n_heads, cfg.head_dim
    return MLSTMState(
        c=jnp.zeros((batch, h, dh, dh), jnp.float32),
        n=jnp.zeros((batch, h, dh), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
    )


def init_slstm(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    gates = {}
    # keys g_i/g_f/g_z/g_o: unambiguous vs. attention's o-projection in the
    # path-based sharding rules.
    for i, g in enumerate(("g_i", "g_f", "g_z", "g_o")):
        gates[g] = {
            "w": init_linear(ks[2 * i], d, d, dtype)["w"],
            "r": init_linear(ks[2 * i + 1], d, d, dtype)["w"],
            "b": jnp.zeros((d,), jnp.float32),
        }
    return gates


def slstm_mix(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    state: SLSTMState | None = None,
) -> tuple[jax.Array, SLSTMState]:
    """Sequential sLSTM cell with exponential gating (lax.scan over time)."""
    b, l, d = x.shape
    f32 = jnp.float32
    if state is None:
        state = init_slstm_state(cfg, b)

    # Precompute input contributions for all gates: [B, L, d] each.
    gate_names = ("g_i", "g_f", "g_z", "g_o")
    pre = {g: (x @ p[g]["w"]).astype(f32) + p[g]["b"] for g in gate_names}
    rw = {g: p[g]["r"].astype(f32) for g in gate_names}

    def step(carry: SLSTMState, inputs):
        c, n, h, m = carry
        xi, xf, xz, xo = inputs
        it = xi + h @ rw["g_i"]
        ft = xf + h @ rw["g_f"]
        zt = jnp.tanh(xz + h @ rw["g_z"])
        ot = jax.nn.sigmoid(xo + h @ rw["g_o"])
        m_new = jnp.maximum(ft + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(ft + m - m_new)
        c_new = f_p * c + i_p * zt
        n_new = f_p * n + i_p
        h_new = ot * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
        return SLSTMState(c_new, n_new, h_new, m_new), h_new

    xs = tuple(jnp.moveaxis(pre[g], 1, 0) for g in gate_names)
    new_state, hs = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # [B, L, d]
    return y, new_state


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, d), -1e30, jnp.float32))
