"""Model configuration — one dataclass covering all 10 assigned families.

A model is a stack of *blocks*.  ``prefix_pattern`` lists non-repeating
leading blocks (e.g. DeepSeek-MoE's dense layer 0); ``pattern`` is the
repeating unit (e.g. Gemma-2's ``(local, global)`` pair, Jamba's 8-layer
Mamba/attention/MoE period); ``repeats × len(pattern) + len(prefix_pattern)``
must equal ``n_layers``.  Blocks of the same pattern position are stacked and
scanned (`jax.lax.scan`) so the lowered HLO stays small for 80-layer models.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal

BlockKind = Literal[
    "attn",  # self-attention (causal or bidirectional per model kind)
    "attn_local",  # sliding-window self-attention
    "mlp",
    "moe",
    "mamba",
    "mlstm",
    "slstm",
]

Activation = Literal["silu_glu", "gelu_glu", "relu_sq", "gelu"]
NormKind = Literal["rmsnorm", "layernorm"]


@dataclass(frozen=True)
class MoECfg:
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    n_shared: int = 0  # always-active shared experts (DeepSeek-MoE)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight


@dataclass(frozen=True)
class SSMCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 1024  # selective-scan chunk length (memory/HLO trade-off)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # Block layout.  Each layer is "<mixer>+<ffn>" where mixer is one of
    # attn/attn_local/mamba/mlstm/slstm and ffn one of mlp/moe/none.
    # pattern entries are (mixer, ffn) pairs.
    prefix_pattern: tuple[tuple[str, str], ...] = ()
    pattern: tuple[tuple[str, str], ...] = (("attn", "mlp"),)

    # Attention details
    use_rope: bool = True  # Jamba: attention without positional encoding
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    sliding_window: int = 0  # used by attn_local
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    post_block_norm: bool = False  # Gemma-2 pre+post norms

    # FFN / embeddings
    activation: Activation = "silu_glu"
    norm: NormKind = "rmsnorm"
    tied_embeddings: bool = False
    embed_scale: bool = False  # Gemma-style sqrt(d) embedding multiplier

    moe: MoECfg = field(default_factory=MoECfg)
    ssm: SSMCfg = field(default_factory=SSMCfg)

    # Encoder-decoder (seamless-m4t): n_enc_layers encoder blocks with
    # bidirectional attention; decoder blocks gain cross-attention.
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    cross_attn: bool = False  # set on decoder blocks internally

    # Modality frontend stub: if set, the model consumes precomputed
    # embeddings of this length prepended (vlm) or as encoder input (audio).
    frontend: Literal["none", "vision", "audio"] = "none"
    frontend_len: int = 256  # patches / audio frames provided by input_specs

    # Numerics
    dtype: str = "bfloat16"
    remat: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        n_body = self.n_layers - len(self.prefix_pattern)
        if self.pattern and n_body % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: {n_body} body layers not divisible by "
                f"pattern of {len(self.pattern)}"
            )
        if not self.pattern and n_body != 0:
            raise ValueError(f"{self.name}: empty pattern with {n_body} body layers")

    # -- derived -------------------------------------------------------------
    @property
    def repeats(self) -> int:
        if not self.pattern:
            return 0
        return (self.n_layers - len(self.prefix_pattern)) // len(self.pattern)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_seq(self) -> tuple[tuple[str, str], ...]:
        """The full per-layer (mixer, ffn) sequence."""
        return self.prefix_pattern + self.pattern * self.repeats

    # -- parameter counting (used for MODEL_FLOPS and roofline) -------------
    def _mixer_params(self, mixer: str) -> int:
        d = self.d_model
        if mixer in ("attn", "attn_local"):
            p = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                p += self.q_dim + 2 * self.kv_dim
            return p
        if mixer == "mamba":
            di = self.ssm.expand * d
            ds = self.ssm.d_state
            dtr = max(1, d // 16)  # dt_rank
            return (
                d * 2 * di  # in_proj (x, z)
                + self.ssm.d_conv * di + di  # depthwise conv w + b
                + di * (dtr + 2 * ds)  # x_proj → (dt, B, C)
                + dtr * di + di  # dt_proj + bias
                + di * ds + di  # A_log, D
                + di * d  # out_proj
            )
        if mixer == "mlstm":
            # qkv + gates (i, f per head) + out
            return d * 3 * self.q_dim + 2 * d * self.n_heads + self.q_dim * d
        if mixer == "slstm":
            # recurrent cell: 4 gates × (input + recurrent) projections
            return 8 * d * d + 4 * d
        raise ValueError(mixer)

    def _ffn_params(self, ffn: str) -> int:
        d = self.d_model
        if ffn == "none":
            return 0
        if ffn == "mlp":
            mult = 3 if self.activation.endswith("_glu") else 2
            return mult * d * self.d_ff
        if ffn == "moe":
            m = self.moe
            mult = 3  # experts are gated MLPs
            routed = m.n_experts * mult * d * m.d_expert
            shared = m.n_shared * mult * d * m.d_expert
            router = d * m.n_experts
            return routed + shared + router
        if ffn == "dense0":  # DeepSeek layer-0 dense MLP (d_ff stored in d_ff)
            return 3 * self.d_model * self.d_ff
        raise ValueError(ffn)

    def param_count(self) -> int:
        d = self.d_model
        n = self.vocab * d  # embedding
        if not self.tied_embeddings:
            n += self.vocab * d  # lm head
        layers = self.layer_seq()
        if self.is_encoder_decoder:
            # encoder self-attn blocks + decoder (self + cross) blocks
            enc = self.n_enc_layers * (
                self._mixer_params("attn") + self._ffn_params("mlp") + 2 * d
            )
            dec = self.n_layers * (
                2 * self._mixer_params("attn") + self._ffn_params("mlp") + 3 * d
            )
            return n + enc + dec + d
        for mixer, ffn in layers:
            n += self._mixer_params(mixer) + self._ffn_params(ffn)
            n += 2 * d if ffn != "none" else d  # norms
            if self.post_block_norm:
                n += 2 * d
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not any(f == "moe" for _, f in self.layer_seq()):
            return self.param_count()
        d, m = self.d_model, self.moe
        inactive_experts = m.n_experts - m.top_k
        per_moe_layer = inactive_experts * 3 * d * m.d_expert
        n_moe = sum(1 for _, f in self.layer_seq() if f == "moe")
        return self.param_count() - n_moe * per_moe_layer


def unrolled_variant(cfg: ModelConfig, *, ssm_chunk: int | None = None) -> ModelConfig:
    """All layers in ``prefix_pattern`` (no scan) — used by the dry-run so
    ``cost_analysis`` / HLO collective parsing see every layer (a scanned
    body is a while-loop whose cost is counted once)."""
    kw = dict(prefix_pattern=cfg.layer_seq(), pattern=())
    if ssm_chunk is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, chunk=ssm_chunk)
    return dataclasses.replace(cfg, **kw)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """A reduced same-family config for CPU smoke tests."""
    pat = cfg.pattern
    prefix = cfg.prefix_pattern
    n_layers = len(prefix) + len(pat)  # one repeat of the pattern
    moe = cfg.moe
    if moe.n_experts:
        moe = dataclasses.replace(
            moe,
            n_experts=max(4, moe.top_k + 1) if moe.n_experts > 4 else moe.n_experts,
            top_k=min(moe.top_k, 2),
            d_expert=32,
        )
    head_dim = 16
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        moe=moe,
        ssm=dataclasses.replace(cfg.ssm, chunk=16),
        frontend_len=8 if cfg.frontend != "none" else cfg.frontend_len,
        remat=False,
        dtype="float32",
    )
