"""Shared neural layers: norms, RoPE, MLPs, and GQA attention.

Attention is implemented "flash-style" in pure JAX: the query axis is
processed in Python-unrolled chunks so the ``[Lq, Lk]`` score tensor never
exceeds ``q_chunk × Lk`` — this bounds live memory at 32k prefill and keeps
every FLOP visible to ``cost_analysis`` (no while-loops hiding work).  The
Pallas TPU kernel (:mod:`repro.kernels`) is a drop-in replacement selected
with ``attn_impl="pallas"`` on real TPUs.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig

Params = dict[str, Any]

# "xla" (default, compiles everywhere) or "pallas" (TPU kernels).
_ATTN_IMPL = "xla"


def set_attn_impl(impl: str) -> None:
    global _ATTN_IMPL
    assert impl in ("xla", "pallas"), impl
    _ATTN_IMPL = impl


def get_attn_impl() -> str:
    return _ATTN_IMPL


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["w"])
    return layer_norm(x, p["w"], p["b"])


def init_norm(cfg: ModelConfig, dtype) -> Params:
    if cfg.norm == "rmsnorm":
        return {"w": jnp.zeros((cfg.d_model,), dtype)}
    return {"w": jnp.ones((cfg.d_model,), dtype), "b": jnp.zeros((cfg.d_model,), dtype)}


# ---------------------------------------------------------------------------
# Linear / init helpers
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, dtype, *, bias: bool = False) -> Params:
    scale = 1.0 / math.sqrt(d_in)
    p: Params = {
        "w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)
    }
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, L, H, D]; positions: [B, L] or [L]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, L, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    if cfg.activation.endswith("_glu"):
        return {
            "gate": init_linear(ks[0], cfg.d_model, d_ff, dtype),
            "up": init_linear(ks[1], cfg.d_model, d_ff, dtype),
            "down": init_linear(ks[2], d_ff, cfg.d_model, dtype),
        }
    return {
        "up": init_linear(ks[0], cfg.d_model, d_ff, dtype),
        "down": init_linear(ks[1], d_ff, cfg.d_model, dtype),
    }


def apply_mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.activation == "silu_glu":
        h = jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x)
    elif cfg.activation == "gelu_glu":
        h = jax.nn.gelu(linear(p["gate"], x), approximate=True) * linear(p["up"], x)
    elif cfg.activation == "relu_sq":
        h = jnp.square(jax.nn.relu(linear(p["up"], x)))
    elif cfg.activation == "gelu":
        h = jax.nn.gelu(linear(p["up"], x), approximate=True)
    else:
        raise ValueError(cfg.activation)
    return linear(p["down"], h)


# ---------------------------------------------------------------------------
# Attention (GQA; causal / bidirectional / sliding window / cross; softcap)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "q": init_linear(ks[0], cfg.d_model, cfg.q_dim, dtype, bias=cfg.qkv_bias),
        "k": init_linear(ks[1], cfg.d_model, cfg.kv_dim, dtype, bias=cfg.qkv_bias),
        "v": init_linear(ks[2], cfg.d_model, cfg.kv_dim, dtype, bias=cfg.qkv_bias),
        "o": init_linear(ks[3], cfg.q_dim, cfg.d_model, dtype),
    }


def _sdpa_chunk(
    q: jax.Array,  # [B, c, Hkv, G, D] fp32-scaled queries
    k: jax.Array,  # [B, Lk, Hkv, D]
    v: jax.Array,  # [B, Lk, Hkv, D]
    q_pos: jax.Array,  # [c] (or [B, c]) absolute positions of the q rows
    k_pos: jax.Array,  # [Lk]
    kv_valid: Optional[jax.Array],  # [] or [B] — #valid cache rows, or None
    *,
    causal: bool,
    window: int,
    softcap: float,
) -> jax.Array:
    scores = jnp.einsum(
        "bchgd,bkhd->bchgk", q, k, preferred_element_type=jnp.float32
    )
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap
    qp = q_pos if q_pos.ndim == 2 else q_pos[None, :]  # [B?, c]
    kp = k_pos[None, None, :]  # [1, 1, Lk]
    mask = jnp.ones((qp.shape[0], qp.shape[1], k_pos.shape[0]), bool)
    if causal:
        mask &= qp[:, :, None] >= kp
    if window > 0:
        mask &= qp[:, :, None] - kp < window
    if kv_valid is not None:
        kv = jnp.asarray(kv_valid)
        kv = kv[:, None, None] if kv.ndim == 1 else kv[None, None, None]
        mask &= kp < kv
    scores = jnp.where(mask[:, :, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bchgk,bkhd->bchgd", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.astype(v.dtype)


def sdpa(
    q: jax.Array,  # [B, Lq, Hq, D]
    k: jax.Array,  # [B, Lk, Hkv, D]
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: jax.Array | int = 0,
    kv_valid: Optional[jax.Array] = None,
    q_chunk: int = 2048,
    stride_chunks: bool = False,
) -> jax.Array:
    """Chunked-query GQA attention; returns [B, Lq, Hq, D].

    ``stride_chunks``: chunk the query axis by STRIDE instead of contiguous
    ranges — used when Lq is sequence-sharded over the TP axis, so every
    chunk keeps rows on every shard (a contiguous chunk would collapse onto
    one shard and serialise the mesh).  Masks stay exact because positions
    are explicit.
    """
    if _ATTN_IMPL == "pallas" and kv_valid is None and window == 0 and causal:
        from repro.kernels import ops as kops

        return kops.flash_attention(q, k, v, causal=True, softcap=softcap)

    b, lq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qs = (q.astype(jnp.float32) / math.sqrt(d)).reshape(b, lq, hkv, g, d)
    k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    offs = jnp.asarray(q_offset, jnp.int32)

    def chunk_out(rows: jax.Array, q_pos: jax.Array, size: int) -> jax.Array:
        o = _sdpa_chunk(
            rows, k, v, q_pos, k_pos, kv_valid,
            causal=causal, window=window, softcap=softcap,
        )
        return o.reshape(b, size, hq, d)

    if lq <= q_chunk:
        qp = offs + jnp.arange(lq, dtype=jnp.int32)
        return chunk_out(qs, qp, lq)
    assert lq % q_chunk == 0, (lq, q_chunk)
    n = lq // q_chunk
    if stride_chunks:
        outs = []
        for c in range(n):
            qp = offs + jnp.arange(c, lq, n, dtype=jnp.int32)
            outs.append(chunk_out(qs[:, c::n], qp, q_chunk))
        # row i·n + c of the output is row i of chunk c
        return (
            jnp.stack(outs, axis=2)  # [B, lq/n, n, H, D]
            .reshape(b, lq, hq, d)
        )
    outs = []
    for start in range(0, lq, q_chunk):
        qp = offs + jnp.arange(start, start + q_chunk, dtype=jnp.int32)
        outs.append(chunk_out(qs[:, start : start + q_chunk], qp, q_chunk))
    return jnp.concatenate(outs, axis=1)


def attention_block(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,  # [B, L, d]
    *,
    positions: jax.Array,  # [L] absolute positions
    causal: bool,
    window: int = 0,
    cache: Optional[Params] = None,  # {"k","v","len"} — decode/prefill cache
    cross_kv: Optional[tuple[jax.Array, jax.Array]] = None,
    use_rope: bool = True,
) -> tuple[jax.Array, Optional[Params]]:
    """Full attention sub-block: projections + RoPE + SDPA (+ cache update)."""
    b, l, _ = x.shape
    q = linear(p["q"], x).reshape(b, l, cfg.n_heads, cfg.head_dim)
    if cross_kv is not None:
        k, v = cross_kv
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
        out = sdpa(q, k, v, causal=False, softcap=cfg.attn_logit_softcap)
        return linear(p["o"], out.reshape(b, l, cfg.q_dim)), cache

    k = linear(p["k"], x).reshape(b, l, cfg.n_kv_heads, cfg.head_dim)
    v = linear(p["v"], x).reshape(b, l, cfg.n_kv_heads, cfg.head_dim)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        # H1 (hints): explicit attention sharding.  GSPMD's default for GQA
        # with Hkv ∤ TP partially shards heads and ALL-REDUCES the score
        # tensor (~10 GB/layer on llama3.2-3b).  Two regimes:
        #   · Hkv | tp  → head-parallel: everything local per KV head;
        #   · Hkv ∤ tp  → sequence-sharded queries + replicated K/V: one
        #     K/V all-gather per layer instead of score all-reduces.
        from .hints import constrain, get_hints

        h = get_hints()
        head_parallel = (
            h is not None
            and h.head_shard_attention
            and cfg.n_kv_heads % h.tp_size == 0
            and b % h.dp_size == 0
        )
        seq_parallel = (
            h is not None
            and not head_parallel
            and h.seq_shard_attention
            and l % h.tp_size == 0
            and b % h.dp_size == 0
        )
        if head_parallel:
            dp = h.dp_spec()
            q = constrain(q, dp, None, h.tp_axis, None)
            k = constrain(k, dp, None, h.tp_axis, None)
            v = constrain(v, dp, None, h.tp_axis, None)
            out = sdpa(
                q, k, v, causal=causal, window=window,
                softcap=cfg.attn_logit_softcap,
            )
            out = constrain(out, dp, None, h.tp_axis, None)
        elif seq_parallel:
            dp = h.dp_spec()
            q = constrain(q, dp, h.tp_axis, None, None)
            k = constrain(k, dp, None, None, None)
            v = constrain(v, dp, None, None, None)
            # ≤4k: the TP split already bounds per-device score memory —
            # skip chunking (strided chunks lower to gather/scatter whose
            # backward re-introduces full-residual collectives, §Perf it.3).
            out = sdpa(
                q, k, v, causal=causal, window=window,
                softcap=cfg.attn_logit_softcap,
                q_chunk=l if l <= 4096 else 2048,
                stride_chunks=True,
            )
            out = constrain(out, dp, h.tp_axis, None, None)
        else:
            out = sdpa(
                q, k, v, causal=causal, window=window,
                softcap=cfg.attn_logit_softcap,
            )
        new_cache = None
    else:
        # Write new K/V rows at cache["len"], then attend over valid rows.
        idx = cache["len"]  # scalar int32
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0, 0))
        valid = idx + l
        out = sdpa(
            q, ck, cv, causal=causal, window=window,
            softcap=cfg.attn_logit_softcap,
            q_offset=idx, kv_valid=valid,
        )
        new_cache = {"k": ck, "v": cv, "len": valid}
    return linear(p["o"], out.reshape(b, l, cfg.q_dim)), new_cache


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((), jnp.int32),
    }
