"""Model assembly: blocks → scanned layer groups → LM / enc-dec forward.

Layers are grouped by the config's repeating ``pattern``; params of each
pattern position are stacked over ``repeats`` and the stack is traversed
with ``jax.lax.scan`` so an 80-layer model lowers to a compact HLO.  The
same block code serves training (no cache), prefill (cache write) and
decode (cache append) — recurrent mixers thread their states through the
identical path, which is what makes ``long_500k`` O(1)-state decode work.

Vocab padding: embedding/LM-head rows are padded up to a multiple of 256 so
vocab shards evenly on the ``model`` mesh axis (the config's logical vocab is
unchanged; padded logits are masked to −∞).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    apply_mlp,
    apply_norm,
    attention_block,
    init_attention,
    init_kv_cache,
    init_mlp,
    init_norm,
    linear,
    init_linear,
)
from .moe import apply_moe, init_moe
from .recurrent import (
    MambaState,
    MLSTMState,
    SLSTMState,
    init_mamba,
    init_mamba_state,
    init_mlstm,
    init_mlstm_state,
    init_slstm,
    init_slstm_state,
    mamba_mix,
    mlstm_mix,
    slstm_mix,
)

Params = dict[str, Any]
PyTree = Any

VOCAB_PAD = 256


def padded_vocab(cfg: ModelConfig) -> int:
    return -(-cfg.vocab // VOCAB_PAD) * VOCAB_PAD


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, kind: tuple[str, str], *, cross: bool, dtype) -> Params:
    mixer, ffn = kind
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": init_norm(cfg, dtype)}
    if mixer in ("attn", "attn_local"):
        p["mixer"] = init_attention(ks[0], cfg, dtype)
    elif mixer == "mamba":
        p["mixer"] = init_mamba(ks[0], cfg, dtype)
    elif mixer == "mlstm":
        p["mixer"] = init_mlstm(ks[0], cfg, dtype)
    elif mixer == "slstm":
        p["mixer"] = init_slstm(ks[0], cfg, dtype)
    else:
        raise ValueError(mixer)
    if cfg.post_block_norm:
        p["post_norm1"] = init_norm(cfg, dtype)
    if cross:
        p["norm_cross"] = init_norm(cfg, dtype)
        p["cross"] = init_attention(ks[1], cfg, dtype)
    if ffn != "none":
        p["norm2"] = init_norm(cfg, dtype)
        if ffn == "mlp":
            p["ffn"] = init_mlp(ks[2], cfg, cfg.d_ff, dtype)
        elif ffn == "moe":
            p["ffn"] = init_moe(ks[2], cfg, dtype)
        elif ffn == "dense0":
            p["ffn"] = init_mlp(ks[2], cfg, cfg.d_ff, dtype)
        else:
            raise ValueError(ffn)
        if cfg.post_block_norm:
            p["post_norm2"] = init_norm(cfg, dtype)
    return p


def init_block_cache(
    cfg: ModelConfig, kind: tuple[str, str], batch: int, max_len: int, dtype
) -> Params | None:
    """Cache entry for one layer (no 'len' — it is shared model-wide)."""
    mixer, _ = kind
    if mixer in ("attn", "attn_local"):
        kv = init_kv_cache(cfg, batch, max_len, dtype)
        return {"k": kv["k"], "v": kv["v"]}
    if mixer == "mamba":
        return {"state": init_mamba_state(cfg, batch, dtype)}
    if mixer == "mlstm":
        return {"state": init_mlstm_state(cfg, batch)}
    if mixer == "slstm":
        return {"state": init_slstm_state(cfg, batch)}
    raise ValueError(mixer)


def apply_block(
    cfg: ModelConfig,
    kind: tuple[str, str],
    p: Params,
    x: jax.Array,
    *,
    positions: jax.Array,
    causal: bool,
    cache: Params | None,  # per-layer entry (no "len")
    cache_len: jax.Array | None,  # shared scalar, None when training
    enc_out: jax.Array | None = None,
) -> tuple[jax.Array, Params | None, jax.Array]:
    """Returns (x', new cache entry, aux loss)."""
    mixer, ffn = kind
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, p["norm1"], x)

    new_cache: Params | None = None
    if mixer in ("attn", "attn_local"):
        window = cfg.sliding_window if mixer == "attn_local" else 0
        kv_cache = None
        if cache is not None:
            kv_cache = {"k": cache["k"], "v": cache["v"], "len": cache_len}
        out, upd = attention_block(
            cfg, p["mixer"], h,
            positions=positions, causal=causal, window=window, cache=kv_cache,
            use_rope=cfg.use_rope,
        )
        if upd is not None:
            new_cache = {"k": upd["k"], "v": upd["v"]}
    elif mixer == "mamba":
        out, st = mamba_mix(cfg, p["mixer"], h, cache["state"] if cache else None)
        new_cache = {"state": st}
    elif mixer == "mlstm":
        out, st = mlstm_mix(cfg, p["mixer"], h, cache["state"] if cache else None)
        new_cache = {"state": st}
    elif mixer == "slstm":
        out, st = slstm_mix(cfg, p["mixer"], h, cache["state"] if cache else None)
        new_cache = {"state": st}
    else:
        raise ValueError(mixer)

    if cfg.post_block_norm:
        out = apply_norm(cfg, p["post_norm1"], out)
    x = x + out

    if "cross" in p:
        h = apply_norm(cfg, p["norm_cross"], x)
        b, s, _ = enc_out.shape
        ck = linear(p["cross"]["k"], enc_out).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        cv = linear(p["cross"]["v"], enc_out).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        out, _ = attention_block(
            cfg, p["cross"], h,
            positions=positions, causal=False, cross_kv=(ck, cv), use_rope=False,
        )
        x = x + out

    if ffn != "none":
        h = apply_norm(cfg, p["norm2"], x)
        if ffn == "moe":
            from .hints import get_hints
            from .moe import apply_moe_sharded

            hints = get_hints()
            if (
                hints is not None
                and hints.local_moe_dispatch
                and cfg.moe.n_experts % hints.tp_size == 0
                and (x.shape[0] * x.shape[1]) % hints.dp_size == 0
                and x.shape[0] % hints.dp_size == 0
            ):
                out, aux = apply_moe_sharded(cfg, p["ffn"], h)
            else:
                out, aux = apply_moe(cfg, p["ffn"], h)
        else:
            out = apply_mlp(cfg, p["ffn"], h)
        if cfg.post_block_norm:
            out = apply_norm(cfg, p["post_norm2"], out)
        x = x + out
    if cache is not None and new_cache is None:
        new_cache = cache
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Layer stacks (prefix unrolled, body scanned over repeats)
# ---------------------------------------------------------------------------


def _init_stack(key, cfg: ModelConfig, *, cross: bool, dtype) -> Params:
    """params = {"prefix": [block...], "body": tuple_j stacked-block}."""
    kp, kb = jax.random.split(key)
    prefix = []
    for i, kind in enumerate(cfg.prefix_pattern):
        prefix.append(
            init_block(jax.random.fold_in(kp, i), cfg, kind, cross=cross, dtype=dtype)
        )
    body = []
    for j, kind in enumerate(cfg.pattern):
        per_repeat = [
            init_block(
                jax.random.fold_in(kb, j * cfg.repeats + r),
                cfg, kind, cross=cross, dtype=dtype,
            )
            for r in range(cfg.repeats)
        ]
        body.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_repeat))
    return {"prefix": prefix, "body": tuple(body)}


def _init_stack_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype
) -> Params:
    prefix = [
        init_block_cache(cfg, kind, batch, max_len, dtype)
        for kind in cfg.prefix_pattern
    ]
    body = []
    for kind in cfg.pattern:
        per_repeat = [
            init_block_cache(cfg, kind, batch, max_len, dtype)
            for _ in range(cfg.repeats)
        ]
        body.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_repeat))
    return {"prefix": prefix, "body": tuple(body)}


def _apply_stack(
    cfg: ModelConfig,
    params: Params,
    x: jax.Array,
    *,
    positions: jax.Array,
    causal: bool,
    cache: Params | None,
    cache_len: jax.Array | None,
    enc_out: jax.Array | None = None,
) -> tuple[jax.Array, Params | None, jax.Array]:
    # H4 (hints): Megatron-SP residual stream — between blocks the
    # [B, L, d] activations live sequence-sharded over the TP axis, so the
    # backward activation-grad exchange lowers to reduce-scatter/all-gather
    # pairs instead of full all-reduces (≈2× less residual traffic) and
    # norms compute on 1/tp of the rows.
    from .hints import constrain, get_hints

    h = get_hints()
    sp_resid = (
        h is not None
        and h.seq_parallel_residual
        and cache is None  # decode keeps L=1
        and x.shape[1] % h.tp_size == 0
        and x.shape[0] % h.dp_size == 0
    )

    def sp(z):
        return constrain(z, h.dp_spec(), h.tp_axis, None) if sp_resid else z

    x = sp(x)
    aux_total = jnp.zeros((), jnp.float32)
    new_prefix = []
    for i, kind in enumerate(cfg.prefix_pattern):
        c = cache["prefix"][i] if cache is not None else None
        block_fn = partial(
            apply_block, cfg, kind,
            positions=positions, causal=causal, cache_len=cache_len,
        )
        if cfg.remat:
            block_fn = jax.checkpoint(
                block_fn,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        x, nc, aux = block_fn(params["prefix"][i], x, cache=c, enc_out=enc_out)
        x = sp(x)
        new_prefix.append(nc)
        aux_total = aux_total + aux

    def body_fn(carry, per_layer):
        x, aux_acc = carry
        p_j, c_j = per_layer
        new_c = []
        for j, kind in enumerate(cfg.pattern):
            x, nc, aux = apply_block(
                cfg, kind, p_j[j], x,
                positions=positions, causal=causal,
                cache=c_j[j] if c_j is not None else None,
                cache_len=cache_len, enc_out=enc_out,
            )
            x = sp(x)
            new_c.append(nc)
            aux_acc = aux_acc + aux
        return (x, aux_acc), tuple(new_c) if c_j is not None else None

    if cfg.repeats > 0:
        body_cache = cache["body"] if cache is not None else None
        fn = body_fn
        if cfg.remat:
            fn = jax.checkpoint(
                body_fn,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        (x, aux_total), new_body = jax.lax.scan(
            fn, (x, aux_total), (params["body"], body_cache)
        )
    else:
        new_body = cache["body"] if cache is not None else None

    new_cache = None
    if cache is not None:
        new_cache = {"prefix": new_prefix, "body": new_body}
    return x, new_cache, aux_total


# ---------------------------------------------------------------------------
# The Model facade
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- init ----------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        dtype = _dtype(cfg)
        ks = jax.random.split(key, 6)
        vp = padded_vocab(cfg)
        params: Params = {
            "embed": (
                jax.random.normal(ks[0], (vp, cfg.d_model), jnp.float32)
                * (1.0 / math.sqrt(cfg.d_model))
            ).astype(dtype),
            "final_norm": init_norm(cfg, dtype),
        }
        if not cfg.tied_embeddings:
            params["lm_head"] = init_linear(ks[1], cfg.d_model, vp, dtype)
        if cfg.is_encoder_decoder:
            enc_cfg = dataclasses.replace(
                cfg,
                n_layers=cfg.n_enc_layers,
                prefix_pattern=(),
                pattern=(("attn", "mlp"),),
            )
            params["encoder"] = _init_stack(ks[2], enc_cfg, cross=False, dtype=dtype)
            params["enc_norm"] = init_norm(cfg, dtype)
            params["decoder"] = _init_stack(ks[3], cfg, cross=True, dtype=dtype)
        else:
            params["decoder"] = _init_stack(ks[3], cfg, cross=False, dtype=dtype)
        if cfg.frontend == "vision":
            params["vision_proj"] = init_linear(ks[4], cfg.d_model, cfg.d_model, dtype)
        return params

    # -- embedding / head ------------------------------------------------------
    def _embed(self, params: Params, tokens: jax.Array) -> jax.Array:
        x = params["embed"][tokens]
        if self.cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(self.cfg.d_model), x.dtype)
        return x

    def _logits(self, params: Params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = apply_norm(cfg, params["final_norm"], x)
        if cfg.tied_embeddings:
            logits = x @ params["embed"].T
        else:
            logits = linear(params["lm_head"], x)
        if cfg.final_logit_softcap > 0.0:
            c = cfg.final_logit_softcap
            logits = jnp.tanh(logits / c) * c
        vp = padded_vocab(cfg)
        if vp != cfg.vocab:  # mask padded rows
            pad_mask = jnp.arange(vp) >= cfg.vocab
            logits = jnp.where(pad_mask, -1e30, logits)
        return logits

    def _encode(self, params: Params, src_embeds: jax.Array) -> jax.Array:
        cfg = self.cfg
        enc_cfg = dataclasses.replace(
            cfg,
            n_layers=cfg.n_enc_layers,
            prefix_pattern=(),
            pattern=(("attn", "mlp"),),
        )
        pos = jnp.arange(src_embeds.shape[1], dtype=jnp.int32)
        x, _, _ = _apply_stack(
            enc_cfg, params["encoder"], src_embeds,
            positions=pos, causal=False, cache=None, cache_len=None,
        )
        return apply_norm(cfg, params["enc_norm"], x)

    # -- training forward -------------------------------------------------------
    def forward(
        self,
        params: Params,
        tokens: jax.Array,  # [B, L]
        *,
        src_embeds: jax.Array | None = None,  # audio frontend (enc-dec)
        patch_embeds: jax.Array | None = None,  # vision frontend (prepended)
    ) -> tuple[jax.Array, jax.Array]:
        """Returns (logits [B, L(+P), Vp], aux_loss)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        enc_out = None
        if cfg.is_encoder_decoder:
            assert src_embeds is not None, "enc-dec model needs src_embeds"
            enc_out = self._encode(params, src_embeds.astype(x.dtype))
        if cfg.frontend == "vision":
            assert patch_embeds is not None, "vlm needs patch_embeds"
            pe = linear(params["vision_proj"], patch_embeds.astype(x.dtype))
            x = jnp.concatenate([pe, x], axis=1)
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)
        x, _, aux = _apply_stack(
            cfg, params["decoder"], x,
            positions=pos, causal=True, cache=None, cache_len=None,
            enc_out=enc_out,
        )
        return self._logits(params, x), aux

    # -- loss ----------------------------------------------------------------
    def loss(
        self,
        params: Params,
        batch: dict[str, jax.Array],
        *,
        seq_chunk: int = 1024,
    ) -> tuple[jax.Array, dict[str, jax.Array]]:
        """Next-token CE (labels == -1 ignored) + MoE aux loss."""
        cfg = self.cfg
        logits, aux = self.forward(
            params,
            batch["tokens"],
            src_embeds=batch.get("src_embeds"),
            patch_embeds=batch.get("patch_embeds"),
        )
        labels = batch["labels"]
        if cfg.frontend == "vision":  # loss only over the token region
            logits = logits[:, -labels.shape[1] :]
        b, l, vp = logits.shape
        chunk = min(seq_chunk, l)
        total, count = jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)
        for s in range(0, l, chunk):
            lg = logits[:, s : s + chunk].astype(jnp.float32)
            lb = labels[:, s : s + chunk]
            logp = jax.nn.log_softmax(lg, axis=-1)
            tgt = jnp.take_along_axis(
                logp, jnp.maximum(lb, 0)[..., None], axis=-1
            )[..., 0]
            mask = (lb >= 0).astype(jnp.float32)
            total = total - jnp.sum(tgt * mask)
            count = count + jnp.sum(mask)
        ce = total / jnp.maximum(count, 1.0)
        loss = ce + cfg.moe.router_aux_weight * aux
        return loss, {"ce": ce, "aux": aux, "tokens": count}

    # -- serving ----------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        dtype = _dtype(cfg)
        cache: Params = {
            "len": jnp.zeros((), jnp.int32),
            "decoder": _init_stack_cache(cfg, batch, max_len, dtype),
        }
        if cfg.is_encoder_decoder:
            cache["enc_out"] = jnp.zeros(
                (batch, cfg.frontend_len, cfg.d_model), dtype
            )
        return cache

    def prefill(
        self,
        params: Params,
        tokens: jax.Array,  # [B, L0]
        cache: Params,
        *,
        src_embeds: jax.Array | None = None,
        patch_embeds: jax.Array | None = None,
    ) -> tuple[jax.Array, Params]:
        """Consume the prompt; returns (last-position logits, cache)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        enc_out = cache.get("enc_out")
        if cfg.is_encoder_decoder:
            assert src_embeds is not None
            enc_out = self._encode(params, src_embeds.astype(x.dtype))
            cache = dict(cache, enc_out=enc_out)
        if cfg.frontend == "vision":
            assert patch_embeds is not None
            pe = linear(params["vision_proj"], patch_embeds.astype(x.dtype))
            x = jnp.concatenate([pe, x], axis=1)
        ln = cache["len"]
        pos = ln + jnp.arange(x.shape[1], dtype=jnp.int32)
        x, dec_cache, _ = _apply_stack(
            cfg, params["decoder"], x,
            positions=pos, causal=True,
            cache=cache["decoder"], cache_len=ln, enc_out=enc_out,
        )
        logits = self._logits(params, x[:, -1:])
        new_cache = dict(
            cache, decoder=dec_cache, len=ln + x.shape[1]
        )
        return logits, new_cache

    def decode_step(
        self, params: Params, token: jax.Array, cache: Params
    ) -> tuple[jax.Array, Params]:
        """One decode step: token [B, 1] → (logits [B, 1, Vp], cache)."""
        cfg = self.cfg
        x = self._embed(params, token)
        ln = cache["len"]
        pos = ln + jnp.arange(1, dtype=jnp.int32)
        x, dec_cache, _ = _apply_stack(
            cfg, params["decoder"], x,
            positions=pos, causal=True,
            cache=cache["decoder"], cache_len=ln,
            enc_out=cache.get("enc_out"),
        )
        logits = self._logits(params, x)
        return logits, dict(cache, decoder=dec_cache, len=ln + 1)
