"""Distribution hints — the beyond-paper collective optimisations.

The baseline lets GSPMD infer every intermediate sharding from the
parameter/batch specs.  The dry-run profile (EXPERIMENTS.md §Perf) shows
GSPMD making two catastrophic choices:

1. it partially shards GQA attention heads (Hkv < TP degree) and
   **all-reduces the score tensor** across the leftover head_dim split —
   ~10 GB/layer on llama3.2-3b × train_4k;
2. it materialises the MoE capacity buffer **globally** and all-reduces it
   across the token shards — ~75 GB/layer on deepseek-moe-16b × train_4k.

When hints are installed (``set_hints``), the model inserts explicit
constraints/shard_map regions that replace those patterns with
sequence-sharded attention (K/V all-gather, ~40× less traffic) and
expert-local MoE dispatch (output psum only, ~50× less traffic).  Hints are
process-global (like the attention-impl switch) so the same model code
serves both the paper-faithful baseline and the optimised plan — both are
recorded in EXPERIMENTS.md.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ShardHints:
    mesh: Mesh
    dp_axes: tuple[str, ...]  # batch axes, e.g. ("data",) or ("pod", "data")
    tp_axis: str = "model"
    seq_shard_attention: bool = True  # H1 (GQA with Hkv ∤ tp)
    head_shard_attention: bool = True  # H1b (MHA/GQA with Hkv | tp)
    local_moe_dispatch: bool = True  # H2
    seq_parallel_residual: bool = True  # H4 (Megatron-SP residual stream)

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis]

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    def dp_spec(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]


_HINTS: ShardHints | None = None


def set_hints(hints: ShardHints | None) -> None:
    global _HINTS
    _HINTS = hints


def get_hints() -> ShardHints | None:
    return _HINTS


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint under the installed hints (no-op without)."""
    h = _HINTS
    if h is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(h.mesh, P(*spec))
    )
