"""Model zoo: configs, layers, mixers (attention/Mamba/xLSTM), MoE, facade."""

from .config import ModelConfig, MoECfg, SSMCfg, smoke_variant, unrolled_variant
from .model import Model, padded_vocab
from .layers import set_attn_impl, get_attn_impl

__all__ = [
    "ModelConfig",
    "MoECfg",
    "SSMCfg",
    "smoke_variant",
    "unrolled_variant",
    "Model",
    "padded_vocab",
    "set_attn_impl",
    "get_attn_impl",
]
