"""Incremental placement scoring — candidate moves without tree rebuilds.

``refine_placement`` historically scored every candidate move by
re-encoding the whole instance into trace trees, re-running the rewrite
rules over them and re-simulating from scratch — superlinear per move and
infeasible beyond a few hundred steps.  :class:`PlacementScorer` keeps the
plan in the flat domain for the whole search:

* the per-location **rows** (work-queue blocks with their recv/send
  templates, already filtered through the R1/R2 scan) are cached and, when
  one step moves, only the rows whose content mentions that step — its old
  and new homes, the locations of its producers (their send targets change)
  and of its consumers (their recv sources change) — are rebuilt;
* R3 survivorship and the event graph are re-derived from the cached rows
  with plain arrays (no ``Seq``/``Par``/dataclass nodes anywhere), and the
  schedule itself runs through the same
  :func:`repro.sched.simulate.run_event_schedule` core as the public
  simulator.

Equivalence contract: ``score()`` returns exactly the ``(makespan,
cross_bytes)`` that ``evaluate_placement`` — ``simulate(rewrite(encode(I
under M)))`` — would report for the same mapping, including tie-breaking
(events are constructed in the same order as
:func:`repro.sched.simulate.simulate` constructs them, and the heap breaks
ties on event id).  The differential suite in
``tests/test_compile_scale.py`` pins this on random instances; if a rule
list outside the supported forms is requested the caller falls back to the
tree path (:class:`UnsupportedRules`).
"""

from __future__ import annotations

from bisect import bisect_left, insort
from typing import Mapping, Sequence

from repro.core.graph import DistributedWorkflowInstance

from .estimate import CostModel, SizeModel
from .network import NetworkModel
from .simulate import SimulationError, run_event_schedule

__all__ = ["PlacementScorer", "UnsupportedRules"]

#: Rule lists the scorer can replay (prefixes of the canonical order).
_SUPPORTED_RULES = {(), ("R1R2",), ("R1R2", "R3")}


class UnsupportedRules(ValueError):
    """The requested rewrite-rule list has no flat-domain replay."""


class PlacementScorer:
    """Score ``(makespan, cross_bytes)`` of placements, patching per move.

    Usage::

        scorer = PlacementScorer(inst, network, sizes=s, costs=c, rules=r)
        scorer.reset(mapping)
        base = scorer.score()
        scorer.move("s12", ("l3",))
        cand = scorer.score()          # only affected rows were rebuilt
        scorer.move("s12", home)       # revert is just another move
    """

    def __init__(
        self,
        inst: DistributedWorkflowInstance,
        network: NetworkModel,
        *,
        sizes: SizeModel,
        costs: CostModel,
        rules: Sequence[str] = ("R1R2",),
        exec_slots: int | None = 1,
    ) -> None:
        rules = tuple(rules)
        if rules not in _SUPPORTED_RULES:
            raise UnsupportedRules(
                f"no flat-domain replay for rule list {rules!r}; "
                f"supported: {sorted(_SUPPORTED_RULES)}"
            )
        self.rules = rules
        self.exec_slots = exec_slots
        self.locations = sorted(inst.locations)
        self.network = network.bind(inst.locations)

        wf = inst.workflow
        topo = wf.topological_steps()
        self.topo_index = {s: i for i, s in enumerate(topo)}
        self.steps = topo

        # Static per-step / per-datum tables.
        self.in_sorted: dict[str, tuple[str, ...]] = {}
        self.out_sorted: dict[str, tuple[str, ...]] = {}
        self.exec_s: dict[str, float] = {}
        self._pretty_prefix: dict[str, str] = {}
        self.port_of: dict[str, str] = dict(inst.placement)
        self.producers: dict[str, tuple[str, ...]] = {}
        self.consumers: dict[str, tuple[str, ...]] = {}
        self.bytes_of: dict[str, int] = {}
        for s in topo:
            ins = tuple(sorted(inst.in_data(s)))
            outs = tuple(sorted(inst.out_data(s)))
            self.in_sorted[s] = ins
            self.out_sorted[s] = outs
            self.exec_s[s] = max(costs.exec_s(s), 0.0)
            self._pretty_prefix[s] = (
                f"exec({s},{{{','.join(ins)}}}->{{{','.join(outs)}}},{{"
            )
            for d in ins:
                if d not in self.producers:
                    self.producers[d] = tuple(
                        sorted(inst.producers_of_data(d))
                    )
            for d in outs:
                if d not in self.consumers:
                    self.consumers[d] = tuple(
                        sorted(inst.consumers_of_data(d))
                    )
                if d not in self.bytes_of:
                    self.bytes_of[d] = sizes.bytes_of(d)

        # Transfer link cache per ordered location pair.
        self._link = {
            (a, b): self.network.link(a, b)
            for a in self.locations
            for b in self.locations
        }

        # Mutable search state, established by reset().
        self.mapping: dict[str, tuple[str, ...]] = {}
        self._queues: dict[str, list[str]] = {}
        self._rows: dict[str, list] = {}
        self._pretty: dict[str, str] = {}
        #: Exec events ordered by pretty string (simulate()'s order), kept
        #: sorted incrementally — a move changes exactly one entry.
        self._exec_sorted: list[tuple[str, str]] = []
        #: R3 kill set of the current state, shared between the byte screen
        #: and the full score; invalidated by move()/reset().
        self._killed_cache: dict[str, set[tuple]] | None = None

    # -- state construction -------------------------------------------------
    def reset(self, mapping: Mapping[str, Sequence[str]]) -> None:
        """(Re)build every row for ``mapping``."""
        self.mapping = {s: tuple(ls) for s, ls in mapping.items()}
        self._pretty = {
            s: self._pretty_prefix[s] + ",".join(self.mapping[s]) + "})"
            for s in self.steps
        }
        self._exec_sorted = sorted(
            (p, s) for s, p in self._pretty.items()
        )
        self._killed_cache = None
        self._queues = {l: [] for l in self.locations}
        for s in self.steps:  # topo order == work-queue order
            for l in self.mapping[s]:
                self._queues[l].append(s)
        self._rows = {l: self._build_row(l) for l in self.locations}

    def _build_row(self, loc: str) -> list:
        """Blocks ``(step, recvs, sends)`` at ``loc`` after the R1/R2 scan.

        ``recvs`` are ``(port, src)``, ``sends`` are ``(data, port, dst)``
        pairs in Def.-10 emission order; with ``rules == ()`` the raw
        encoding is kept verbatim.
        """
        mapping = self.mapping
        dedupe = bool(self.rules)  # any supported non-empty list starts R1R2
        seen: set[tuple] = set()
        row: list = []
        for s in self._queues[loc]:
            recvs: list[tuple[str, str]] = []
            for d in self.in_sorted[s]:
                port = self.port_of[d]
                for ps in self.producers.get(d, ()):
                    for lj in mapping[ps]:
                        if dedupe:
                            if lj == loc:  # R1
                                continue
                            key = ("r", port, lj)
                            if key in seen:  # R2
                                continue
                            seen.add(key)
                        recvs.append((port, lj))
            sends: list[tuple[str, str, str]] = []
            for d in self.out_sorted[s]:
                port = self.port_of[d]
                for sk in self.consumers.get(d, ()):
                    for lj in mapping[sk]:
                        if dedupe:
                            if lj == loc:  # R1
                                continue
                            key = ("s", d, port, lj)
                            if key in seen:  # R2
                                continue
                            seen.add(key)
                        sends.append((d, port, lj))
            row.append((s, recvs, sends))
        return row

    def action_count(self) -> int:
        """Predicate occurrences in the current (rewritten) plan."""
        return sum(
            1 + len(recvs) + len(sends)
            for row in self._rows.values()
            for _, recvs, sends in row
        )

    # -- incremental patch --------------------------------------------------
    def move(self, step: str, new_locs: tuple[str, ...]) -> None:
        """Re-home ``step``; rebuilds only the rows its placement touches."""
        old_locs = self.mapping[step]
        if new_locs == old_locs:
            return
        affected = set(old_locs) | set(new_locs)
        for d in self.in_sorted[step]:
            for ps in self.producers.get(d, ()):
                affected.update(self.mapping[ps])
        for d in self.out_sorted[step]:
            for sk in self.consumers.get(d, ()):
                affected.update(self.mapping[sk])

        self.mapping[step] = new_locs
        old_pretty = self._pretty[step]
        new_pretty = self._pretty_prefix[step] + ",".join(new_locs) + "})"
        self._pretty[step] = new_pretty
        del self._exec_sorted[
            bisect_left(self._exec_sorted, (old_pretty, step))
        ]
        insort(self._exec_sorted, (new_pretty, step))
        self._killed_cache = None
        ti = self.topo_index
        for l in old_locs:
            if l not in new_locs:
                self._queues[l].remove(step)
        for l in new_locs:
            if l not in old_locs:
                q = self._queues[l]
                lo, hi = 0, len(q)
                key = ti[step]
                while lo < hi:
                    mid = (lo + hi) // 2
                    if ti[q[mid]] < key:
                        lo = mid + 1
                    else:
                        hi = mid
                q.insert(lo, step)
        for l in affected:
            self._rows[l] = self._build_row(l)

    # -- scoring ------------------------------------------------------------
    def score(self) -> tuple[float, int]:
        """``(makespan, cross_bytes)`` of the current mapping.

        Bit-identical to ``simulate(rewrite(encode(inst under mapping)),
        exec_slots=...)`` — see the module docstring.
        """
        mapping = self.mapping
        rows = self._rows

        # R3 survivor filtering (per evaluation, over the cached rows).
        killed: dict[str, set[tuple]] = {}
        if "R3" in self.rules:
            killed = self._r3_killed()

        # 1. Exec events, ordered exactly like simulate(): by pretty()
        #    (the order is maintained incrementally across moves).
        exec_order = [s for _, s in self._exec_sorted]
        exec_eid = {s: i for i, s in enumerate(exec_order)}
        n_exec = len(exec_order)
        preds: list[list[int]] = [[] for _ in range(n_exec)]
        durations: list[float] = [self.exec_s[s] for s in exec_order]
        exec_locations: list = [
            tuple(sorted(set(mapping[s]))) for s in exec_order
        ]

        # 2. Comm events in node order; channel FIFOs as we go.
        send_data: dict[int, str] = {}  # send event id -> datum carried
        chan_sends: dict[tuple[str, str, str], list[int]] = {}
        chan_recvs: dict[tuple[str, str, str], list[int]] = {}
        eid = n_exec
        for loc in self.locations:
            kset = killed.get(loc, ())
            for s, recvs, sends in rows[loc]:
                xe = exec_eid[s]
                xpreds = preds[xe]
                for i, (port, src) in enumerate(recvs):
                    if kset and ("r", s, i) in kset:
                        continue
                    preds.append([])
                    durations.append(0.0)
                    exec_locations.append(None)
                    xpreds.append(eid)
                    chan_recvs.setdefault((src, loc, port), []).append(eid)
                    eid += 1
                for i, (d, port, dst) in enumerate(sends):
                    if kset and ("s", s, i) in kset:
                        continue
                    preds.append([xe])
                    durations.append(0.0)
                    exec_locations.append(None)
                    chan_sends.setdefault((loc, dst, port), []).append(eid)
                    send_data[eid] = d
                    eid += 1

        # 3. FIFO channel matching (k-th send ↔ k-th recv).
        comm_edges: dict[int, tuple[int, float]] = {}
        cross_bytes = 0
        link = self._link
        bytes_of = self.bytes_of
        for chan, rlist in chan_recvs.items():
            slist = chan_sends.get(chan, [])
            if len(rlist) > len(slist):
                raise SimulationError(
                    f"{len(rlist) - len(slist)} recv(s) on channel {chan} "
                    "have no matching send — the plan would deadlock"
                )
            src, dst, _port = chan
            lnk = link[(src, dst)]
            for seid, reid in zip(slist, rlist):
                nbytes = bytes_of[send_data[seid]]
                transfer = lnk.transfer_s(nbytes)
                comm_edges[reid] = (seid, transfer)
                preds[reid].append(seid)
                if src != dst:
                    cross_bytes += nbytes

        # 4. Shared scheduling core.
        _, finish, _, unfinished = run_event_schedule(
            preds,
            durations,
            exec_locations,
            comm_edges,
            self.exec_slots,
            self.locations,
        )
        if unfinished:
            raise SimulationError(
                "cyclic channel wait — the plan cannot be replayed"
            )
        makespan = max(finish, default=0.0)
        return makespan, cross_bytes

    def cross_bytes_only(self) -> int:
        """Exact cross-location bytes of the current mapping, no schedule.

        The ``objective="bytes"`` fast path: the byte total depends only on
        which sends survive rewriting and pair with a recv, so candidate
        moves that do not beat the incumbent byte count can be rejected
        without running the event schedule at all.
        """
        rows = self._rows
        killed: dict[str, set[tuple]] = {}
        if "R3" in self.rules:
            killed = self._r3_killed()
        chan_sends: dict[tuple[str, str, str], list[str]] = {}
        chan_recvs: dict[tuple[str, str, str], int] = {}
        for loc in self.locations:
            kset = killed.get(loc, ())
            for s, recvs, sends in rows[loc]:
                for i, (port, src) in enumerate(recvs):
                    if kset and ("r", s, i) in kset:
                        continue
                    key = (src, loc, port)
                    chan_recvs[key] = chan_recvs.get(key, 0) + 1
                for i, (d, port, dst) in enumerate(sends):
                    if kset and ("s", s, i) in kset:
                        continue
                    chan_sends.setdefault((loc, dst, port), []).append(d)
        total = 0
        bytes_of = self.bytes_of
        for chan, n_recv in chan_recvs.items():
            src, dst, _port = chan
            if src == dst:
                continue
            for d in chan_sends.get(chan, [])[:n_recv]:
                total += bytes_of[d]
        return total

    # -- R3 over the cached rows --------------------------------------------
    def _r3_killed(self) -> dict[str, set[tuple]]:
        """Positions deleted by R3, as ``{loc: {("s"|"r", step, idx)}}``.

        Mirrors :func:`repro.core.flat.rewrite_r3` over the row structure:
        tables over the R1R2 survivors, then one pass over the surviving
        sends in system program order, deleting each qualifying send at its
        source together with the first surviving matching recv at its
        destination.  Memoised per state so the byte screen and the full
        score of the same candidate share one pass.
        """
        if self._killed_cache is not None:
            return self._killed_cache
        mapping = self.mapping
        rows = self._rows

        produces: dict[str, set[str]] = {}
        for s in self.steps:
            outs = self.out_sorted[s]
            if not outs:
                continue
            for l in mapping[s]:
                produces.setdefault(l, set()).update(outs)

        # FIFO indexes over surviving comm positions, plus the live
        # port → data table (both over the R1R2 survivors, exactly like
        # the flat engine builds them over the alive actions).
        send_fifo: dict[tuple, list[tuple]] = {}
        recv_fifo: dict[tuple, list[tuple]] = {}
        port_data: dict[str, set[str]] = {}
        snapshot: list[tuple] = []
        for loc in self.locations:
            for s, recvs, sends in rows[loc]:
                for i, (port, src) in enumerate(recvs):
                    recv_fifo.setdefault((loc, port, src), []).append(
                        ("r", s, i)
                    )
                for i, (d, port, dst) in enumerate(sends):
                    port_data.setdefault(port, set()).add(d)
                    send_fifo.setdefault((loc, d, port, dst), []).append(
                        ("s", s, i)
                    )
                    snapshot.append((loc, d, port, dst))

        killed: dict[str, set[tuple]] = {}
        heads: dict[tuple, int] = {}
        for loc, d, port, dst in snapshot:
            if loc == dst:
                continue
            if len(port_data[port]) != 1:
                continue
            if d not in produces.get(dst, ()):
                continue
            skey = (loc, d, port, dst)
            rkey = (dst, port, loc)
            sq = send_fifo.get(skey)
            rq = recv_fifo.get(rkey)
            if sq is None or rq is None:
                continue
            shead = heads.get(skey, 0)
            rhead = heads.get(rkey, 0)
            if shead >= len(sq) or rhead >= len(rq):
                continue
            heads[skey] = shead + 1
            heads[rkey] = rhead + 1
            killed.setdefault(loc, set()).add(sq[shead])
            killed.setdefault(dst, set()).add(rq[rhead])
        self._killed_cache = killed
        return killed
