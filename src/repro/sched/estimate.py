"""Payload-size and step-cost estimators for the scheduler.

The makespan simulator needs two numbers the SWIRL calculus deliberately
abstracts away: how many *bytes* each data element carries, and how long
each ``exec`` takes.  Both come in as pluggable models with layered sources:

* :class:`SizeModel` — explicit per-datum byte sizes, harvested from
  :class:`~repro.core.compile.StepMeta.output_bytes` declarations
  (:meth:`SizeModel.from_step_metas`), measured from real payloads'
  ``nbytes`` (:meth:`SizeModel.from_payloads`), or derived from an assigned
  workload shape (:meth:`SizeModel.for_shape` — the same
  ``tokens × d_model × dtype`` activation-boundary model
  :mod:`repro.roofline.analytic` uses for HBM traffic).
* :class:`CostModel` — per-step execution seconds, harvested from
  :class:`~repro.core.compile.StepMeta.expected_seconds` (the same hint the
  runtime's straggler speculation consumes).

Unknown entries fall back to defaults, so a schedule can always be computed;
better estimates just make it better.
"""

from __future__ import annotations

import sys
from dataclasses import dataclass, field, replace
from typing import Any, Mapping

from repro.core.compile import StepMeta


@dataclass(frozen=True)
class SizeModel:
    """Bytes carried by each data element (``default_bytes`` otherwise)."""

    default_bytes: int = 1024
    sizes: Mapping[str, int] = field(default_factory=dict)

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "sizes", {d: int(n) for d, n in dict(self.sizes).items()}
        )

    def bytes_of(self, data: str) -> int:
        return self.sizes.get(data, self.default_bytes)

    def updated(self, sizes: Mapping[str, int]) -> "SizeModel":
        return replace(self, sizes={**self.sizes, **dict(sizes)})

    @classmethod
    def from_step_metas(
        cls,
        metas: Mapping[str, StepMeta | Any],
        *,
        default_bytes: int = 1024,
    ) -> "SizeModel":
        """Harvest ``StepMeta.output_bytes`` declarations from a registry."""
        sizes: dict[str, int] = {}
        for meta in metas.values():
            if isinstance(meta, StepMeta) and meta.output_bytes:
                sizes.update(
                    {d: int(n) for d, n in meta.output_bytes.items()}
                )
        return cls(default_bytes=default_bytes, sizes=sizes)

    @classmethod
    def from_payloads(
        cls,
        payloads: Mapping[Any, Any],
        *,
        default_bytes: int = 1024,
    ) -> "SizeModel":
        """Measure real payloads: ``(location, datum) -> value`` or
        ``datum -> value`` maps; arrays report ``nbytes``, everything else
        ``sys.getsizeof``."""
        sizes: dict[str, int] = {}
        for key, value in payloads.items():
            d = key[1] if isinstance(key, tuple) else key
            nb = getattr(value, "nbytes", None)
            sizes[d] = int(nb) if nb is not None else sys.getsizeof(value)
        return cls(default_bytes=default_bytes, sizes=sizes)

    @classmethod
    def for_shape(
        cls,
        shape,
        *,
        d_model: int | None = None,
        cfg=None,
        dtype_bytes: int = 2,
        sizes: Mapping[str, int] | None = None,
    ) -> "SizeModel":
        """Default every datum to one activation boundary of ``shape``.

        ``shape`` is a :class:`repro.configs.shapes.Shape` or a name from
        :data:`repro.configs.shapes.SHAPES`; the boundary is
        ``tokens × d_model × dtype_bytes`` with ``tokens`` counted as in
        :func:`repro.roofline.analytic.analytic_flops_global` (decode moves
        one row per sequence).  ``d_model`` comes from ``cfg`` (a
        :class:`repro.models.config.ModelConfig`) when not given directly.
        """
        from repro.configs.shapes import SHAPES, Shape

        if isinstance(shape, str):
            shape = SHAPES[shape]
        if not isinstance(shape, Shape):
            raise TypeError(f"not a shape: {shape!r}")
        if d_model is None:
            if cfg is None:
                raise TypeError("for_shape needs d_model= or cfg=")
            d_model = cfg.d_model
        tokens = (
            shape.global_batch
            if shape.kind == "decode"
            else shape.seq_len * shape.global_batch
        )
        return cls(
            default_bytes=int(tokens * d_model * dtype_bytes),
            sizes=sizes or {},
        )


@dataclass(frozen=True)
class CostModel:
    """Execution seconds per step (``default_exec_s`` otherwise)."""

    default_exec_s: float = 1e-3
    costs: Mapping[str, float] = field(default_factory=dict)

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "costs", {s: float(c) for s, c in dict(self.costs).items()}
        )

    def exec_s(self, step: str) -> float:
        return self.costs.get(step, self.default_exec_s)

    def updated(self, costs: Mapping[str, float]) -> "CostModel":
        return replace(self, costs={**self.costs, **dict(costs)})

    @classmethod
    def from_step_metas(
        cls,
        metas: Mapping[str, StepMeta | Any],
        *,
        default_exec_s: float = 1e-3,
    ) -> "CostModel":
        """Harvest ``StepMeta.expected_seconds`` hints from a registry."""
        costs = {
            name: float(meta.expected_seconds)
            for name, meta in metas.items()
            if isinstance(meta, StepMeta) and meta.expected_seconds is not None
        }
        return cls(default_exec_s=default_exec_s, costs=costs)

    @classmethod
    def from_profile(
        cls,
        profile: Any,
        *,
        default_exec_s: float = 1e-3,
    ) -> "CostModel":
        """Calibrate per-step costs from a measured run.

        ``profile`` is a :class:`repro.obs.RunProfile` (anything with an
        ``exec_durations() -> {step: [seconds, ...]}`` method) or a plain
        mapping ``step -> seconds`` / ``step -> [seconds, ...]``.  Each
        step's cost becomes the mean of its measured exec-span durations,
        closing the loop between the simulator's guesses and what a
        backend actually did.
        """
        if hasattr(profile, "exec_durations"):
            samples: Mapping[str, Any] = profile.exec_durations()
        elif isinstance(profile, Mapping):
            samples = profile
        else:
            raise TypeError(
                "from_profile needs a RunProfile or a mapping, got "
                f"{type(profile).__name__}"
            )
        costs: dict[str, float] = {}
        for step, val in samples.items():
            if isinstance(val, (int, float)):
                costs[step] = float(val)
            else:
                vals = [float(v) for v in val]
                if vals:
                    costs[step] = sum(vals) / len(vals)
        return cls(default_exec_s=default_exec_s, costs=costs)
