"""Placement search — decide ``M(s)`` against the network cost model.

Two phases, per the classic list-scheduling literature (HEFT; Bux & Leser's
SWfMS survey):

1. :func:`greedy_placement` — critical-path (upward-rank) ordering, then
   earliest-finish-time assignment per step, accounting for where each input
   datum lives and what the link to it costs.  ``objective="bytes"`` swaps
   the score for incoming cross-location bytes (tie-broken by finish time).
2. :func:`refine_placement` — first-improvement local search: try moving
   each movable step to every other location, score the *real* plan under
   the candidate mapping, keep strict improvements.  Scoring is
   incremental (:class:`~repro.sched.incremental.PlacementScorer`): a move
   patches the affected per-location rows and comm-key index entries and
   re-runs the event schedule through the simulator's array core —
   bit-identical to re-encode + rewrite + simulate, without building
   trees — under an eval budget that keeps 10k-step searches tractable.

Spatially-constrained steps (``|M(s)| > 1`` — collectives like the
trainer's ``gradsync``) and explicitly pinned steps are never moved: their
multi-location mapping is semantics, not scheduling.

Candidates are scored on the re-encoded system *after* the paper's R1+R2
rewrite — that is the integration loop the ISSUE asks for: the scheduler
co-locates producers with consumers, which turns remote sends into local
ones that R1 then deletes, and the score sees exactly the plan that will be
lowered.

:func:`auto_placement` packages both phases plus the round-robin baseline
into a :class:`~repro.sched.report.ScheduleReport`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Mapping

from repro.core.encoding import encode
from repro.core.graph import DistributedWorkflowInstance
from repro.core.optimizer import REWRITE_RULES

from .estimate import CostModel, SizeModel
from .incremental import PlacementScorer, UnsupportedRules
from .network import NetworkModel
from .report import ScheduleReport
from .simulate import Simulation, simulate

#: Lower bound on a step's cost during ranking, so upward ranks strictly
#: decrease along dependency chains (producers always rank above consumers).
_EPS = 1e-9

Placement = dict[str, tuple[str, ...]]


def movable_steps(
    inst: DistributedWorkflowInstance, pin: Iterable[str] = ()
) -> tuple[str, ...]:
    """Steps the scheduler may move: single-location and not pinned."""
    pinned = set(pin)
    return tuple(
        sorted(
            s
            for s in inst.workflow.steps
            if s not in pinned and len(inst.locs_of(s)) == 1
        )
    )


def round_robin_placement(
    inst: DistributedWorkflowInstance,
    *,
    pin: Iterable[str] = (),
) -> Placement:
    """The naive baseline: movable steps dealt round-robin over locations."""
    locs = sorted(inst.locations)
    mapping: Placement = {s: tuple(ls) for s, ls in inst.mapping.items()}
    for i, s in enumerate(movable_steps(inst, pin)):
        mapping[s] = (locs[i % len(locs)],)
    return mapping


def _placed(
    inst: DistributedWorkflowInstance, mapping: Mapping[str, tuple[str, ...]]
) -> DistributedWorkflowInstance:
    """The instance with ``M`` swapped; locations and ``G`` are kept."""
    return dataclasses.replace(inst, mapping=dict(mapping))


def evaluate_placement(
    inst: DistributedWorkflowInstance,
    mapping: Mapping[str, tuple[str, ...]],
    network: NetworkModel,
    *,
    sizes: SizeModel,
    costs: CostModel,
    exec_slots: int | None = 1,
    rules: tuple[str, ...] = ("R1R2",),
) -> Simulation:
    """Re-encode under ``mapping``, apply ``rules``, simulate the result.

    ``rules`` must match what the caller will actually apply to the chosen
    placement (``Plan.schedule`` passes its recorded rule list), so the
    score sees exactly the plan that will be lowered.
    """
    system = encode(_placed(inst, mapping))
    for rule in rules:
        system, _ = REWRITE_RULES[rule](system)
    return simulate(
        system,
        network=network,
        sizes=sizes,
        costs=costs,
        exec_slots=exec_slots,
    )


def _upward_ranks(
    inst: DistributedWorkflowInstance,
    network: NetworkModel,
    sizes: SizeModel,
    costs: CostModel,
) -> dict[str, float]:
    """HEFT upward ranks with location-averaged transfer costs."""
    locs = sorted(inst.locations)
    pairs = [(a, b) for a in locs for b in locs if a != b]

    def avg_transfer(nbytes: int) -> float:
        if not pairs:
            return 0.0
        return sum(
            network.transfer_s(nbytes, a, b) for a, b in pairs
        ) / len(pairs)

    ranks: dict[str, float] = {}
    for s in reversed(inst.workflow.topological_steps()):
        best = 0.0
        for d in inst.out_data(s):
            t = avg_transfer(sizes.bytes_of(d))
            for c in inst.consumers_of_data(d):
                best = max(best, t + ranks.get(c, 0.0))
        ranks[s] = max(costs.exec_s(s), _EPS) + best
    return ranks


def greedy_placement(
    inst: DistributedWorkflowInstance,
    network: NetworkModel,
    *,
    sizes: SizeModel,
    costs: CostModel,
    objective: str = "makespan",
    pin: Iterable[str] = (),
) -> Placement:
    """Critical-path-first earliest-finish-time assignment (see module doc)."""
    network = network.bind(inst.locations)
    locs = sorted(inst.locations)
    movable = set(movable_steps(inst, pin))
    ranks = _upward_ranks(inst, network, sizes, costs)
    order = sorted(inst.workflow.steps, key=lambda s: (-ranks[s], s))

    mapping: Placement = {s: tuple(ls) for s, ls in inst.mapping.items()}
    avail = {l: 0.0 for l in locs}
    # datum -> (resident locations, time it becomes available there)
    data_at: dict[str, tuple[tuple[str, ...], float]] = {}
    for l, ds in sorted(inst.initial_data.items()):
        for d in ds:
            # a datum may start resident on several locations (G lists them
            # independently); keep every copy so the nearest one is charged
            data_at[d] = (data_at.get(d, ((), 0.0))[0] + (l,), 0.0)

    def ready_at(s: str, l: str) -> tuple[float, int]:
        """(earliest input-complete time, incoming cross-location bytes)."""
        t, xbytes = 0.0, 0
        for d in inst.in_data(s):
            if d not in data_at:
                continue  # unsourced datum: assume resident everywhere
            srcs, t_src = data_at[d]
            nbytes = sizes.bytes_of(d)
            src = min(srcs, key=lambda a: network.transfer_s(nbytes, a, l))
            t = max(t, t_src + network.transfer_s(nbytes, src, l))
            if src != l:
                xbytes += nbytes
        return t, xbytes

    for s in order:
        cost = max(costs.exec_s(s), 0.0)
        if s in movable:
            best = None
            for l in locs:
                t_ready, xbytes = ready_at(s, l)
                eft = max(avail[l], t_ready) + cost
                score = (
                    (eft, xbytes, l)
                    if objective == "makespan"
                    else (xbytes, eft, l)
                )
                if best is None or score < best[0]:
                    best = (score, l, eft)
            _, chosen, eft = best
            mapping[s] = (chosen,)
            avail[chosen] = eft
            finish_locs = [chosen]
        else:
            finish_locs = list(mapping[s])
            eft = (
                max(
                    max(avail[l], ready_at(s, l)[0]) for l in finish_locs
                )
                + cost
            )
            for l in finish_locs:
                avail[l] = eft
        for d in inst.out_data(s):
            data_at[d] = (tuple(finish_locs), eft)
    return mapping


#: Operation budget behind the default ``max_evals`` policy: the local
#: search may spend roughly this many action-evaluations (candidate moves ×
#: plan size) before stopping, so refinement cost stays near-constant as
#: plans grow — a 20-step plan gets an exhaustive search, a 10k-step plan an
#: anytime one.  Explicit ``max_evals`` overrides.
_EVAL_OP_BUDGET = 2_500_000


def _default_max_evals(n_actions: int) -> int:
    return max(512, _EVAL_OP_BUDGET // max(1, n_actions))


def refine_placement(
    inst: DistributedWorkflowInstance,
    mapping: Placement,
    network: NetworkModel,
    *,
    sizes: SizeModel,
    costs: CostModel,
    objective: str = "makespan",
    pin: Iterable[str] = (),
    max_rounds: int = 3,
    rules: tuple[str, ...] = ("R1R2",),
    max_evals: int | None = None,
) -> tuple[Placement, Simulation]:
    """First-improvement local search over single-step moves.

    Candidates are scored by the incremental
    :class:`~repro.sched.incremental.PlacementScorer`: when one step moves,
    only the per-location rows and comm-key index entries its placement
    touches are patched, and the event schedule re-runs through the shared
    array core — no re-encoding, no trace trees, bit-identical scores to
    :func:`evaluate_placement` (differentially tested).  Under
    ``objective="bytes"`` a candidate is first screened by its exact byte
    delta and only simulated when it can actually improve the incumbent.

    ``max_evals`` bounds the number of scored candidates (an *anytime*
    search); the default policy scales it inversely with plan size so
    refinement stays tractable at 10k steps.  Rule lists the scorer cannot
    replay fall back to the original re-encode-per-candidate loop.
    """
    network = network.bind(inst.locations)
    locs = sorted(inst.locations)
    movable = movable_steps(inst, pin)

    try:
        scorer = PlacementScorer(
            inst, network, sizes=sizes, costs=costs, rules=rules
        )
    except UnsupportedRules:
        return _refine_placement_tree(
            inst, mapping, network, sizes=sizes, costs=costs,
            objective=objective, pin=pin, max_rounds=max_rounds, rules=rules,
            max_evals=max_evals,
        )

    def score(makespan: float, cross_bytes: int) -> tuple[float, float]:
        if objective == "bytes":
            return (float(cross_bytes), makespan)
        return (makespan, float(cross_bytes))

    current = dict(mapping)
    scorer.reset(current)
    if max_evals is None:
        max_evals = _default_max_evals(scorer.action_count())
    best_score = score(*scorer.score())
    evals = 1
    for _ in range(max_rounds):
        improved = False
        for s in movable:
            home = current[s]
            for l in locs:
                if (l,) == home:
                    continue
                if evals >= max_evals:
                    break
                scorer.move(s, (l,))
                evals += 1
                if objective == "bytes":
                    # Exact byte screen: if the primary key cannot improve,
                    # skip the event schedule entirely.
                    if scorer.cross_bytes_only() > best_score[0]:
                        scorer.move(s, home)
                        continue
                cand = score(*scorer.score())
                if cand < best_score:
                    best_score = cand
                    home = (l,)
                    current[s] = (l,)
                    improved = True
                else:
                    scorer.move(s, home)
            if evals >= max_evals:
                break
        if not improved or evals >= max_evals:
            break
    best_sim = evaluate_placement(
        inst, current, network, sizes=sizes, costs=costs, rules=rules
    )
    return current, best_sim


def _refine_placement_tree(
    inst: DistributedWorkflowInstance,
    mapping: Placement,
    network: NetworkModel,
    *,
    sizes: SizeModel,
    costs: CostModel,
    objective: str,
    pin: Iterable[str],
    max_rounds: int,
    rules: tuple[str, ...],
    max_evals: int | None = None,
) -> tuple[Placement, Simulation]:
    """The original re-encode-per-candidate loop (rule-list fallback).

    An explicit ``max_evals`` caps candidate evaluations here too — the
    per-candidate cost on this path is the superlinear one, so dropping the
    caller's anytime budget would be worst exactly where it matters.  With
    ``max_evals=None`` the loop is exhaustive (legacy behaviour; this
    fallback is only reached for custom rule lists).
    """
    locs = sorted(inst.locations)
    movable = movable_steps(inst, pin)

    def score(sim: Simulation) -> tuple[float, float]:
        if objective == "bytes":
            return (float(sim.cross_bytes), sim.makespan)
        return (sim.makespan, float(sim.cross_bytes))

    current = dict(mapping)
    best_sim = evaluate_placement(
        inst, current, network, sizes=sizes, costs=costs, rules=rules
    )
    best_score = score(best_sim)
    evals = 1
    exhausted = False
    for _ in range(max_rounds):
        improved = False
        for s in movable:
            home = current[s]
            for l in locs:
                if (l,) == home:
                    continue
                if max_evals is not None and evals >= max_evals:
                    exhausted = True
                    break
                current[s] = (l,)
                evals += 1
                sim = evaluate_placement(
                    inst, current, network,
                    sizes=sizes, costs=costs, rules=rules,
                )
                if score(sim) < best_score:
                    best_score, best_sim = score(sim), sim
                    home = (l,)
                    improved = True
            current[s] = home
            if exhausted:
                break
        if not improved or exhausted:
            break
    return current, best_sim


def auto_placement(
    inst: DistributedWorkflowInstance,
    network: NetworkModel | None = None,
    *,
    objective: str = "makespan",
    sizes: SizeModel | None = None,
    costs: CostModel | None = None,
    refine: bool = True,
    pin: Iterable[str] = (),
    rules: tuple[str, ...] = ("R1R2",),
    max_evals: int | None = None,
) -> ScheduleReport:
    """Greedy + (optional) local search, reported against round-robin.

    ``max_evals`` bounds the refinement's candidate evaluations (see
    :func:`refine_placement`); the default policy keeps search cost
    near-constant across plan sizes.
    """
    if objective not in ("makespan", "bytes"):
        raise ValueError(
            f"objective must be 'makespan' or 'bytes', got {objective!r}"
        )
    network = (network or NetworkModel.preset("uniform")).bind(inst.locations)
    sizes = sizes or SizeModel()
    costs = costs or CostModel()

    t0 = time.perf_counter()
    mapping = greedy_placement(
        inst, network, sizes=sizes, costs=costs, objective=objective, pin=pin
    )
    if refine:
        mapping, predicted = refine_placement(
            inst, mapping, network,
            sizes=sizes, costs=costs, objective=objective, pin=pin,
            rules=rules, max_evals=max_evals,
        )
    else:
        predicted = evaluate_placement(
            inst, mapping, network, sizes=sizes, costs=costs, rules=rules
        )
    search_s = time.perf_counter() - t0

    baseline_mapping = round_robin_placement(inst, pin=pin)
    baseline = evaluate_placement(
        inst, baseline_mapping, network, sizes=sizes, costs=costs, rules=rules
    )
    return ScheduleReport(
        objective=objective,
        network=network,
        placement=mapping,
        baseline_placement=baseline_mapping,
        predicted=predicted,
        baseline=baseline,
        search_seconds=search_s,
    )
