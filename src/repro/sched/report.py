"""Schedule report — what the placement search chose and what it predicts.

Attached to :class:`repro.api.Plan` by ``Plan.schedule`` /
``Plan.lower(placement="auto")`` and rendered by ``Plan.explain``; also
handed down to every backend as the uniform ``schedule`` lowering option
(the JAX backend uses the network groups to co-locate rack members on
devices).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping

from .network import NetworkModel
from .simulate import Simulation


@dataclass(frozen=True)
class ScheduleReport:
    """Chosen placement + predictions, against the round-robin baseline."""

    objective: str
    network: NetworkModel
    placement: Mapping[str, tuple[str, ...]]
    baseline_placement: Mapping[str, tuple[str, ...]]
    predicted: Simulation
    baseline: Simulation
    search_seconds: float = 0.0

    def __post_init__(self) -> None:
        object.__setattr__(self, "placement", dict(self.placement))
        object.__setattr__(
            self, "baseline_placement", dict(self.baseline_placement)
        )

    @property
    def bytes_saved(self) -> int:
        return self.baseline.cross_bytes - self.predicted.cross_bytes

    @property
    def bytes_saved_frac(self) -> float:
        if self.baseline.cross_bytes == 0:
            return 0.0
        return self.bytes_saved / self.baseline.cross_bytes

    @property
    def makespan_speedup(self) -> float:
        if self.predicted.makespan == 0:
            return 1.0
        return self.baseline.makespan / self.predicted.makespan

    def summary(self) -> str:
        lines = [
            f"objective: {self.objective}   network: {self.network.name}"
            + (f"   search: {self.search_seconds * 1e3:.0f} ms"),
            f"predicted makespan: {self.predicted.makespan * 1e3:.2f} ms "
            f"(round-robin {self.baseline.makespan * 1e3:.2f} ms, "
            f"{self.makespan_speedup:.2f}x)",
            f"cross-location bytes: {self.predicted.cross_bytes} "
            f"(round-robin {self.baseline.cross_bytes}, "
            f"saved {self.bytes_saved_frac * 100:.0f}%)",
        ]
        lines.append("placement (step -> M(s)):")
        for s, locs in sorted(self.placement.items()):
            lines.append(f"    {s:<24} {', '.join(locs)}")
        if self.predicted.critical_path:
            lines.append(
                "critical path: "
                + " -> ".join(self.predicted.critical_path)
            )
        return "\n".join(lines)
