"""``repro.sched`` — cost-model-driven placement & data-movement scheduling.

The layer between ``Plan`` and ``Lowered``: SWIRL's rewriting (R1-R3)
deletes *redundant* communications, this subsystem decides *where steps run*
so that communications become redundant in the first place.

Pieces:

* :class:`NetworkModel` / :class:`Link` — per-location-pair bandwidth and
  latency, with named presets (``uniform``, ``two-rack``,
  ``cpu+accelerator``);
* :class:`SizeModel` / :class:`CostModel` — payload byte-sizes and step
  exec-seconds, harvested from :class:`~repro.core.compile.StepMeta`, real
  payloads, or assigned workload shapes;
* :func:`simulate` — replay a plan's traces against the cost model:
  per-location timelines, makespan, critical path, cross-location bytes;
* :func:`auto_placement` (+ :func:`greedy_placement`,
  :func:`refine_placement`, :func:`round_robin_placement`) — critical-path
  greedy placement with local-search refinement, reported as a
  :class:`ScheduleReport`.

Front door: ``plan.schedule(network=NetworkModel.preset("two-rack"))`` or
``plan.lower(backend, placement="auto", network=...)``.
"""

from .estimate import CostModel, SizeModel
from .incremental import PlacementScorer, UnsupportedRules
from .network import LOCAL_LINK, Link, NetworkModel
from .place import (
    auto_placement,
    evaluate_placement,
    greedy_placement,
    movable_steps,
    refine_placement,
    round_robin_placement,
)
from .report import ScheduleReport
from .simulate import SimEvent, Simulation, SimulationError, simulate

__all__ = [
    "Link",
    "LOCAL_LINK",
    "NetworkModel",
    "SizeModel",
    "CostModel",
    "simulate",
    "Simulation",
    "SimEvent",
    "SimulationError",
    "auto_placement",
    "greedy_placement",
    "refine_placement",
    "round_robin_placement",
    "evaluate_placement",
    "movable_steps",
    "PlacementScorer",
    "UnsupportedRules",
    "ScheduleReport",
]
