"""Network cost model — per-location-pair bandwidth/latency.

SWIRL's stated purpose is the *automatic optimisation of data movements*;
deciding where steps should run requires an explicit model of what a
``send``/``recv`` between two locations costs.  :class:`NetworkModel` maps
ordered location pairs to :class:`Link` parameters (bandwidth in bytes/s,
latency in seconds) with three resolution layers, most specific first:

1. an explicit per-pair entry in ``links``;
2. the pair's *group* link — locations are partitioned into named groups
   (racks, host classes) and ``group_links`` prices each group pair;
3. the ``default`` link.

Intra-location movement is always free (``src == dst`` — exactly the
transfers rule R1 deletes).

Named presets cover the common topologies (the Bux & Leser SWfMS-scheduling
survey's machine models):

* ``uniform``          — every pair identical (a flat cluster);
* ``two-rack``         — fast intra-rack, slow inter-rack links; racks are
  given explicitly or assigned at :meth:`bind` time (sorted locations split
  in half);
* ``cpu+accelerator``  — a slow host tier and a fast accelerator tier joined
  by a PCIe-class link; the host tier is given explicitly or inferred from
  location names at :meth:`bind` time.

Presets that need the location set (``two-rack`` without ``racks=``,
``cpu+accelerator`` without ``cpu=``) stay *unbound* until
:meth:`NetworkModel.bind` is called with the system's locations —
``Plan.schedule`` and :func:`repro.sched.simulate.simulate` bind
automatically.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Iterable, Mapping


@dataclass(frozen=True)
class Link:
    """One directed link: ``transfer_s = latency + nbytes / bandwidth``."""

    bandwidth: float  # bytes per second
    latency: float = 0.0  # seconds

    def __post_init__(self) -> None:
        if self.bandwidth <= 0:
            raise ValueError(f"bandwidth must be positive: {self.bandwidth}")
        if self.latency < 0:
            raise ValueError(f"latency must be non-negative: {self.latency}")

    def transfer_s(self, nbytes: float) -> float:
        if self.bandwidth == float("inf"):
            return self.latency
        return self.latency + nbytes / self.bandwidth


#: The implicit intra-location link: moving data to yourself is free.
LOCAL_LINK = Link(bandwidth=float("inf"), latency=0.0)


@dataclass(frozen=True)
class NetworkModel:
    """Per-location-pair link parameters with group-level defaults."""

    default: Link = field(default_factory=lambda: Link(1e9, 100e-6))
    links: Mapping[tuple[str, str], Link] = field(default_factory=dict)
    groups: Mapping[str, frozenset[str]] = field(default_factory=dict)
    group_links: Mapping[tuple[str, str], Link] = field(default_factory=dict)
    #: Group assigned to locations not listed in any ``groups`` entry.
    open_group: str | None = None
    name: str = "custom"
    # Preset still awaiting the location set (see bind()).
    _pending: str | None = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "links", dict(self.links))
        object.__setattr__(
            self,
            "groups",
            {g: frozenset(ms) for g, ms in dict(self.groups).items()},
        )
        object.__setattr__(self, "group_links", dict(self.group_links))
        seen: dict[str, str] = {}
        for g, ms in self.groups.items():
            for l in ms:
                if l in seen:
                    raise ValueError(
                        f"location {l!r} is in groups {seen[l]!r} and {g!r}"
                    )
                seen[l] = g

    # -- resolution ---------------------------------------------------------
    def group_of(self, location: str) -> str | None:
        for g, members in self.groups.items():
            if location in members:
                return g
        return self.open_group

    def link(self, src: str, dst: str) -> Link:
        """The link used for a ``src -> dst`` transfer (LOCAL if same)."""
        if src == dst:
            return LOCAL_LINK
        hit = self.links.get((src, dst))
        if hit is not None:
            return hit
        gs, gd = self.group_of(src), self.group_of(dst)
        if gs is not None and gd is not None:
            hit = self.group_links.get((gs, gd)) or self.group_links.get(
                (gd, gs)
            )
            if hit is not None:
                return hit
        return self.default

    def transfer_s(self, nbytes: float, src: str, dst: str) -> float:
        """Seconds to move ``nbytes`` from ``src`` to ``dst``."""
        return self.link(src, dst).transfer_s(nbytes)

    # -- binding ------------------------------------------------------------
    def bind(self, locations: Iterable[str]) -> "NetworkModel":
        """Resolve a location-dependent preset against a concrete system.

        Idempotent: an already-bound (or never-pending) model returns a model
        with the same pricing.  Locations not covered by any group fall back
        to the ``default`` link.
        """
        locs = sorted(set(locations))
        if self._pending is None:
            return self
        if self._pending == "two-rack":
            half = (len(locs) + 1) // 2
            groups = {
                "rack0": frozenset(locs[:half]),
                "rack1": frozenset(locs[half:]),
            }
            return replace(self, groups=groups, _pending=None)
        if self._pending == "cpu+accelerator":
            cpu = frozenset(
                l
                for l in locs
                if "cpu" in l.lower() or "host" in l.lower() or l == "l^d"
            )
            if not cpu and locs:
                cpu = frozenset(locs[:1])
            groups = {
                "cpu": cpu,
                "accel": frozenset(l for l in locs if l not in cpu),
            }
            return replace(self, groups=groups, _pending=None)
        raise ValueError(f"unknown pending preset {self._pending!r}")

    # -- presets ------------------------------------------------------------
    @classmethod
    def preset(cls, name: str, **kw) -> "NetworkModel":
        """Named topologies: ``uniform``, ``two-rack``, ``cpu+accelerator``.

        ``uniform(bandwidth=, latency=)`` — one link everywhere.

        ``two-rack(racks={"rack0": [...], "rack1": [...]}, intra=Link,
        inter=Link)`` — without ``racks=`` the sorted location set is split
        in half at :meth:`bind` time.

        ``cpu+accelerator(cpu=[...], cpu_link=, accel_link=, pcie=)`` —
        without ``cpu=`` the host tier is inferred at :meth:`bind` time from
        location names (``cpu``/``host``/``l^d``), falling back to the first
        sorted location.
        """
        if name == "uniform":
            link = Link(
                bandwidth=float(kw.pop("bandwidth", 1e9)),
                latency=float(kw.pop("latency", 100e-6)),
            )
            _reject_extra(name, kw)
            return cls(default=link, name=name)
        if name == "two-rack":
            intra = kw.pop("intra", Link(10e9, 10e-6))
            inter = kw.pop("inter", Link(1e9, 500e-6))
            racks = kw.pop("racks", None)
            _reject_extra(name, kw)
            group_links = {
                ("rack0", "rack0"): intra,
                ("rack1", "rack1"): intra,
                ("rack0", "rack1"): inter,
            }
            if racks is not None:
                groups = {g: frozenset(ms) for g, ms in dict(racks).items()}
                unknown = set(groups) - {"rack0", "rack1"}
                if unknown:
                    raise ValueError(
                        f"two-rack racks must be named rack0/rack1, got "
                        f"{sorted(unknown)}"
                    )
                return cls(
                    default=inter,
                    groups=groups,
                    group_links=group_links,
                    name=name,
                )
            return cls(
                default=inter,
                group_links=group_links,
                name=name,
                _pending="two-rack",
            )
        if name == "cpu+accelerator":
            cpu_link = kw.pop("cpu_link", Link(1e9, 100e-6))
            accel_link = kw.pop("accel_link", Link(50e9, 5e-6))
            pcie = kw.pop("pcie", Link(16e9, 20e-6))
            cpu = kw.pop("cpu", None)
            _reject_extra(name, kw)
            group_links = {
                ("cpu", "cpu"): cpu_link,
                ("accel", "accel"): accel_link,
                ("cpu", "accel"): pcie,
            }
            if cpu is not None:
                return cls(
                    default=pcie,
                    groups={"cpu": frozenset(cpu)},
                    group_links=group_links,
                    open_group="accel",  # everything else is the fast tier
                    name=name,
                )
            return cls(
                default=pcie,
                group_links=group_links,
                name=name,
                _pending="cpu+accelerator",
            )
        raise ValueError(
            f"unknown network preset {name!r}; "
            "known: uniform, two-rack, cpu+accelerator"
        )

    # -- introspection ------------------------------------------------------
    def describe(self) -> str:
        lines = [f"network: {self.name}"]
        if self._pending:
            lines.append("  (unbound preset — call .bind(locations))")
        for g, members in sorted(self.groups.items()):
            lines.append(f"  {g}: {', '.join(sorted(members))}")
        return "\n".join(lines)


def _reject_extra(name: str, kw: dict) -> None:
    if kw:
        raise TypeError(
            f"unknown arguments for preset {name!r}: {sorted(kw)}"
        )
