"""Makespan simulator — replay a plan's SWIRL traces against a cost model.

The simulator turns a :class:`~repro.core.syntax.WorkflowSystem` into a
timed precedence DAG and computes, without executing anything:

* per-location **timelines** (when each exec/send/recv happens),
* the **makespan** and the **critical path** through it,
* total **cross-location bytes** (and the per-pair breakdown) — the
  quantity SWIRL's rewriting exists to minimise.

The timing model follows the send/receive semantics of the paper:

* ``exec`` occupies every location of ``M(s)`` for ``CostModel.exec_s``
  seconds, starting when all of them are ready (the (EXEC) rule's
  synchronised reduction);
* ``send`` is fire-and-forget — the payload *arrives* at the destination
  ``Link.transfer_s(bytes)`` later, but the sender continues immediately, so
  communication overlaps computation exactly as the decentralised threaded
  runtime overlaps it;
* ``recv`` completes at ``max(local readiness, matching send + transfer)``;
* intra-location transfers are free (they are what rule R1 deletes);
* ``Seq`` serialises, ``Par`` overlaps — the trace structure *is* the
  dependency graph, matching one thread per parallel branch at runtime.

Sends and recvs pair up per ``(src, dst, port)`` channel in program order
(the channels are FIFOs).  A recv with no matching send would block forever
at runtime, so the simulator raises :class:`SimulationError` for it.

``exec_slots`` optionally bounds how many execs one location can run
concurrently (list scheduling): ``None`` models the threaded runtime's
one-thread-per-branch behaviour; ``1`` models classic one-worker-per-machine
SWfMS scheduling and is what the placement search optimises against.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Mapping

from repro.core.syntax import (
    Action,
    Exec,
    Nil,
    Par,
    Recv,
    Send,
    Seq,
    Trace,
    WorkflowSystem,
    is_action,
)

from .estimate import CostModel, SizeModel
from .network import NetworkModel


class SimulationError(RuntimeError):
    """The system cannot be replayed (unmatched recv / cyclic channel wait)."""


@dataclass(frozen=True)
class SimEvent:
    """One timeline entry at a location.

    ``name`` is the bare subject of the event — the step for ``exec``,
    the datum for ``send``, the port for ``recv`` — so profilers can
    match predicted events against recorded spans without parsing
    ``label``.
    """

    start: float
    end: float
    kind: str  # "exec" | "send" | "recv"
    label: str
    name: str | None = None

    def pretty(self) -> str:
        return f"[{self.start * 1e3:8.2f}ms → {self.end * 1e3:8.2f}ms] {self.label}"


@dataclass(frozen=True)
class Simulation:
    """What the replay predicted."""

    makespan: float
    timelines: Mapping[str, tuple[SimEvent, ...]]
    critical_path: tuple[str, ...]
    cross_bytes: int
    bytes_by_pair: Mapping[tuple[str, str], int]
    comm_seconds: float  # summed cross-location transfer time
    exec_seconds: float  # summed exec durations (work, not wall-clock)

    def summary(self) -> str:
        lines = [
            f"makespan: {self.makespan * 1e3:.2f} ms  "
            f"(exec work {self.exec_seconds * 1e3:.2f} ms, "
            f"cross-location transfer {self.comm_seconds * 1e3:.2f} ms)",
            f"cross-location bytes: {self.cross_bytes}",
        ]
        if self.critical_path:
            lines.append("critical path: " + " -> ".join(self.critical_path))
        return "\n".join(lines)


@dataclass
class _Node:
    """One action occurrence in one location's trace (program order id)."""

    nid: int
    location: str
    action: Action
    preds: set[int] = field(default_factory=set)


@dataclass
class _Event:
    """A schedulable unit: one comm occurrence, or one synchronised exec."""

    eid: int
    kind: str
    locations: tuple[str, ...]
    label: str
    duration: float
    preds: set[int] = field(default_factory=set)
    action: Action | None = None


def _collect_nodes(location: str, trace: Trace, start_id: int) -> list[_Node]:
    """Flatten a trace into nodes with structural precedence edges.

    Node ids follow program order (the :func:`~repro.core.syntax.actions`
    traversal), which is also the FIFO order of channel operations.
    """
    nodes: list[_Node] = []

    def build(t: Trace, preds: set[int]) -> set[int]:
        if isinstance(t, Nil):
            return preds
        if is_action(t):
            nid = start_id + len(nodes)
            nodes.append(_Node(nid, location, t, set(preds)))
            return {nid}
        if isinstance(t, Seq):
            cur = preds
            for item in t.items:
                cur = build(item, cur)
            return cur
        if isinstance(t, Par):
            exits: set[int] = set()
            for b in t.branches:
                exits |= build(b, preds)
            return exits
        raise TypeError(f"not a trace: {t!r}")

    build(trace, set())
    return nodes


def run_event_schedule(
    preds: list,
    durations: list[float],
    exec_locations: list,
    comm_edges: Mapping[int, tuple[int, float]],
    exec_slots: int | None,
    locations,
) -> tuple[list[float], list[float], list, int]:
    """Event-driven longest path / list scheduling over plain arrays.

    The shared core behind :func:`simulate` and the placement search's
    incremental scorer (:mod:`repro.sched.incremental`): event ``i`` has
    predecessor ids ``preds[i]``, runs for ``durations[i]`` seconds, and —
    when it is a (possibly multi-location) exec — occupies one slot on each
    of ``exec_locations[i]`` (``None`` marks comm events, which never
    contend).  ``comm_edges[recv] = (send, transfer_s)`` adds the transfer
    latency on exactly that edge.  Ties break on event id, so callers that
    construct events in the same order get bit-identical schedules.

    Returns ``(start, finish, crit_pred, unfinished)``; a non-empty
    ``unfinished`` (event ids never scheduled) means a cyclic wait — the
    caller decides how to report it.
    """
    n_events = len(preds)
    indeg = [len(p) for p in preds]
    succs: dict[int, list[int]] = {}
    for eid, ps in enumerate(preds):
        for p in ps:
            succs.setdefault(p, []).append(eid)

    ready = [0.0] * n_events
    crit_pred: list[int | None] = [None] * n_events
    start = [0.0] * n_events
    finish = [0.0] * n_events
    slot_free: dict[str, list[float]] = {}
    single_free: dict[str, float] = {}
    single_slot = exec_slots == 1  # scalar fast path: one worker per machine
    if exec_slots is not None:
        if exec_slots < 1:
            raise ValueError(f"exec_slots must be >= 1: {exec_slots}")
        if single_slot:
            single_free = {loc: 0.0 for loc in locations}
        else:
            slot_free = {loc: [0.0] * exec_slots for loc in locations}

    heap: list[tuple[float, int]] = [
        (0.0, eid) for eid in range(n_events) if indeg[eid] == 0
    ]
    heapq.heapify(heap)
    done = 0
    while heap:
        _, eid = heapq.heappop(heap)
        t = ready[eid]
        ev_locs = exec_locations[eid]
        if ev_locs is not None and exec_slots is not None:
            if single_slot:
                for loc in ev_locs:
                    busy_until = single_free[loc]
                    if busy_until > t:
                        t = busy_until
                end = t + durations[eid]
                for loc in ev_locs:
                    single_free[loc] = end
            else:
                for loc in ev_locs:
                    t = max(t, min(slot_free[loc]))
                end = t + durations[eid]
                for loc in ev_locs:
                    free = slot_free[loc]
                    free[free.index(min(free))] = end
        start[eid] = t
        fin = finish[eid] = t + durations[eid]
        done += 1
        for s in succs.get(eid, ()):
            weight = 0.0
            edge = comm_edges.get(s)
            if edge is not None and edge[0] == eid:
                weight = edge[1]
            cand = fin + weight
            if cand >= ready[s]:
                ready[s] = cand
                crit_pred[s] = eid
            indeg[s] -= 1
            if indeg[s] == 0:
                heapq.heappush(heap, (ready[s], s))
    unfinished = (
        [] if done == n_events else [e for e in range(n_events) if indeg[e] > 0]
    )
    return start, finish, crit_pred, unfinished


def simulate(
    system: WorkflowSystem,
    *,
    network: NetworkModel | None = None,
    sizes: SizeModel | None = None,
    costs: CostModel | None = None,
    exec_slots: int | None = None,
) -> Simulation:
    """Replay ``system``'s traces against the cost model (see module doc)."""
    network = (network or NetworkModel.preset("uniform")).bind(
        system.locations()
    )
    sizes = sizes or SizeModel()
    costs = costs or CostModel()

    # 1. Per-location nodes with structural precedence.
    nodes: list[_Node] = []
    for cfg in system.configs:
        nodes.extend(_collect_nodes(cfg.location, cfg.trace, len(nodes)))

    # 2. Merge the per-location occurrences of one synchronised exec into a
    #    single event; comm occurrences become one event each.
    exec_sites: dict[Exec, dict[str, list[int]]] = {}
    for n in nodes:
        if isinstance(n.action, Exec):
            exec_sites.setdefault(n.action, {}).setdefault(
                n.location, []
            ).append(n.nid)

    events: list[_Event] = []
    node_event: dict[int, int] = {}

    def new_event(
        kind: str, locations: tuple[str, ...], label: str,
        duration: float, members: list[int], action: Action,
    ) -> None:
        eid = len(events)
        events.append(
            _Event(eid, kind, locations, label, duration, action=action)
        )
        for nid in members:
            node_event[nid] = eid

    for act in sorted(exec_sites, key=lambda a: a.pretty()):
        sites = exec_sites[act]
        depth = max(len(ids) for ids in sites.values())
        for k in range(depth):
            members = [
                ids[k] for ids in sites.values() if k < len(ids)
            ]
            locs = tuple(
                sorted(l for l, ids in sites.items() if k < len(ids))
            )
            new_event(
                "exec", locs, f"exec({act.step})@{','.join(locs)}",
                max(costs.exec_s(act.step), 0.0), members, act,
            )
    for n in nodes:
        if isinstance(n.action, Send):
            a = n.action
            new_event(
                "send", (n.location,),
                f"send({a.data})@{a.src}->{a.dst}", 0.0, [n.nid], a,
            )
        elif isinstance(n.action, Recv):
            a = n.action
            new_event(
                "recv", (n.location,),
                f"recv({a.port})@{a.dst}<-{a.src}", 0.0, [n.nid], a,
            )

    # Structural precedence, lifted node -> event.
    for n in nodes:
        ev = events[node_event[n.nid]]
        for p in n.preds:
            pe = node_event[p]
            if pe != ev.eid:
                ev.preds.add(pe)

    # 3. FIFO channel matching: k-th send pairs with k-th recv.
    sends: dict[tuple[str, str, str], list[int]] = {}
    recvs: dict[tuple[str, str, str], list[int]] = {}
    for n in nodes:  # nid order == program order per location
        if isinstance(n.action, Send):
            sends.setdefault(
                (n.action.src, n.action.dst, n.action.port), []
            ).append(node_event[n.nid])
        elif isinstance(n.action, Recv):
            recvs.setdefault(
                (n.action.src, n.action.dst, n.action.port), []
            ).append(node_event[n.nid])

    comm_edges: dict[int, tuple[int, float]] = {}  # recv event -> (send, s)
    cross_bytes = 0
    bytes_by_pair: dict[tuple[str, str], int] = {}
    comm_seconds = 0.0
    for chan, rlist in recvs.items():
        slist = sends.get(chan, [])
        if len(rlist) > len(slist):
            raise SimulationError(
                f"{len(rlist) - len(slist)} recv(s) on channel {chan} have "
                "no matching send — the plan would deadlock"
            )
        for seid, reid in zip(slist, rlist):
            send_act = events[seid].action
            assert isinstance(send_act, Send)
            nbytes = sizes.bytes_of(send_act.data)
            transfer = network.transfer_s(nbytes, send_act.src, send_act.dst)
            comm_edges[reid] = (seid, transfer)
            events[reid].preds.add(seid)
            if send_act.src != send_act.dst:
                cross_bytes += nbytes
                pair = (send_act.src, send_act.dst)
                bytes_by_pair[pair] = bytes_by_pair.get(pair, 0) + nbytes
                comm_seconds += transfer

    # 4. Event-driven longest path (list scheduling when exec_slots is set),
    #    via the shared array core.
    n_events = len(events)
    start, finish, crit_pred, unfinished = run_event_schedule(
        [ev.preds for ev in events],
        [ev.duration for ev in events],
        [ev.locations if ev.kind == "exec" else None for ev in events],
        comm_edges,
        exec_slots,
        system.locations(),
    )
    if unfinished:
        stuck = [events[eid].label for eid in unfinished[:5]]
        raise SimulationError(
            "cyclic channel wait — the plan cannot be replayed; "
            f"stuck events include {stuck}"
        )

    # 5. Reports.
    makespan = max(finish, default=0.0)
    timelines: dict[str, list[SimEvent]] = {
        loc: [] for loc in system.locations()
    }
    for ev in events:
        act = ev.action
        if isinstance(act, Exec):
            name: str | None = act.step
        elif isinstance(act, Send):
            name = act.data
        elif isinstance(act, Recv):
            name = act.port
        else:
            name = None
        entry = SimEvent(
            start[ev.eid], finish[ev.eid], ev.kind, ev.label, name
        )
        for loc in ev.locations:
            timelines[loc].append(entry)
    for loc in timelines:
        timelines[loc].sort(key=lambda e: (e.start, e.end, e.label))

    path: list[str] = []
    if events:
        cur: int | None = max(range(n_events), key=lambda i: finish[i])
        while cur is not None:
            path.append(events[cur].label)
            cur = crit_pred[cur]
        path.reverse()

    return Simulation(
        makespan=makespan,
        timelines={loc: tuple(tl) for loc, tl in timelines.items()},
        critical_path=tuple(path),
        cross_bytes=cross_bytes,
        bytes_by_pair=bytes_by_pair,
        comm_seconds=comm_seconds,
        exec_seconds=sum(
            ev.duration for ev in events if ev.kind == "exec"
        ),
    )
