"""Three-term roofline from a compiled dry-run artifact.

TPU v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.  ``cost_analysis()`` of a partitioned executable reports the
*per-device* program, so the terms are:

    compute    = flops_per_device / 197e12
    memory     = hbm_bytes_per_device / 819e9
    collective = link_bytes_per_device / 50e9

MODEL_FLOPS uses the classic 6·N·D (train) / 2·N·D (inference) with
N = active params for MoE; the ratio MODEL_FLOPS / (HLO flops × chips)
surfaces remat and dispatch overheads.  Analytic corrections for FLOPs that
hide inside ``lax.scan`` loops (sLSTM) are added by the caller via
``extra_flops``.
"""

from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link


@dataclass(frozen=True)
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    hbm_bytes_per_device: float
    link_bytes_per_device: float
    model_flops_global: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Lower-bound step time = max of the three overlap-able terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / compiled FLOPs (global)."""
        total = self.flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilisation if the step ran at the roofline bound."""
        if self.bound_s == 0:
            return 0.0
        return self.model_flops_global / (self.chips * PEAK_FLOPS * self.bound_s)

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_s": self.bound_s,
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "link_bytes_per_device": self.link_bytes_per_device,
            "model_flops_global": self.model_flops_global,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu_bound": self.mfu_bound,
            "chips": self.chips,
        }


def roofline(
    *,
    flops_per_device: float,
    hbm_bytes_per_device: float,
    link_bytes_per_device: float,
    model_flops_global: float,
    chips: int,
) -> Roofline:
    return Roofline(
        compute_s=flops_per_device / PEAK_FLOPS,
        memory_s=hbm_bytes_per_device / HBM_BW,
        collective_s=link_bytes_per_device / ICI_BW,
        flops_per_device=flops_per_device,
        hbm_bytes_per_device=hbm_bytes_per_device,
        link_bytes_per_device=link_bytes_per_device,
        model_flops_global=model_flops_global,
        chips=chips,
    )


def model_flops(cfg, shape) -> float:
    """6·N_active·tokens (train) or 2·N_active·tokens (serve)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def slstm_extra_flops(cfg, shape) -> float:
    """Analytic FLOPs hidden in the sLSTM lax.scan (cost_analysis counts the
    while body once).  Per step: 4 recurrent matmuls (2·d² each) + ~20·d
    elementwise, per token, per sLSTM layer."""
    n_slstm = sum(1 for mix, _ in cfg.layer_seq() if mix == "slstm")
    if n_slstm == 0:
        return 0.0
    if shape.kind == "decode":
        tokens = shape.global_batch
    else:
        tokens = shape.seq_len * shape.global_batch
    per_token_layer = 4 * 2 * cfg.d_model**2 + 20 * cfg.d_model
    # scan body counted once by cost_analysis → missing (T-1)/T ≈ all of it
    return float(n_slstm) * tokens * per_token_layer
