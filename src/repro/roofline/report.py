"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run JSONs.

Usage::

    PYTHONPATH=src python -m repro.roofline.report experiments/dryrun
"""

from __future__ import annotations

import json
import sys
from pathlib import Path


def load(dir_: str | Path) -> list[dict]:
    recs = [json.loads(p.read_text()) for p in sorted(Path(dir_).glob("*.json"))]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9), r["mesh"]))
    return recs


def _f(x: float) -> str:
    if x == 0:
        return "0"
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.2f}"
    return f"{x:.2ps}" if False else f"{x:.3g}"


def dryrun_table(recs: list[dict]) -> str:
    rows = [
        "| arch | shape | mesh | status | compile s | HLO flops/dev | arg GB/dev | temp GB/dev | collectives (count) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP ({r['reason'][:40]}…) | | | | | |"
            )
            continue
        mem = r.get("memory_analysis", {})
        arg = mem.get("argument_bytes", 0) / 2**30
        tmp = mem.get("temp_bytes", 0) / 2**30
        cols = r.get("collectives", {}).get("count", {})
        colstr = ", ".join(f"{k.split('-')[0]}-{k.split('-')[1] if '-' in k else ''}:{v}" for k, v in sorted(cols.items()))
        colstr = ", ".join(f"{k}:{v}" for k, v in sorted(cols.items()))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} "
            f"| {r.get('compile_s', 0):.0f} "
            f"| {r['cost_analysis']['flops']:.3g} "
            f"| {arg:.1f} | {tmp:.1f} | {colstr} |"
        )
    return "\n".join(rows)


def roofline_table(recs: list[dict], mesh: str = "pod1") -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant | bound s | useful-FLOP frac | MFU bound |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh or r.get("status") != "ok":
            continue
        rl = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {_f(rl['compute_s'])} | {_f(rl['memory_s'])} "
            f"| {_f(rl['collective_s'])} | **{rl['dominant']}** "
            f"| {_f(rl['bound_s'])} | {rl['useful_flops_fraction']:.2f} "
            f"| {rl['mfu_bound'] * 100:.1f}% |"
        )
    return "\n".join(rows)


def interesting_cells(recs: list[dict], mesh: str = "pod1") -> list[dict]:
    """Worst MFU bound, most collective-bound, most SWIRL-representative."""
    ok = [r for r in recs if r["mesh"] == mesh and r.get("status") == "ok"]
    worst = min(ok, key=lambda r: r["roofline"]["mfu_bound"])
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
    return [worst, coll]


def main() -> None:
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(d)
    n_ok = sum(1 for r in recs if r.get("status") == "ok")
    n_skip = sum(1 for r in recs if r.get("status") == "skipped")
    print(f"## Dry-run: {n_ok} compiled, {n_skip} documented skips\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16×16, per step)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
