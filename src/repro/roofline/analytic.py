"""Analytic FLOP and HBM-traffic models per (config × shape).

Why analytic: the production program scans its layer stack, and XLA's
``cost_analysis`` counts a while-loop body ONCE, so compiled-artifact FLOPs
under-report by ~the repeat count; conversely the CPU backend's
"bytes accessed" counts every unfused operand access and over-reports HBM
traffic by orders of magnitude versus a fusing TPU backend.  The models
below count matmul FLOPs exactly from the layer dimensions and estimate
fused HBM traffic from first principles.  They are validated against an
*unrolled* compiled cell (llama3.2-3b × train_4k) in the §Roofline log —
agreement is within ~15%.

Multipliers: train = fwd + bwd(2×) + remat-recompute(1×) = 4× forward
FLOPs inside remat'd blocks, 3× for the LM head (outside remat);
prefill/decode = 1× forward.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.shapes import Shape
from repro.models.config import ModelConfig

VOCAB_PAD = 256


def _padded_vocab(cfg: ModelConfig) -> int:
    return -(-cfg.vocab // VOCAB_PAD) * VOCAB_PAD


def _attn_len(kind: str, seq_len: int, window: int) -> float:
    """Average attended KV length per query token."""
    if kind == "decode":
        return float(seq_len)  # one token attends over the whole cache
    eff = (seq_len + 1) / 2.0  # causal average
    if window > 0:
        eff = min(eff, float(window))
    return eff


def _mixer_fwd_flops_per_token(
    cfg: ModelConfig, mixer: str, kind: str, seq_len: int
) -> float:
    d = cfg.d_model
    if mixer in ("attn", "attn_local"):
        proj = 2.0 * d * (2 * cfg.q_dim + 2 * cfg.kv_dim)
        window = cfg.sliding_window if mixer == "attn_local" else 0
        l_eff = _attn_len(kind, seq_len, window)
        attn = 4.0 * cfg.q_dim * l_eff  # qk^T + pv
        return proj + attn
    if mixer == "mamba":
        di = cfg.ssm.expand * d
        ds = cfg.ssm.d_state
        dtr = max(1, d // 16)
        return (
            2.0 * d * 2 * di  # in_proj
            + 2.0 * cfg.ssm.d_conv * di  # conv
            + 2.0 * di * (dtr + 2 * ds)  # x_proj
            + 2.0 * dtr * di  # dt_proj
            + 9.0 * di * ds  # scan recurrence (elementwise)
            + 2.0 * di * ds  # C·h readout
            + 2.0 * di * d  # out_proj
        )
    if mixer == "mlstm":
        qd = cfg.q_dim
        c = 1.0 if kind == "decode" else min(cfg.ssm.chunk, seq_len)
        intra = 2.0 * 2.0 * qd * (c / 2.0)  # qk^T + weighted v within chunk
        inter = 3.0 * 2.0 * qd * cfg.head_dim  # carry read + update
        return 2.0 * d * 3 * qd + intra + inter + 2.0 * qd * d
    if mixer == "slstm":
        return 16.0 * d * d  # 4 input + 4 recurrent matmuls
    raise ValueError(mixer)


def _ffn_fwd_flops_per_token(cfg: ModelConfig, ffn: str) -> float:
    d = cfg.d_model
    if ffn == "none":
        return 0.0
    if ffn == "mlp":
        mult = 3 if cfg.activation.endswith("_glu") else 2
        return 2.0 * mult * d * cfg.d_ff
    if ffn == "dense0":
        return 2.0 * 3 * d * cfg.d_ff
    if ffn == "moe":
        m = cfg.moe
        routed = 2.0 * 3 * d * m.d_expert * m.top_k * m.capacity_factor
        shared = 2.0 * 3 * d * (m.n_shared * m.d_expert)
        router = 2.0 * d * m.n_experts
        return routed + shared + router
    raise ValueError(ffn)


def analytic_flops_global(cfg: ModelConfig, shape: Shape) -> float:
    """Total FLOPs of one step across all chips."""
    kind = shape.kind
    if kind == "decode":
        tokens = float(shape.global_batch)
        seq_for_attn = shape.seq_len
    else:
        tokens = float(shape.seq_len * shape.global_batch)
        seq_for_attn = shape.seq_len

    block_fwd = 0.0
    for mixer, ffn in cfg.layer_seq():
        block_fwd += _mixer_fwd_flops_per_token(cfg, mixer, kind, seq_for_attn)
        block_fwd += _ffn_fwd_flops_per_token(cfg, ffn)

    if cfg.is_encoder_decoder:
        # decoder blocks add cross-attention to frontend_len encoder rows
        cross = 2.0 * cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim) + 4.0 * cfg.q_dim * cfg.frontend_len
        block_fwd += cross * cfg.n_layers
        # encoder runs over frontend_len rows (per sequence, train/prefill)
        enc_fwd_per_tok = cfg.n_enc_layers * (
            _mixer_fwd_flops_per_token(cfg, "attn", "prefill", cfg.frontend_len)
            + _ffn_fwd_flops_per_token(cfg, "mlp")
        )
        enc_tokens = (
            float(shape.global_batch * cfg.frontend_len)
            if kind != "decode"
            else 0.0
        )
    else:
        enc_fwd_per_tok, enc_tokens = 0.0, 0.0

    head_fwd = 2.0 * cfg.d_model * _padded_vocab(cfg)

    if kind == "train":
        block_mult, head_mult = 4.0, 3.0
    else:
        block_mult, head_mult = 1.0, 1.0
    head_tokens = tokens if kind == "train" else float(shape.global_batch)
    # prefill computes the full-seq logits? we only take the last position;
    # the head runs on 1 row per sequence for prefill/decode.

    total = (
        tokens * block_fwd * block_mult
        + enc_tokens * enc_fwd_per_tok * block_mult
        + head_tokens * head_fwd * head_mult
    )
    return total


@dataclass(frozen=True)
class MemoryModel:
    params_bytes: float
    opt_bytes: float
    grad_bytes: float
    act_bytes: float
    kv_bytes: float
    logits_bytes: float

    @property
    def total(self) -> float:
        return (
            self.params_bytes
            + self.opt_bytes
            + self.grad_bytes
            + self.act_bytes
            + self.kv_bytes
            + self.logits_bytes
        )


def analytic_hbm_bytes_per_device(
    cfg: ModelConfig,
    shape: Shape,
    *,
    model_ways: int,
    data_ways: int,
) -> MemoryModel:
    """Estimated fused HBM traffic per device per step.

    Sharding model: params over ``model`` (TP/EP); batch over data axes;
    optimizer moments additionally over ``data`` (ZeRO-1).
    """
    p_local = cfg.param_count() / model_ways
    kind = shape.kind
    b_local = max(1, shape.global_batch // data_ways)
    l = shape.seq_len
    d = cfg.d_model
    dt = 2.0  # bf16

    if kind == "train":
        tokens_local = b_local * l
        params = p_local * dt * 3  # fwd read + bwd read + update write
        opt = (p_local / data_ways) * 4.0 * 2 * 2  # m,v read+write fp32
        grads = p_local * dt * 2  # write + read (+AR staging not counted here)
        # activations: per layer one saved residual stream (remat policy),
        # written fwd / read bwd, plus ~2× recompute traffic
        act = cfg.n_layers * tokens_local * d * dt * 4
        kv = 0.0
        logits = b_local * l * (_padded_vocab(cfg) / model_ways) * dt * 4
    elif kind == "prefill":
        tokens_local = b_local * l
        params = p_local * dt
        opt = grads = 0.0
        act = cfg.n_layers * tokens_local * d * dt * 2
        # KV cache write once + chunked re-reads (q_chunk = 2048)
        n_attn = sum(1 for m, _ in cfg.layer_seq() if m.startswith("attn"))
        rereads = max(1, l // 2048) / 2  # causal: half the blocks on average
        kv = n_attn * b_local * l * cfg.kv_dim * 2 * dt * (1 + rereads)
        logits = b_local * (_padded_vocab(cfg) / model_ways) * dt
    else:  # decode
        params = p_local * dt  # whole model read once per token step
        opt = grads = 0.0
        act = cfg.n_layers * b_local * d * dt * 4
        n_attn = sum(1 for m, _ in cfg.layer_seq() if m.startswith("attn"))
        if shape.global_batch >= data_ways:
            cache_rows_local = b_local * l
        else:  # SP long-context: sequence sharded over data
            cache_rows_local = shape.global_batch * l / data_ways
        # KV heads (or head_dim) are model-sharded → per-device kv_dim slice
        kv = n_attn * cache_rows_local * (cfg.kv_dim / model_ways) * 2 * dt
        # recurrent state traffic
        n_rec = sum(
            1 for m, _ in cfg.layer_seq() if m in ("mamba", "mlstm", "slstm")
        )
        di = cfg.ssm.expand * d
        rec = n_rec * b_local * (di / model_ways) * cfg.ssm.d_state * 4.0 * 2
        act += rec
        logits = b_local * (_padded_vocab(cfg) / model_ways) * dt
    return MemoryModel(
        params_bytes=params,
        opt_bytes=opt,
        grad_bytes=grads,
        act_bytes=act,
        kv_bytes=kv,
        logits_bytes=logits,
    )
