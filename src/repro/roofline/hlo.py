"""HLO text analysis: collective-traffic extraction for the roofline.

``cost_analysis()`` gives FLOPs and HBM bytes but not inter-chip traffic,
so collective bytes are parsed from the partitioned optimized-HLO text.
Shapes in SPMD modules are *per-device*; per-chip link traffic follows the
ring-algorithm terms:

    all-gather          out_bytes · (n−1)/n
    reduce-scatter      out_bytes · (n−1)
    all-reduce          2 · bytes · (n−1)/n
    all-to-all          bytes · (n−1)/n
    collective-permute  bytes
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 1


_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


@dataclass
class CollectiveStats:
    # per-op: count, per-device result bytes, per-device link traffic
    count: dict[str, int] = field(default_factory=lambda: defaultdict(int))
    result_bytes: dict[str, int] = field(default_factory=lambda: defaultdict(int))
    link_bytes: dict[str, float] = field(default_factory=lambda: defaultdict(float))
    # attribution: (link_bytes, op, group_size, scaled, op_name) heaviest first
    top: list[tuple[float, str, int, int, str]] = field(default_factory=list)

    @property
    def total_link_bytes(self) -> float:
        return sum(self.link_bytes.values())

    def as_dict(self, *, top_n: int = 12) -> dict:
        return {
            "count": dict(self.count),
            "result_bytes": dict(self.result_bytes),
            "link_bytes": {k: float(v) for k, v in self.link_bytes.items()},
            "total_link_bytes": float(self.total_link_bytes),
            "top": [
                {
                    "link_bytes": b,
                    "op": o,
                    "group": g,
                    "scale": s,
                    "op_name": n,
                }
                for b, o, g, s, n in sorted(self.top, reverse=True)[:top_n]
            ],
        }


def _ring_traffic(op: str, nbytes: int, n: int) -> float:
    if op == "collective-permute":
        # point-to-point: each device ships its buffer once
        return float(nbytes)
    if n <= 1:
        return 0.0
    if op == "all-gather":
        return nbytes * (n - 1) / n
    if op == "reduce-scatter":
        return nbytes * (n - 1)
    if op == "all-reduce":
        return 2.0 * nbytes * (n - 1) / n
    if op == "all-to-all":
        return nbytes * (n - 1) / n
    if op == "collective-permute":
        return float(nbytes)
    raise ValueError(op)


def parse_collectives(hlo_text: str, *, body_scale: int = 1) -> CollectiveStats:
    """Parse collective traffic from (partitioned) optimized HLO text.

    ``body_scale``: trip count applied to collectives that execute inside a
    while-loop body — detected via the instruction's ``op_name`` metadata
    containing ``/while/`` (XLA preserves the JAX trace path).  The only
    scans in this codebase with collectives inside are the layer-stack scans
    (trip count = config ``repeats``); sLSTM's time scan keeps its weights
    replicated precisely so this scaling stays exact.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        nbytes = _shape_bytes(type_str)
        n = _group_size(line)
        scale = body_scale if "/while/" in line else 1
        traffic = _ring_traffic(op, nbytes, n) * scale
        stats.count[op] += scale
        stats.result_bytes[op] += nbytes * scale
        stats.link_bytes[op] += traffic
        nm = _OPNAME_RE.search(line)
        stats.top.append(
            (traffic, op, n, scale, nm.group(1) if nm else "")
        )
    return stats
