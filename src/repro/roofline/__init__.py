"""Roofline analysis: HLO collective parsing + three-term model."""

from .analysis import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    Roofline,
    model_flops,
    roofline,
    slstm_extra_flops,
)
from .hlo import CollectiveStats, parse_collectives

__all__ = [
    "Roofline",
    "roofline",
    "model_flops",
    "slstm_extra_flops",
    "parse_collectives",
    "CollectiveStats",
    "PEAK_FLOPS",
    "HBM_BW",
    "ICI_BW",
]
