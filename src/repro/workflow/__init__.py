"""Distributed workflow runtime for SWIRL systems.

* :mod:`~repro.workflow.runtime`  — reduction-driven, checkpointable executor
  with retry / speculation / heartbeats (execution *is* SWIRL reduction).
* :mod:`~repro.workflow.threaded` — decentralised per-location threads
  interpreting the execution IR (:class:`ThreadedProgramRuntime`, the
  generated-program execution model of paper §5; the tree-walking
  ``ThreadedRuntime`` is kept as a deprecated reference oracle).
* :mod:`~repro.workflow.channels` — in-process channels with fault injection.
* :mod:`~repro.workflow.transport` — pluggable COMM transports (in-memory
  queues, ack-based sockets) shared by the threaded and multiprocess
  backends.
* :mod:`~repro.workflow.fault`    — retry/speculation/heartbeat policies.
* :mod:`~repro.workflow.elastic`  — location renaming, recovery, rebalance.
"""

from .channels import Channel, ChannelClosed, ChannelRegistry
from .transport import (
    AckTimeout,
    InMemoryTransport,
    SocketTransport,
    Transport,
    get_transport,
    register_transport,
    socket_addresses,
)
from .fault import (
    DEFAULT_HEARTBEAT_TIMEOUT_S,
    FlakyFn,
    HeartbeatMonitor,
    LocationDead,
    PermanentError,
    RetryPolicy,
    SlowFn,
    SlowOnceAcrossProcesses,
    SpeculationPolicy,
    TransientError,
)
from .runtime import Checkpoint, Runtime, RunStats, WorkflowDeadlock
from .threaded import ThreadedProgramRuntime, ThreadedRuntime
from .elastic import (
    fold_payloads,
    plan_recovery,
    rebalance,
    recover_checkpoint,
    rename_locations,
)

__all__ = [
    "AckTimeout",
    "Channel",
    "ChannelClosed",
    "ChannelRegistry",
    "DEFAULT_HEARTBEAT_TIMEOUT_S",
    "Transport",
    "InMemoryTransport",
    "SocketTransport",
    "register_transport",
    "get_transport",
    "socket_addresses",
    "Runtime",
    "RunStats",
    "Checkpoint",
    "WorkflowDeadlock",
    "ThreadedRuntime",
    "ThreadedProgramRuntime",
    "RetryPolicy",
    "SpeculationPolicy",
    "HeartbeatMonitor",
    "TransientError",
    "PermanentError",
    "LocationDead",
    "FlakyFn",
    "SlowFn",
    "SlowOnceAcrossProcesses",
    "rename_locations",
    "fold_payloads",
    "recover_checkpoint",
    "plan_recovery",
    "rebalance",
]
