"""The SWIRL workflow runtime — execution *is* reduction.

The runtime interprets a :class:`~repro.core.syntax.WorkflowSystem` by
repeatedly applying the paper's reduction rules (Fig. 3) with real effects:

* an (EXEC) transition runs the registered step function (once, on the
  lexicographically-first location of ``M(s)`` — the *leader*) and stores the
  produced payloads on **every** location of ``M(s)``, exactly like the rule
  adds ``Out^D(s)`` to every ``D_i``;
* a (COMM)/(L-COMM) transition copies the payload from source to destination.

Because the runtime state is always a *reachable workflow system* (Def. 13),
a checkpoint is simply ``dumps(state)`` + the payload store — the SWIRL term
is its own program counter.  Restart re-parses the term and resumes reduction;
in-flight steps at crash time are re-executed, which is sound because steps
are pure (the RDD-lineage argument).

Enabled exec transitions run concurrently on a thread pool (Church–Rosser,
Lemma 1, guarantees any completion order converges), with per-step retry and
straggler speculation from :mod:`repro.workflow.fault`.
"""

from __future__ import annotations

import pickle
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Mapping

from repro.core.parser import dumps, loads
from repro.core.semantics import (
    CommTransition,
    ExecTransition,
    enabled_transitions,
)
from repro.core.semantics import apply_transition
from repro.core.syntax import Exec, WorkflowSystem
from .fault import HeartbeatMonitor, RetryPolicy, SpeculationPolicy

StepFn = Callable[[Mapping[str, Any]], Mapping[str, Any]]
PayloadKey = tuple[str, str]  # (location, data_name)


class WorkflowDeadlock(RuntimeError):
    pass


@dataclass
class RunStats:
    execs: int = 0
    comms: int = 0
    retries: int = 0
    speculations: int = 0
    timeouts: int = 0
    checkpoints: int = 0
    wall_s: float = 0.0
    exec_log: list[tuple[str, str, float]] = field(default_factory=list)
    # (step, leader location, seconds)


@dataclass
class Checkpoint:
    """A consistent global snapshot: remaining system + payload store."""

    system_text: str
    payloads: dict[PayloadKey, Any]
    completed_execs: frozenset[str]

    def save(self, path: str | Path) -> None:
        Path(path).write_bytes(pickle.dumps(self))

    @staticmethod
    def load(path: str | Path) -> "Checkpoint":
        ckpt = pickle.loads(Path(path).read_bytes())
        if not isinstance(ckpt, Checkpoint):
            raise TypeError(f"{path} is not a workflow checkpoint")
        return ckpt

    @property
    def system(self) -> WorkflowSystem:
        return loads(self.system_text)


class Runtime:
    """Reduction-driven executor with fault tolerance.

    Parameters
    ----------
    system:
        The (optimised) workflow system to execute.
    step_fns:
        ``step name -> pure function`` registry.
    expected_s:
        Optional per-step expected durations for straggler speculation.
    initial_payloads:
        Payloads for the data elements already resident per location
        (must cover each location's ``D`` set).
    """

    def __init__(
        self,
        system: WorkflowSystem,
        step_fns: Mapping[str, StepFn],
        *,
        initial_payloads: Mapping[PayloadKey, Any] | None = None,
        expected_s: Mapping[str, float] | None = None,
        retry: RetryPolicy | None = None,
        speculation: SpeculationPolicy | None = None,
        max_workers: int = 8,
        checkpoint_every: int = 0,
        checkpoint_path: str | Path | None = None,
        heartbeat: HeartbeatMonitor | None = None,
    ):
        from repro._compat import warn_legacy

        warn_legacy(
            "constructing repro.workflow.Runtime directly",
            'swirl.trace(...).lower("inprocess").compile(step_fns)',
        )
        self.state = system
        self.step_fns = dict(step_fns)
        self.payloads: dict[PayloadKey, Any] = dict(initial_payloads or {})
        self.expected_s = dict(expected_s or {})
        self.retry = retry or RetryPolicy()
        self.speculation = speculation or SpeculationPolicy(enabled=False)
        self.max_workers = max_workers
        self.checkpoint_every = checkpoint_every
        self.checkpoint_path = Path(checkpoint_path) if checkpoint_path else None
        self.heartbeat = heartbeat or HeartbeatMonitor(timeout_s=60.0)
        self.stats = RunStats()
        self.completed_execs: set[str] = set()
        self._lock = threading.Lock()
        # Validate coverage: every exec action must have a registered fn.
        from repro.core.syntax import actions

        for cfg in system.configs:
            for a in actions(cfg.trace):
                if isinstance(a, Exec) and a.step not in self.step_fns:
                    raise KeyError(f"no step function registered for {a.step!r}")
            self.heartbeat.register(cfg.location)

    # -- checkpointing -------------------------------------------------------
    def checkpoint(self) -> Checkpoint:
        with self._lock:
            return Checkpoint(
                system_text=dumps(self.state),
                payloads=dict(self.payloads),
                completed_execs=frozenset(self.completed_execs),
            )

    @classmethod
    def restore(
        cls, ckpt: Checkpoint, step_fns: Mapping[str, StepFn], **kwargs
    ) -> "Runtime":
        rt = cls(ckpt.system, step_fns, initial_payloads=ckpt.payloads, **kwargs)
        rt.completed_execs = set(ckpt.completed_execs)
        return rt

    # -- effects -------------------------------------------------------------
    def _run_exec(self, act: Exec, pool: ThreadPoolExecutor) -> dict[str, Any]:
        """Run the step function for one exec action; returns its outputs."""
        leader = sorted(act.locations)[0]
        inputs = {d: self.payloads[(leader, d)] for d in sorted(act.inputs)}
        fn = self.step_fns[act.step]

        def attempt() -> Mapping[str, Any]:
            return fn(inputs)

        def with_retry() -> Mapping[str, Any]:
            return self.retry.run(
                attempt, on_retry=lambda n, e: self._count_retry()
            )

        t0 = time.monotonic()
        out, speculated = self.speculation.run(
            with_retry, self.expected_s.get(act.step), pool
        )
        dt = time.monotonic() - t0
        if speculated:
            with self._lock:
                self.stats.speculations += 1
        missing = act.outputs - set(out)
        if missing:
            raise RuntimeError(
                f"step {act.step!r} did not produce outputs {sorted(missing)}"
            )
        with self._lock:
            self.stats.exec_log.append((act.step, leader, dt))
        for l in act.locations:
            self.heartbeat.beat(l)
        return {d: out[d] for d in act.outputs}

    def _apply_exec(self, act: Exec, outputs: dict[str, Any]) -> None:
        """Apply the (EXEC) reduction for ``act`` to the current state."""
        with self._lock:
            for t in enabled_transitions(self.state):
                if isinstance(t, ExecTransition) and t.action == act:
                    self.state = apply_transition(self.state, t)
                    for l in act.locations:
                        for d, v in outputs.items():
                            self.payloads[(l, d)] = v
                    self.stats.execs += 1
                    self.completed_execs.add(act.step)
                    return
            raise RuntimeError(
                f"exec {act.pretty()} no longer enabled — state diverged"
            )

    def _apply_comms(self) -> int:
        """Apply every currently enabled communication, one at a time."""
        n = 0
        while True:
            with self._lock:
                comm = next(
                    (
                        t
                        for t in enabled_transitions(self.state)
                        if isinstance(t, CommTransition)
                    ),
                    None,
                )
                if comm is None:
                    return n
                s = comm.send
                self.state = apply_transition(self.state, comm)
                self.payloads[(s.dst, s.data)] = self.payloads[(s.src, s.data)]
                self.stats.comms += 1
                n += 1

    def _count_retry(self) -> None:
        with self._lock:
            self.stats.retries += 1

    # -- main loop -----------------------------------------------------------
    def run(self, *, max_rounds: int = 1_000_000) -> RunStats:
        t_start = time.monotonic()
        since_ckpt = 0
        pool = ThreadPoolExecutor(max_workers=self.max_workers)
        try:
            inflight: dict[Exec, Future] = {}
            for _ in range(max_rounds):
                progressed = self._apply_comms() > 0

                # Submit every enabled exec that is not already running.
                with self._lock:
                    enabled = [
                        t
                        for t in enabled_transitions(self.state)
                        if isinstance(t, ExecTransition)
                    ]
                for t in enabled:
                    if t.action not in inflight:
                        inflight[t.action] = pool.submit(
                            self._run_exec, t.action, pool
                        )
                        progressed = True

                if not inflight:
                    if progressed:
                        continue
                    break  # terminated or deadlocked

                done, _ = wait(
                    list(inflight.values()), return_when=FIRST_COMPLETED
                )
                for act in [a for a, f in inflight.items() if f in done]:
                    fut = inflight.pop(act)
                    self._apply_exec(act, fut.result())
                    since_ckpt += 1
                    if (
                        self.checkpoint_every
                        and self.checkpoint_path
                        and since_ckpt >= self.checkpoint_every
                    ):
                        self.checkpoint().save(self.checkpoint_path)
                        self.stats.checkpoints += 1
                        since_ckpt = 0
        finally:
            # Do not block on abandoned speculation losers — they are pure
            # and their results are discarded.
            pool.shutdown(wait=False, cancel_futures=True)

        self.stats.wall_s = time.monotonic() - t_start
        if not self.state.is_terminated():
            raise WorkflowDeadlock(
                "workflow did not terminate; remaining system:\n"
                + self.state.pretty()
            )
        return self.stats

    # -- results -------------------------------------------------------------
    def payload(self, location: str, data: str) -> Any:
        return self.payloads[(location, data)]

    def location_data(self, location: str) -> dict[str, Any]:
        return {
            d: v for (l, d), v in self.payloads.items() if l == location
        }
