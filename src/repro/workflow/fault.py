"""Fault-tolerance primitives: retries, heartbeats, straggler speculation.

SWIRL steps are pure dataflow steps (``In^D(s) ↦ Out^D(s)``); re-executing a
step with the same inputs yields the same outputs.  That single assumption —
the same one behind RDD lineage recovery — makes all three mechanisms here
sound:

* **retry** — transient step failures are retried up to ``max_retries``;
* **heartbeat** — a location that stops beating is declared dead and its work
  queue is eligible for re-mapping (see :mod:`repro.workflow.elastic`);
* **speculation** — a step exceeding ``speculation_factor ×`` its expected
  duration is speculatively re-executed; the first result wins.
"""

from __future__ import annotations

import os
import random
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

#: The one heartbeat deadline default, shared by :class:`HeartbeatMonitor`
#: and :class:`repro.exec.policy.FaultPolicy`.  Historically ``fault.py``
#: said 5s while ``central.py`` constructed 60s; 30s is the documented
#: middle ground — long enough that a loaded CI machine never declares a
#: healthy worker dead, short enough that a genuinely wedged worker is
#: recovered within one straggler window.
DEFAULT_HEARTBEAT_TIMEOUT_S = 30.0


class TransientError(RuntimeError):
    """A step failure worth retrying (node blip, OOM-kill, preemption)."""


class PermanentError(RuntimeError):
    """A step failure that must not be retried (bad program)."""


class LocationDead(RuntimeError):
    """Raised by the heartbeat monitor when a location misses its deadline."""


@dataclass
class RetryPolicy:
    """Bounded retry with capped exponential backoff and **full jitter**.

    The sleep before retry ``n`` (0-based) is drawn uniformly from
    ``[0, min(backoff_cap_s, backoff_s * 2**n)]`` — the AWS "full jitter"
    scheme, which decorrelates a thundering herd of retriers.  ``rng`` is
    any object with ``random()``; inject a seeded ``random.Random`` for
    deterministic tests.
    """

    max_retries: int = 3
    backoff_s: float = 0.0  # tests keep this at 0
    backoff_cap_s: float = 30.0
    rng: Any = None

    def sleep_s(self, attempt: int) -> float:
        """The jittered sleep before retrying after failed ``attempt``."""
        if not self.backoff_s:
            return 0.0
        ceiling = min(self.backoff_cap_s, self.backoff_s * (2**attempt))
        return ceiling * (self.rng or random).random()

    def run(self, fn: Callable[[], Any], *, on_retry=None) -> Any:
        last: Exception | None = None
        for attempt in range(self.max_retries + 1):
            try:
                return fn()
            except PermanentError:
                raise
            except Exception as e:  # noqa: BLE001 — step code is arbitrary
                last = e
                if on_retry is not None:
                    on_retry(attempt, e)
                delay = self.sleep_s(attempt)
                if delay:
                    time.sleep(delay)
        raise TransientError(
            f"step failed after {self.max_retries + 1} attempts"
        ) from last


@dataclass
class SpeculationPolicy:
    """Speculative re-execution of stragglers (pure steps make this safe)."""

    enabled: bool = True
    factor: float = 3.0  # speculate when t > factor × expected
    min_expected_s: float = 0.01
    max_speculative: int = 1

    def run(
        self,
        fn: Callable[[], Any],
        expected_s: float | None,
        pool: ThreadPoolExecutor,
    ) -> tuple[Any, bool]:
        """Run ``fn``; launch a backup copy if the primary straggles.

        Returns ``(result, speculated)``.
        """
        if not self.enabled or expected_s is None:
            return fn(), False
        deadline = max(expected_s, self.min_expected_s) * self.factor
        futures: list[Future] = [pool.submit(fn)]
        speculated = False
        launched = 0
        while True:
            done, pending = wait(futures, timeout=deadline, return_when=FIRST_COMPLETED)
            if done:
                winner = next(iter(done))
                for p in pending:
                    p.cancel()
                return winner.result(), speculated
            if launched < self.max_speculative:
                futures.append(pool.submit(fn))
                launched += 1
                speculated = True
            # else: keep waiting on the already-launched copies


class HeartbeatMonitor:
    """Tracks per-location liveness; ``dead()`` lists expired locations."""

    def __init__(
        self,
        timeout_s: float = DEFAULT_HEARTBEAT_TIMEOUT_S,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.timeout_s = timeout_s
        self._clock = clock
        self._last: dict[str, float] = {}
        self._lock = threading.Lock()

    def beat(self, location: str) -> None:
        with self._lock:
            self._last[location] = self._clock()

    def register(self, location: str) -> None:
        self.beat(location)

    def dead(self) -> list[str]:
        now = self._clock()
        with self._lock:
            return sorted(
                l for l, t in self._last.items() if now - t > self.timeout_s
            )

    def alive(self) -> list[str]:
        now = self._clock()
        with self._lock:
            return sorted(
                l for l, t in self._last.items() if now - t <= self.timeout_s
            )

    def check(self, location: str) -> None:
        if location in self.dead():
            raise LocationDead(location)


# ---------------------------------------------------------------------------
# Fault injection helpers for tests & benchmarks
# ---------------------------------------------------------------------------


@dataclass
class FlakyFn:
    """Wraps a step fn to fail the first ``failures`` invocations."""

    fn: Callable[[Mapping[str, Any]], Mapping[str, Any]]
    failures: int = 1
    exc: type[Exception] = TransientError
    calls: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def __call__(self, inputs: Mapping[str, Any]) -> Mapping[str, Any]:
        with self._lock:
            self.calls += 1
            n = self.calls
        if n <= self.failures:
            raise self.exc(f"injected failure #{n}")
        return self.fn(inputs)


@dataclass
class SlowFn:
    """Wraps a step fn to straggle on its first ``slow_calls`` invocations."""

    fn: Callable[[Mapping[str, Any]], Mapping[str, Any]]
    delay_s: float = 0.5
    slow_calls: int = 1
    calls: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def __call__(self, inputs: Mapping[str, Any]) -> Mapping[str, Any]:
        with self._lock:
            self.calls += 1
            n = self.calls
        if n <= self.slow_calls:
            time.sleep(self.delay_s)
        return self.fn(inputs)


@dataclass
class SlowOnceAcrossProcesses:
    """Straggle exactly once **fleet-wide**, surviving process respawns.

    :class:`SlowFn` counts calls in one process's memory; under the fork
    start method every respawned worker inherits ``calls == 0`` and would
    straggle again, so heartbeat-recovery scenarios never converge.  This
    variant claims a filesystem flag (``O_CREAT | O_EXCL`` — atomic across
    processes): the first caller anywhere in the fleet creates it and
    sleeps, every later caller in any process is fast.
    """

    fn: Callable[[Mapping[str, Any]], Mapping[str, Any]]
    flag_path: str
    delay_s: float = 0.5

    def __call__(self, inputs: Mapping[str, Any]) -> Mapping[str, Any]:
        try:
            os.close(os.open(self.flag_path, os.O_CREAT | os.O_EXCL))
        except FileExistsError:
            pass
        else:
            time.sleep(self.delay_s)
        return self.fn(inputs)
