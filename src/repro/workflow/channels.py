"""In-process channels for the decentralised threaded runtime.

Each ``(src, dst, port)`` triple gets one FIFO queue — the in-memory analogue
of the reference implementation's TCP sockets.  ``FaultyChannelRegistry``
injects transport faults (drops / delays) for the fault-tolerance tests; a
dropped message is re-sent by the sender after ``ack_timeout`` (at-least-once
delivery + idempotent receive = exactly-once effect, which is sound because
SWIRL data elements are immutable and COMM copies rather than consumes).
"""

from __future__ import annotations

import random
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Hashable

Endpoint = tuple[str, str, str]  # (src, dst, port)


class ChannelClosed(Exception):
    pass


def endpoint_rng(seed: int, endpoint: Endpoint) -> random.Random:
    """Independent per-endpoint RNG stream.

    ``random.Random`` seeds strings via SHA-512, so mixing the registry seed
    with the endpoint triple is deterministic across runs and processes
    (unaffected by ``PYTHONHASHSEED``) while decorrelating the channels.
    """
    src, dst, port = endpoint
    return random.Random(f"{seed}\x1f{src}\x1f{dst}\x1f{port}")


@dataclass
class Message:
    data_name: str
    payload: Any
    seq: int = 0


class Channel:
    """One directed FIFO with optional injected unreliability."""

    def __init__(
        self,
        endpoint: Endpoint,
        *,
        drop_prob: float = 0.0,
        delay_s: float = 0.0,
        rng: random.Random | None = None,
        seed: int = 0,
    ):
        self.endpoint = endpoint
        self._items: deque[Message] = deque()
        self._cond = threading.Condition()
        self.drop_prob = drop_prob
        self.delay_s = delay_s
        # Each endpoint gets its own stream, derived from the registry seed
        # mixed with (src, dst, port) — a shared Random(0) would make every
        # channel drop/delay in lockstep, i.e. perfectly correlated faults.
        self._rng = rng or endpoint_rng(seed, endpoint)
        self.sent = 0
        self.dropped = 0
        self.delivered = 0
        self._closed = threading.Event()

    def put(self, data_name: str, payload: Any) -> bool:
        """Send; returns False if the transport 'lost' the message."""
        if self._closed.is_set():
            raise ChannelClosed(f"channel {self.endpoint} is closed")
        self.sent += 1
        if self._rng.random() < self.drop_prob:
            self.dropped += 1
            return False
        if self.delay_s:
            time.sleep(self.delay_s)
        with self._cond:
            self._items.append(Message(data_name, payload, self.sent))
            self._cond.notify()
        return True

    def put_reliable(self, data_name: str, payload: Any, *, max_tries: int = 20) -> None:
        """At-least-once: retry until the transport accepts the message."""
        for _ in range(max_tries):
            if self.put(data_name, payload):
                return
        raise ChannelClosed(
            f"channel {self.endpoint} dropped the message {max_tries} times"
        )

    def get(self, timeout: float | None = None) -> Message:
        """Blocking receive.

        A :meth:`close` wakes blocked receivers immediately: pending
        messages are still drained after close, then (and on any later
        call) :class:`ChannelClosed` is raised.  A ``timeout`` raises
        :class:`TimeoutError` exactly as before.
        """
        with self._cond:
            self._cond.wait_for(
                lambda: self._items or self._closed.is_set(), timeout
            )
            if self._items:
                return self._items.popleft()
            if self._closed.is_set():
                raise ChannelClosed(
                    f"channel {self.endpoint} closed while receiving"
                )
            raise TimeoutError(f"recv timed out on {self.endpoint}")

    def close(self) -> None:
        self._closed.set()
        with self._cond:
            self._cond.notify_all()


class ChannelRegistry:
    """Lazily creates one channel per endpoint; thread-safe.

    ``seed`` is the registry-wide fault-injection seed: each channel derives
    its own RNG from it via :func:`endpoint_rng`, so two registries with the
    same seed reproduce the same faults while distinct endpoints within one
    registry stay uncorrelated.
    """

    def __init__(self, *, seed: int = 0, **channel_kwargs):
        self._channels: dict[Endpoint, Channel] = {}
        self._lock = threading.Lock()
        self._seed = seed
        self._kwargs = channel_kwargs
        self._closed = False

    def channel(self, src: str, dst: str, port: str) -> Channel:
        key = (src, dst, port)
        with self._lock:
            if key not in self._channels:
                ch = Channel(key, seed=self._seed, **self._kwargs)
                if self._closed:
                    ch.close()
                self._channels[key] = ch
            return self._channels[key]

    def close(self) -> None:
        """Close every channel (blocked receivers raise ChannelClosed)."""
        with self._lock:
            self._closed = True
            for ch in self._channels.values():
                ch.close()

    # dict-style access used by the generated bundles (core.compile).
    def __getitem__(self, key: Endpoint):
        return _BundleChannelView(self.channel(*key))

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {
                "channels": len(self._channels),
                "sent": sum(c.sent for c in self._channels.values()),
                "dropped": sum(c.dropped for c in self._channels.values()),
            }


class _BundleChannelView:
    """Adapter exposing the ``put((name, payload))`` / ``get()`` protocol the
    generated Python bundles expect."""

    def __init__(self, ch: Channel):
        self._ch = ch

    def put(self, item: tuple[str, Any]) -> None:
        self._ch.put_reliable(item[0], item[1])

    def get(self) -> tuple[str, Any]:
        m = self._ch.get(timeout=30.0)
        return (m.data_name, m.payload)
