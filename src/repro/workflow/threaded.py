"""Decentralised threaded runtime — one thread per location, no orchestrator.

This back-end executes the *compiled bundles* of :mod:`repro.core.compile`
the way the paper's generated TCP programs do: every location runs its own
trace against real channels, with no shared scheduler state.  Spatial
constraints (one step on many locations) synchronise through per-exec
barriers, matching the (EXEC) rule's synchronised reduction.

This is the back-end used by the 1000 Genomes evaluation; the checkpointable
:class:`repro.workflow.runtime.Runtime` is the one used under fault
injection (its state is a reachable SWIRL term, so snapshots are trivial).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Mapping

from repro.core.compile import LocationBundle
from repro.core.syntax import Exec, Nil, Par, Recv, Send, Seq, Trace
from .channels import ChannelRegistry
from .transport import InMemoryTransport, Transport


@dataclass
class _ExecBarrier:
    """Synchronises one exec predicate across its ``M(s)`` locations.

    The first arriving location is the leader: it runs the step function and
    publishes the outputs; everyone waits on the event, then copies the
    outputs into their local data scope (Out^D(s) added to every D_i).
    """

    n: int
    outputs: dict[str, Any] = field(default_factory=dict)
    _arrived: int = 0
    _done: threading.Event = field(default_factory=threading.Event)
    _lock: threading.Lock = field(default_factory=threading.Lock)
    _leader_claimed: bool = False
    error: BaseException | None = None

    def arrive_and_maybe_lead(self) -> bool:
        with self._lock:
            lead = not self._leader_claimed
            self._leader_claimed = True
            self._arrived += 1
            return lead

    def publish(self, outputs: Mapping[str, Any]) -> None:
        self.outputs.update(outputs)
        self._done.set()

    def fail(self, e: BaseException) -> None:
        self.error = e
        self._done.set()

    def wait(self, timeout: float = 60.0) -> dict[str, Any]:
        if not self._done.wait(timeout):
            raise TimeoutError("exec barrier timed out")
        if self.error is not None:
            raise self.error
        return self.outputs


class ThreadedRuntime:
    """Run one thread per location; each interprets only its own bundle."""

    def __init__(
        self,
        bundles: Mapping[str, LocationBundle],
        *,
        initial_payloads: Mapping[tuple[str, str], Any] | None = None,
        channels: ChannelRegistry | None = None,
        transport: Transport | None = None,
        timeout_s: float = 60.0,
    ):
        from repro._compat import warn_legacy

        warn_legacy(
            "constructing repro.workflow.ThreadedRuntime directly",
            'swirl.trace(...).lower("threaded").compile(step_fns)',
        )
        if transport is not None and channels is not None:
            raise TypeError("pass either transport= or channels=, not both")
        if transport is None:
            # The historical in-memory queues, behind the Transport API.
            transport = InMemoryTransport(channels or ChannelRegistry())
        self.bundles = dict(bundles)
        self.transport = transport
        # Back-compat: the wrapped registry, when the transport has one.
        self.channels = getattr(transport, "registry", None)
        self.timeout_s = timeout_s
        self._barriers: dict[Exec, _ExecBarrier] = {}
        self._barrier_lock = threading.Lock()
        self.data: dict[str, dict[str, Any]] = {
            loc: {} for loc in self.bundles
        }
        # Per-location condition: writes notify; execs wait on In^D(s) ⊆ D_l
        # (the (EXEC) rule's premise — after optimisation a datum may arrive
        # via a *sibling* parallel branch's recv, so exec must block on it).
        self._cond: dict[str, threading.Condition] = {
            loc: threading.Condition() for loc in self.bundles
        }
        for (l, d), v in (initial_payloads or {}).items():
            self.data[l][d] = v
        self.errors: list[tuple[str, BaseException]] = []

    def _put_data(self, loc: str, items: Mapping[str, Any]) -> None:
        with self._cond[loc]:
            self.data[loc].update(items)
            self._cond[loc].notify_all()

    def _wait_data(self, loc: str, names: frozenset[str]) -> dict[str, Any]:
        with self._cond[loc]:
            ok = self._cond[loc].wait_for(
                lambda: all(d in self.data[loc] for d in names),
                timeout=self.timeout_s,
            )
            if not ok:
                missing = sorted(d for d in names if d not in self.data[loc])
                raise TimeoutError(f"{loc} never received {missing}")
            return {d: self.data[loc][d] for d in names}

    # -- barrier registry -----------------------------------------------------
    def _barrier_for(self, act: Exec) -> _ExecBarrier:
        with self._barrier_lock:
            if act not in self._barriers:
                self._barriers[act] = _ExecBarrier(n=len(act.locations))
            return self._barriers[act]

    # -- per-location interpreter ----------------------------------------------
    def _interp(self, loc: str, t: Trace) -> None:
        if isinstance(t, Nil):
            return
        if isinstance(t, Seq):
            for item in t.items:
                self._interp(loc, item)
            return
        if isinstance(t, Par):
            errs: list[BaseException] = []

            def branch(b: Trace) -> None:
                try:
                    self._interp(loc, b)
                except BaseException as e:  # noqa: BLE001
                    errs.append(e)

            threads = [
                threading.Thread(target=branch, args=(b,), daemon=True)
                for b in t.branches
            ]
            for th in threads:
                th.start()
            for th in threads:
                th.join(self.timeout_s)
                if th.is_alive():
                    raise TimeoutError(f"parallel branch stuck on {loc}")
            if errs:
                raise errs[0]
            return
        if isinstance(t, Send):
            # The datum may be produced by a sibling branch — wait for it.
            payload = self._wait_data(loc, frozenset([t.data]))[t.data]
            self.transport.send((t.src, t.dst, t.port), t.data, payload)
            return
        if isinstance(t, Recv):
            msg = self.transport.recv(
                (t.src, t.dst, t.port), timeout=self.timeout_s
            )
            self._put_data(loc, {msg.data_name: msg.payload})
            return
        if isinstance(t, Exec):
            bundle = self.bundles[loc]
            meta = bundle.steps[t.step]
            if len(t.locations) == 1:
                inputs = self._wait_data(loc, t.inputs)
                out = meta.fn(inputs)
                self._put_data(loc, {d: out[d] for d in t.outputs})
                return
            barrier = self._barrier_for(t)
            if barrier.arrive_and_maybe_lead():
                try:
                    inputs = self._wait_data(loc, t.inputs)
                    out = meta.fn(inputs)
                    barrier.publish({d: out[d] for d in t.outputs})
                except BaseException as e:  # noqa: BLE001
                    barrier.fail(e)
                    raise
            outputs = barrier.wait(self.timeout_s)
            self._put_data(loc, dict(outputs))
            return
        raise TypeError(f"not a trace: {t!r}")

    def _run_location(self, loc: str) -> None:
        try:
            self._interp(loc, self.bundles[loc].trace)
        except BaseException as e:  # noqa: BLE001
            self.errors.append((loc, e))

    def run(self) -> dict[str, dict[str, Any]]:
        threads = [
            threading.Thread(target=self._run_location, args=(loc,), daemon=True)
            for loc in sorted(self.bundles)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join(self.timeout_s)
            if th.is_alive():
                # A peer's failure (e.g. a sender exhausting channel
                # retries) leaves blocked receivers behind — report the
                # root cause, not the stuck thread it orphaned.
                self._raise_first_error()
                raise TimeoutError("a location thread did not finish")
        self._raise_first_error()
        return self.data

    def _raise_first_error(self) -> None:
        if self.errors:
            loc, err = self.errors[0]
            raise RuntimeError(f"location {loc} failed: {err!r}") from err
