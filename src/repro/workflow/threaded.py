"""Decentralised threaded runtime — one thread per location, no orchestrator.

:class:`ThreadedProgramRuntime` executes per-location programs of the
execution IR (:mod:`repro.exec.program`) the way the paper's generated TCP
programs do: every location interprets *only its own op array* against real
channels, with no shared scheduler state.  Spatial constraints (one step on
many locations) synchronise through per-exec barriers, matching the (EXEC)
rule's synchronised reduction.  An ``instance_tag`` namespaces every channel
endpoint, which is what lets :meth:`repro.api.Executable.run_many` drive
many workflow instances through one shared transport concurrently.

This is the back-end used by the 1000 Genomes evaluation.  The historical
tree-walking interpreter (:class:`ThreadedRuntime`, over compiled
``LocationBundle``s) is kept verbatim as a deprecated reference oracle —
``tests/test_differential.py`` checks flat-program execution against it.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from time import monotonic as _mono
from typing import Any, Mapping

from repro.core.compile import LocationBundle, StepMeta
from repro.core.syntax import Exec, Nil, Par, Recv, Send, Seq, Trace
from repro.exec.interp import (
    Cursor,
    Deadline,
    StepGuard,
    record_exec_fire,
    record_policy_fire,
    record_recv_fire,
    record_send_fire,
)
from repro.exec.program import (
    K_ACT,
    K_PAR,
    K_SEQ,
    LocationProgram,
    RecvOp,
    SendOp,
)


from .channels import ChannelRegistry
from .transport import InMemoryTransport, Transport


class _BranchAborted(RuntimeError):
    """A Par branch gave up because a *sibling* poisoned the location.

    Never the root cause — error-reporting sites prefer any other
    exception over this one (see :func:`_first_real`).
    """


def _first_real(errs: list[BaseException]) -> BaseException:
    """The first non-:class:`_BranchAborted` error, else the first error."""
    for e in errs:
        if not isinstance(e, _BranchAborted):
            return e
    return errs[0]


def total_par_branches(programs: Mapping[str, "LocationProgram"]) -> int:
    """Static upper bound on concurrently-live parallel branches.

    The sum of every ``Par`` node's branch count across all location
    programs — what one in-flight instance can demand from a shared branch
    pool at worst (all pars active at once).  ``run_many`` sizes its pool
    as ``lanes × total_par_branches`` so pooled branches can never starve
    each other into deadlock.
    """
    n = 0
    for lp in programs.values():
        spec = lp.control()
        for nid, kind in enumerate(spec.kind):
            if kind == K_PAR:
                n += len(spec.children[nid])
    return n


@dataclass
class _ExecBarrier:
    """Synchronises one exec predicate across its ``M(s)`` locations.

    The first arriving location is the leader: it runs the step function and
    publishes the outputs; everyone waits on the event, then copies the
    outputs into their local data scope (Out^D(s) added to every D_i).
    """

    n: int
    outputs: dict[str, Any] = field(default_factory=dict)
    _arrived: int = 0
    _done: threading.Event = field(default_factory=threading.Event)
    _lock: threading.Lock = field(default_factory=threading.Lock)
    _leader_claimed: bool = False
    error: BaseException | None = None

    def arrive_and_maybe_lead(self) -> bool:
        with self._lock:
            lead = not self._leader_claimed
            self._leader_claimed = True
            self._arrived += 1
            return lead

    def publish(self, outputs: Mapping[str, Any]) -> None:
        self.outputs.update(outputs)
        self._done.set()

    def fail(self, e: BaseException) -> None:
        self.error = e
        self._done.set()

    def wait(self, timeout: float = 60.0) -> dict[str, Any]:
        if not self._done.wait(timeout):
            raise TimeoutError("exec barrier timed out")
        if self.error is not None:
            raise self.error
        return self.outputs


class ThreadedProgramRuntime:
    """Run one thread per location; each interprets only its own program.

    ``programs`` maps location → :class:`~repro.exec.program.LocationProgram`
    and ``steps`` maps location → step name → :class:`StepMeta` (per-location
    registries so callers — e.g. the multiprocess worker — can wrap step
    bodies per location).  ``instance_tag`` suffixes every channel endpoint's
    port, isolating concurrent workflow instances on one shared transport.
    """

    def __init__(
        self,
        programs: Mapping[str, LocationProgram],
        steps: Mapping[str, Mapping[str, StepMeta]],
        *,
        initial_payloads: Mapping[tuple[str, str], Any] | None = None,
        transport: Transport | None = None,
        timeout_s: float = 60.0,
        instance_tag: str | None = None,
        branch_pool=None,
        validate: bool = True,
        recorder=None,
        policy=None,
    ):
        self.programs = dict(programs)
        self.steps = {loc: dict(metas) for loc, metas in steps.items()}
        if validate:
            for loc, lp in self.programs.items():
                local = self.steps.get(loc, {})
                for op in lp.exec_ops():
                    if op.step not in local:
                        raise KeyError(
                            f"no step function registered for {op.step!r}"
                        )
        #: Optional shared executor for parallel branches: run_many reuses
        #: one pool across the whole batch instead of spawning fresh threads
        #: per Par node per instance (the pool is sized by the static branch
        #: count so blocked branches can never starve each other).
        self._branch_pool = branch_pool
        self.recorder = recorder
        self.transport = transport or InMemoryTransport(ChannelRegistry())
        self.timeout_s = timeout_s
        self.instance_tag = instance_tag
        self._barriers: dict[tuple, _ExecBarrier] = {}
        self._barrier_lock = threading.Lock()
        self.data: dict[str, dict[str, Any]] = {
            loc: {} for loc in self.programs
        }
        # Per-location condition: writes notify; execs wait on In^D(s) ⊆ D_l
        # (the (EXEC) rule's premise — after optimisation a datum may arrive
        # via a *sibling* parallel branch's recv, so exec must block on it).
        self._cond: dict[str, threading.Condition] = {
            loc: threading.Condition() for loc in self.programs
        }
        #: A failed parallel branch poisons its location so sibling branches
        #: blocked in ``_wait_data`` or ``_recv`` abort at once instead of
        #: burning ``timeout_s`` — the location thread then reports the root
        #: cause promptly (and, under a fault policy, crash-recovery replay
        #: starts while the run-level join still has budget left).
        self._poison: dict[str, BaseException | None] = {
            loc: None for loc in self.programs
        }
        for (l, d), v in (initial_payloads or {}).items():
            if l in self.data:
                self.data[l][d] = v
        self.errors: list[tuple[str, BaseException]] = []
        #: Uniform FaultPolicy (repro.exec.policy): a shared StepGuard wraps
        #: every step fire with timeout + retry, and a per-location op log
        #: (completed op indices, completion order) enables crash recovery —
        #: a died location thread is replayed from its cursor.  The log is
        #: only kept under a policy, so the policy-free hot path is unchanged.
        self.policy = policy
        self._guard: StepGuard | None = None
        self._op_log: dict[str, list[int]] | None = None
        self.recoveries: list[dict[str, Any]] = []
        if policy is not None:
            self._guard = StepGuard(
                policy,
                on_retry=lambda step, n, e: record_policy_fire(
                    self.recorder, "retry", "-", step, _mono(), _mono()
                ),
                on_timeout=lambda step: record_policy_fire(
                    self.recorder, "timeout", "-", step, _mono(), _mono()
                ),
            )
            self._op_log = {loc: [] for loc in self.programs}

    def _endpoint(self, op: SendOp | RecvOp) -> tuple[str, str, str]:
        if self.instance_tag is None:
            return op.endpoint
        return (op.src, op.dst, f"{op.port}#{self.instance_tag}")

    def _put_data(self, loc: str, items: Mapping[str, Any]) -> None:
        with self._cond[loc]:
            self.data[loc].update(items)
            self._cond[loc].notify_all()

    def _wait_data(self, loc: str, names) -> dict[str, Any]:
        with self._cond[loc]:
            self._cond[loc].wait_for(
                lambda: self._poison[loc] is not None
                or all(d in self.data[loc] for d in names),
                timeout=self.timeout_s,
            )
            if not all(d in self.data[loc] for d in names):
                poison = self._poison[loc]
                if poison is not None:
                    raise _BranchAborted(
                        f"{loc} branch aborted: a sibling failed with "
                        f"{poison!r}"
                    )
                missing = sorted(d for d in names if d not in self.data[loc])
                raise TimeoutError(f"{loc} never received {missing}")
            return {d: self.data[loc][d] for d in names}

    def _recv(self, loc: str, op: RecvOp):
        """``transport.recv``, abortable by a sibling branch's poison.

        A blocked receive cannot be woken through the location's data
        condition, so it polls in short slices and checks the poison flag
        between them — a crashed sibling must not leave this branch pinned
        for the full ``timeout_s`` (the run must report the root cause
        while the run-level join still has budget, and a crash-recovery
        replay needs that budget).  The unconsumed message, if it ever
        arrives, stays queued for the replay's own receive.
        """
        endpoint = self._endpoint(op)
        deadline = _mono() + self.timeout_s
        while True:
            if self._poison[loc] is not None:
                raise _BranchAborted(
                    f"{loc} recv aborted: a sibling failed with "
                    f"{self._poison[loc]!r}"
                )
            remaining = deadline - _mono()
            if remaining <= 0:
                raise TimeoutError(
                    f"{loc} never received on {endpoint}"
                )
            try:
                return self.transport.recv(
                    endpoint, timeout=min(remaining, 0.05)
                )
            except TimeoutError:
                continue

    def _poison_location(self, loc: str, exc: BaseException) -> None:
        """Abort the location's blocked data-waits and receives."""
        with self._cond[loc]:
            if self._poison[loc] is None:
                self._poison[loc] = exc
            self._cond[loc].notify_all()

    def _clear_poison(self, loc: str) -> None:
        with self._cond[loc]:
            self._poison[loc] = None

    # -- barrier registry ------------------------------------------------------
    def _barrier_for(self, op) -> _ExecBarrier:
        key = (op.step, op.inputs, op.outputs, op.locations)
        with self._barrier_lock:
            if key not in self._barriers:
                self._barriers[key] = _ExecBarrier(n=len(op.locations))
            return self._barriers[key]

    # -- per-location interpreter ----------------------------------------------
    def _fire(self, loc: str, op, meta, inputs):
        """One step-body call, under the fault policy's guard when present."""
        if self._guard is None:
            return meta.fn(inputs)
        return self._guard.fire(op.step, lambda: meta.fn(inputs))

    def _run_op(self, loc: str, op, index: int | None = None) -> None:
        """Interpret one op; log its index on success for crash replay."""
        self._run_op_inner(loc, op)
        if index is not None and self._op_log is not None:
            # list.append is atomic under the GIL; one writer per location
            # in normal runs, one per parallel branch inside a Par — either
            # way the log records a valid completion order for this loc.
            self._op_log[loc].append(index)

    def _run_op_inner(self, loc: str, op) -> None:
        rec = self.recorder
        if isinstance(op, SendOp):
            # The datum may be produced by a sibling branch — wait for it.
            payload = self._wait_data(loc, (op.data,))[op.data]
            if rec is None:
                self.transport.send(self._endpoint(op), op.data, payload)
            else:
                t0 = _mono()
                self.transport.send(self._endpoint(op), op.data, payload)
                record_send_fire(rec, op, t0, _mono(), payload)
            return
        if isinstance(op, RecvOp):
            t0 = _mono()
            msg = self._recv(loc, op)
            if rec is not None:
                record_recv_fire(rec, op, t0, _mono(), msg.payload)
            self._put_data(loc, {msg.data_name: msg.payload})
            return
        # ExecOp
        meta = self.steps[loc][op.step]
        if not op.is_spatial:
            inputs = self._wait_data(loc, op.inputs)
            if rec is None:
                out = self._fire(loc, op, meta, inputs)
            else:
                t0 = _mono()
                out = self._fire(loc, op, meta, inputs)
                record_exec_fire(rec, op, t0, _mono(), (loc,))
            self._put_data(loc, {d: out[d] for d in op.outputs})
            return
        # Spatial constraint: the op's pre-resolved leader flag elects who
        # runs the step body; everyone else synchronises on the barrier
        # (the (EXEC) rule's "Out^D(s) added to every D_i").
        barrier = self._barrier_for(op)
        t0 = _mono() if rec is not None else 0.0
        if op.leader:
            try:
                inputs = self._wait_data(loc, op.inputs)
                out = self._fire(loc, op, meta, inputs)
                barrier.publish({d: out[d] for d in op.outputs})
            except BaseException as e:  # noqa: BLE001
                barrier.fail(e)
                raise
        outputs = barrier.wait(self.timeout_s)
        if rec is not None:
            record_exec_fire(rec, op, t0, _mono(), (loc,))
        self._put_data(loc, dict(outputs))

    def _run_node(self, loc: str, spec, nid: int) -> None:
        kind = spec.kind[nid]
        if kind == K_ACT:
            i = spec.instr[nid]
            self._run_op(loc, self.programs[loc].ops[i], i)
            return
        if kind == K_SEQ:
            for child in spec.children[nid]:
                self._run_node(loc, spec, child)
            return
        # K_PAR — parallel branches become threads, like the generated
        # multithreaded bundles of the reference implementation.  With a
        # shared branch pool (run_many batches) the threads are reused
        # across instances instead of spawned per Par node; provably
        # non-blocking send-only branches run inline first (a schedule the
        # (L-PAR) congruence already allows), and the last blocking branch
        # runs on the current thread — only true concurrency pays for a
        # thread handoff.
        if self._branch_pool is not None:
            from concurrent.futures import wait as _fwait

            safe = self.programs[loc].inline_send_branches().get(
                nid, frozenset()
            )
            rest = []
            batch: list[tuple] = []
            for c in spec.children[nid]:
                if c in safe:
                    acts = self._collect_send_acts(loc, spec, c)
                    if acts is None:
                        self._run_node(loc, spec, c)
                    else:
                        batch.extend(acts)
                else:
                    rest.append(c)
            if batch:
                self._fire_send_batch(loc, batch)
            if not rest:
                return
            futures = [
                self._branch_pool.submit(self._run_branch, loc, spec, c)
                for c in rest[:-1]
            ]
            self._run_branch(loc, spec, rest[-1])
            _, not_done = _fwait(futures, timeout=self.timeout_s)
            if not_done:
                for f in not_done:
                    f.cancel()
                # A failed sibling usually *caused* the stuck branch (its
                # send never happened) — report the root cause, not the
                # orphaned receiver.
                errs = [
                    f.exception()
                    for f in futures
                    if f.done() and not f.cancelled() and f.exception()
                ]
                real = [e for e in errs if not isinstance(e, _BranchAborted)]
                if real or errs:
                    raise (real or errs)[0]
                raise TimeoutError(f"parallel branch stuck on {loc}")
            for f in futures:
                f.result()  # propagate the first branch failure
            return
        errs: list[BaseException] = []

        def branch(child: int) -> None:
            try:
                self._run_branch(loc, spec, child)
            except BaseException as e:  # noqa: BLE001
                errs.append(e)

        threads = [
            threading.Thread(target=branch, args=(c,), daemon=True)
            for c in spec.children[nid]
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join(self.timeout_s)
            if th.is_alive():
                if errs:
                    # The failed sibling is why this branch is stuck —
                    # surface the root cause.
                    raise _first_real(errs)
                raise TimeoutError(f"parallel branch stuck on {loc}")
        if errs:
            raise _first_real(errs)

    def _collect_send_acts(
        self, loc: str, spec, nid: int
    ) -> "list[tuple] | None":
        """Flatten a send-only inline branch into its (op, index) acts.

        Returns ``None`` when the subtree holds anything but sequential
        SendOps — the caller falls back to per-op interpretation.
        """
        kind = spec.kind[nid]
        if kind == K_ACT:
            i = spec.instr[nid]
            op = self.programs[loc].ops[i]
            if isinstance(op, SendOp):
                return [(op, i)]
            return None
        if kind == K_SEQ:
            acts: list[tuple] = []
            for child in spec.children[nid]:
                sub = self._collect_send_acts(loc, spec, child)
                if sub is None:
                    return None
                acts.extend(sub)
            return acts
        return None

    def _fire_send_batch(self, loc: str, acts: "list[tuple]") -> None:
        """Fire a rank's worth of sends as one fan-out exchange.

        Grouping consecutive ready sends by destination lets the
        transport amortise framing and the ack round trip over the whole
        burst (``scatter``/``send_many``) instead of paying them per
        message — on the zero-copy path a broadcast payload is also
        written to shared memory once, not once per destination.  Batch
        order preserves per-endpoint program order, so the FIFO delivery
        contract is unchanged; op indices are only logged after the
        whole exchange is acknowledged, so a crash replays the entire
        batch (exactly the all-or-nothing semantics crash replay already
        assumes for an unlogged op).
        """
        payloads = [
            self._wait_data(loc, (op.data,))[op.data] for op, _ in acts
        ]
        groups: dict[tuple, list] = {}
        for (op, _), payload in zip(acts, payloads):
            groups.setdefault(self._endpoint(op), []).append(
                (op.data, payload)
            )
        rec = self.recorder
        t0 = _mono() if rec is not None else 0.0
        self.transport.scatter(list(groups.items()))
        t1 = _mono() if rec is not None else 0.0
        for (op, i), payload in zip(acts, payloads):
            if rec is not None:
                record_send_fire(rec, op, t0, t1, payload)
            if self._op_log is not None:
                self._op_log[loc].append(i)

    def _run_branch(self, loc: str, spec, nid: int) -> None:
        """One Par branch; a failure poisons the location's data waits."""
        try:
            self._run_node(loc, spec, nid)
        except BaseException as e:  # noqa: BLE001
            self._poison_location(loc, e)
            raise

    def _run_location(self, loc: str) -> None:
        try:
            spec = self.programs[loc].control()
            if spec.root is not None:
                self._run_node(loc, spec, spec.root)
        except BaseException as e:  # noqa: BLE001
            if self._op_log is not None and not isinstance(e, TimeoutError):
                # Crash recovery: the location thread died mid-program.
                # Steps are pure and every completed op index is logged, so
                # the location can be replayed from its cursor — completed
                # ops skipped, the rest re-interpreted (same lineage
                # argument as elastic worker recovery).  Timeouts are
                # excluded: peer data that never arrived will not arrive
                # on replay either, it would just block another timeout_s.
                try:
                    done = len(self._op_log[loc])
                    self._replay_location(loc)
                except BaseException as replay_err:  # noqa: BLE001
                    self.errors.append((loc, e))
                    self.errors.append((loc, replay_err))
                else:
                    self.recoveries.append(
                        {
                            "mode": "replay",
                            "location": loc,
                            "completed_ops": done,
                            "error": repr(e),
                        }
                    )
                    t = _mono()
                    record_policy_fire(
                        self.recorder, "replay", loc, "-", t, t
                    )
                return
            self.errors.append((loc, e))

    def _replay_location(self, loc: str) -> None:
        """Re-interpret one location from its logged completion cursor.

        A fresh :class:`Cursor` is advanced through the logged indices (the
        recorded order was a real execution order, so each is enabled when
        completed), then the remaining ops run to termination.  Enabled ops
        are scheduled *dynamically*: each completion immediately launches
        whatever it newly enabled.  A lockstep frontier barrier would
        deadlock here — e.g. ``{exec v, recv dv}`` can both be enabled while
        ``recv dv`` waits on ``send dv``, which only becomes enabled once
        ``exec v`` completes.
        """
        self._clear_poison(loc)
        lp = self.programs[loc]
        cur = Cursor(lp)
        for i in self._op_log[loc]:
            cur.complete(i)
        if cur.finished():
            return
        cond = threading.Condition()
        errs: list[BaseException] = []
        running: set[int] = set()

        def one(i: int) -> None:
            try:
                self._run_op(loc, lp.ops[i], i)
            except BaseException as e:  # noqa: BLE001
                with cond:
                    errs.append(e)
                    running.discard(i)
                    cond.notify_all()
                return
            with cond:
                cur.complete(i)
                running.discard(i)
                if not errs:
                    launch_enabled()
                cond.notify_all()

        def launch_enabled() -> None:
            # Caller holds ``cond``.
            for j in cur.enabled_ops():
                if j not in running:
                    running.add(j)
                    threading.Thread(
                        target=one, args=(j,), daemon=True
                    ).start()

        deadline = _mono() + self.timeout_s
        with cond:
            launch_enabled()
            while not cur.finished() and not errs:
                remaining = deadline - _mono()
                if remaining <= 0 or not cond.wait(remaining):
                    raise TimeoutError(f"replay stuck on {loc}")
            if errs:
                raise errs[0]

    def run(self) -> dict[str, dict[str, Any]]:
        deadline = Deadline(
            self.policy.deadline_s if self.policy is not None else None
        )
        threads = [
            threading.Thread(target=self._run_location, args=(loc,), daemon=True)
            for loc in sorted(self.programs)
        ]
        for th in threads:
            th.start()
        for th in threads:
            rem = deadline.remaining()
            th.join(
                self.timeout_s if rem is None else min(self.timeout_s, max(rem, 0.0))
            )
            if th.is_alive():
                # The run deadline beats the per-thread diagnosis: abandon
                # the daemon location threads (pure steps — sound) and
                # surface the typed overrun.
                deadline.check()
                # A peer's failure (e.g. a sender exhausting channel
                # retries) leaves blocked receivers behind — report the
                # root cause, not the stuck thread it orphaned.
                self._raise_first_error()
                raise TimeoutError("a location thread did not finish")
        self._raise_first_error()
        return self.data

    def _raise_first_error(self) -> None:
        if self.errors:
            loc, err = self.errors[0]
            raise RuntimeError(f"location {loc} failed: {err!r}") from err


class ThreadedRuntime:
    """Run one thread per location; each interprets only its own bundle.

    Deprecated tree-walking reference oracle — the staged pipeline's
    ``threaded`` backend interprets the execution IR via
    :class:`ThreadedProgramRuntime` instead.
    """

    def __init__(
        self,
        bundles: Mapping[str, LocationBundle],
        *,
        initial_payloads: Mapping[tuple[str, str], Any] | None = None,
        channels: ChannelRegistry | None = None,
        transport: Transport | None = None,
        timeout_s: float = 60.0,
    ):
        from repro._compat import warn_legacy

        warn_legacy(
            "constructing repro.workflow.ThreadedRuntime directly",
            'swirl.trace(...).lower("threaded").compile(step_fns)',
        )
        if transport is not None and channels is not None:
            raise TypeError("pass either transport= or channels=, not both")
        if transport is None:
            # The historical in-memory queues, behind the Transport API.
            transport = InMemoryTransport(channels or ChannelRegistry())
        self.bundles = dict(bundles)
        self.transport = transport
        # Back-compat: the wrapped registry, when the transport has one.
        self.channels = getattr(transport, "registry", None)
        self.timeout_s = timeout_s
        self._barriers: dict[Exec, _ExecBarrier] = {}
        self._barrier_lock = threading.Lock()
        self.data: dict[str, dict[str, Any]] = {
            loc: {} for loc in self.bundles
        }
        # Per-location condition: writes notify; execs wait on In^D(s) ⊆ D_l
        # (the (EXEC) rule's premise — after optimisation a datum may arrive
        # via a *sibling* parallel branch's recv, so exec must block on it).
        self._cond: dict[str, threading.Condition] = {
            loc: threading.Condition() for loc in self.bundles
        }
        for (l, d), v in (initial_payloads or {}).items():
            self.data[l][d] = v
        self.errors: list[tuple[str, BaseException]] = []

    def _put_data(self, loc: str, items: Mapping[str, Any]) -> None:
        with self._cond[loc]:
            self.data[loc].update(items)
            self._cond[loc].notify_all()

    def _wait_data(self, loc: str, names: frozenset[str]) -> dict[str, Any]:
        with self._cond[loc]:
            ok = self._cond[loc].wait_for(
                lambda: all(d in self.data[loc] for d in names),
                timeout=self.timeout_s,
            )
            if not ok:
                missing = sorted(d for d in names if d not in self.data[loc])
                raise TimeoutError(f"{loc} never received {missing}")
            return {d: self.data[loc][d] for d in names}

    # -- barrier registry -----------------------------------------------------
    def _barrier_for(self, act: Exec) -> _ExecBarrier:
        with self._barrier_lock:
            if act not in self._barriers:
                self._barriers[act] = _ExecBarrier(n=len(act.locations))
            return self._barriers[act]

    # -- per-location interpreter ----------------------------------------------
    def _interp(self, loc: str, t: Trace) -> None:
        if isinstance(t, Nil):
            return
        if isinstance(t, Seq):
            for item in t.items:
                self._interp(loc, item)
            return
        if isinstance(t, Par):
            errs: list[BaseException] = []

            def branch(b: Trace) -> None:
                try:
                    self._interp(loc, b)
                except BaseException as e:  # noqa: BLE001
                    errs.append(e)

            threads = [
                threading.Thread(target=branch, args=(b,), daemon=True)
                for b in t.branches
            ]
            for th in threads:
                th.start()
            for th in threads:
                th.join(self.timeout_s)
                if th.is_alive():
                    raise TimeoutError(f"parallel branch stuck on {loc}")
            if errs:
                raise errs[0]
            return
        if isinstance(t, Send):
            # The datum may be produced by a sibling branch — wait for it.
            payload = self._wait_data(loc, frozenset([t.data]))[t.data]
            self.transport.send((t.src, t.dst, t.port), t.data, payload)
            return
        if isinstance(t, Recv):
            msg = self.transport.recv(
                (t.src, t.dst, t.port), timeout=self.timeout_s
            )
            self._put_data(loc, {msg.data_name: msg.payload})
            return
        if isinstance(t, Exec):
            bundle = self.bundles[loc]
            meta = bundle.steps[t.step]
            if len(t.locations) == 1:
                inputs = self._wait_data(loc, t.inputs)
                out = meta.fn(inputs)
                self._put_data(loc, {d: out[d] for d in t.outputs})
                return
            barrier = self._barrier_for(t)
            if barrier.arrive_and_maybe_lead():
                try:
                    inputs = self._wait_data(loc, t.inputs)
                    out = meta.fn(inputs)
                    barrier.publish({d: out[d] for d in t.outputs})
                except BaseException as e:  # noqa: BLE001
                    barrier.fail(e)
                    raise
            outputs = barrier.wait(self.timeout_s)
            self._put_data(loc, dict(outputs))
            return
        raise TypeError(f"not a trace: {t!r}")

    def _run_location(self, loc: str) -> None:
        try:
            self._interp(loc, self.bundles[loc].trace)
        except BaseException as e:  # noqa: BLE001
            self.errors.append((loc, e))

    def run(self) -> dict[str, dict[str, Any]]:
        threads = [
            threading.Thread(target=self._run_location, args=(loc,), daemon=True)
            for loc in sorted(self.bundles)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join(self.timeout_s)
            if th.is_alive():
                # A peer's failure (e.g. a sender exhausting channel
                # retries) leaves blocked receivers behind — report the
                # root cause, not the stuck thread it orphaned.
                self._raise_first_error()
                raise TimeoutError("a location thread did not finish")
        self._raise_first_error()
        return self.data

    def _raise_first_error(self) -> None:
        if self.errors:
            loc, err = self.errors[0]
            raise RuntimeError(f"location {loc} failed: {err!r}") from err
