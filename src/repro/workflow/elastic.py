"""Elastic re-mapping and failure recovery.

SWIRL semantics is invariant under *location renaming* (names are opaque in
Figs. 2-3), so recovering from a dead location is a bijective substitution on
the last consistent checkpoint:

1. take the checkpointed system (remaining traces per location),
2. rename every reference to the dead location — configuration name,
   ``send``/``recv`` endpoints, ``exec`` location sets — to a spare,
3. move the dead location's checkpointed payloads to the spare,
4. resume reduction.

Steps already completed before the checkpoint are not re-run; in-flight work
is re-executed from pure inputs (lineage argument).  The same primitive
implements *scale-down* (fold several locations onto one — the renaming is
then surjective rather than bijective, which is still sound because traces
compose in parallel and L-COMM handles the now-local transfers) and
*scale-up* via :func:`rebalance` (re-encode the instance with a new mapping).
"""

from __future__ import annotations

from dataclasses import replace
from typing import Mapping

from repro.core.encoding import encode
from repro.core.graph import DistributedWorkflowInstance
from repro.core.optimizer import rewrite_system
from repro.core.parser import dumps
from repro.core.syntax import (
    Exec,
    LocationConfig,
    Nil,
    Par,
    Recv,
    Send,
    Seq,
    Trace,
    WorkflowSystem,
    par,
    seq,
)
from .runtime import Checkpoint


def _rename_trace(t: Trace, ren: Mapping[str, str]) -> Trace:
    r = lambda l: ren.get(l, l)  # noqa: E731
    if isinstance(t, Nil):
        return t
    if isinstance(t, Exec):
        return Exec(t.step, t.inputs, t.outputs, tuple(r(l) for l in t.locations))
    if isinstance(t, Send):
        return Send(t.data, t.port, r(t.src), r(t.dst))
    if isinstance(t, Recv):
        return Recv(t.port, r(t.src), r(t.dst))
    if isinstance(t, Seq):
        return seq(*(_rename_trace(i, ren) for i in t.items))
    if isinstance(t, Par):
        return par(*(_rename_trace(b, ren) for b in t.branches))
    raise TypeError(f"not a trace: {t!r}")


def rename_locations(w: WorkflowSystem, ren: Mapping[str, str]) -> WorkflowSystem:
    """Apply a location substitution to a whole system.

    If two configurations collapse onto the same name (scale-down), their
    data sets are united and their traces composed in parallel.
    """
    merged: dict[str, LocationConfig] = {}
    for cfg in w.configs:
        new_name = ren.get(cfg.location, cfg.location)
        new_trace = _rename_trace(cfg.trace, ren)
        if new_name in merged:
            prev = merged[new_name]
            merged[new_name] = LocationConfig(
                new_name, prev.data | cfg.data, par(prev.trace, new_trace)
            )
        else:
            merged[new_name] = LocationConfig(new_name, cfg.data, new_trace)
    return WorkflowSystem(tuple(merged[k] for k in sorted(merged)))


def fold_payloads(
    payloads: Mapping[tuple[str, str], object], ren: Mapping[str, str]
) -> dict[tuple[str, str], object]:
    """Move payloads under a location substitution, deterministically.

    A fold can collapse two holders of the same datum onto one key.  The
    precedence is fixed: a *survivor's* payload (a location not being
    renamed away) always beats one inherited from a renamed (dead)
    location, and between two renamed locations the lexicographically
    smallest source wins — never dict-iteration order.
    """
    folded: dict[tuple[str, str], object] = {}
    for l, d in sorted(payloads):
        v = payloads[(l, d)]
        if l in ren:
            folded.setdefault((ren[l], d), v)
        else:
            folded[(l, d)] = v
    return folded


def recover_checkpoint(
    ckpt: Checkpoint, ren: Mapping[str, str]
) -> Checkpoint:
    """Produce the post-recovery checkpoint under a location substitution."""
    system = rename_locations(ckpt.system, ren)
    return Checkpoint(
        system_text=dumps(system),
        payloads=fold_payloads(ckpt.payloads, ren),
        completed_execs=ckpt.completed_execs,
    )


def plan_recovery(
    live: list[str], dead: list[str], spares: list[str]
) -> dict[str, str]:
    """Assign each dead location a replacement: spares first, then fold onto
    live locations round-robin (scale-down)."""
    ren: dict[str, str] = {}
    pool = list(spares)
    live_sorted = sorted(live)
    folded = 0  # counts fold assignments only, so the round-robin starts
    # at live_sorted[0] regardless of how many deads took spares first.
    for d in sorted(dead):
        if pool:
            ren[d] = pool.pop(0)
        elif live_sorted:
            ren[d] = live_sorted[folded % len(live_sorted)]
            folded += 1
        else:
            raise RuntimeError("no live locations or spares to recover onto")
    return ren


def rebalance(
    inst: DistributedWorkflowInstance,
    new_mapping: Mapping[str, tuple[str, ...]],
    *,
    optimize_system: bool = True,
) -> WorkflowSystem:
    """Scale-out/in: re-encode the *instance* under a new step→location map.

    Used at iteration boundaries (e.g. between training steps) when the
    resource pool changed: the workflow graph and data are unchanged, only
    ``M`` is replaced, then ``⟦·⟧`` and the optimiser re-derive the plan.
    """
    locations = frozenset(l for ls in new_mapping.values() for l in ls)
    new_inst = replace(
        inst,
        locations=locations,
        mapping={s: tuple(ls) for s, ls in new_mapping.items()},
        initial_data={
            l: ds for l, ds in inst.initial_data.items() if l in locations
        },
    )
    w = encode(new_inst)
    if optimize_system:
        w, _ = rewrite_system(w)
    return w
