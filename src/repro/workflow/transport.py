"""Pluggable COMM transports — the wire under every decentralised backend.

A :class:`Transport` carries SWIRL COMM messages between locations, one
logically independent FIFO per ``(src, dst, port)`` endpoint.  The threaded
backend and the multiprocess backend both speak this interface; the only
difference between "threads over queues" and "processes over sockets" is
which transport the runtime is handed.

Contract (enforced by ``tests/test_transport.py`` against every registered
implementation):

* :meth:`Transport.send` blocks until the transport has durably accepted the
  message and returns exactly once per logical message.  Unreliable wires
  retransmit (at-least-once) and the receiving side deduplicates by sequence
  number, so the *effect* is exactly-once — sound because SWIRL data
  elements are immutable and COMM copies rather than consumes.
* Messages on one endpoint are delivered in send order; distinct endpoints
  never leak into each other.
* :meth:`Transport.recv` with a timeout raises :class:`TimeoutError`; a
  blocked ``recv`` (and any later one) raises :class:`ChannelClosed` once
  the transport is closed, after draining already-delivered messages.
* :meth:`Transport.close` is idempotent and unblocks every waiter.

Three implementations ship in-tree:

==========  ==============================================================
``memory``  :class:`InMemoryTransport` — the historical in-process queues
            (:class:`~repro.workflow.channels.ChannelRegistry`) behind the
            interface; what the ``threaded`` backend uses.
``socket``  :class:`SocketTransport` — ``multiprocessing.connection``
            sockets (AF_UNIX, TCP fallback) with pickle-5 out-of-band
            payload framing, per-message acks, and resend on ack timeout;
            what the ``multiprocess`` backend uses across OS processes.
``shm``     :class:`SharedMemoryTransport` — the socket control/ack plane
            with array payloads framed through POSIX shared memory
            segments: receivers map buffers instead of deserialising
            bytes (zero-copy), non-array payloads spill to the pickle
            path.
==========  ==============================================================

Third-party transports join through :func:`register_transport` and get the
conformance suite for free by implementing :meth:`Transport.conformance`.
"""

from __future__ import annotations

import glob
import hashlib
import itertools
import os
import pickle
import socket as _socket
import tempfile
import threading
import time
import weakref
from abc import ABC, abstractmethod
from collections import deque
from typing import Any, Iterable, Mapping

import numpy as np

from .channels import (
    Channel,
    ChannelClosed,
    ChannelRegistry,
    Endpoint,
    Message,
    endpoint_rng,
)

__all__ = [
    "AckTimeout",
    "Transport",
    "InMemoryTransport",
    "SocketTransport",
    "SharedMemoryTransport",
    "HybridTransport",
    "ChannelClosed",
    "Message",
    "TRANSPORTS",
    "register_transport",
    "get_transport",
    "socket_addresses",
    "shm_namespace",
]

#: Poll interval for interruptible blocking waits.
_POLL_S = 0.05

#: AF_UNIX socket paths are limited to ~108 bytes; stay well under it.
_MAX_UNIX_PATH = 90


class AckTimeout(ChannelClosed):
    """A send exhausted its resend budget without ever seeing an ack.

    Distinct from a peer-initiated close (a bare :class:`ChannelClosed`):
    the peer may still be alive but silent — callers deciding between
    "peer is gone" and "peer is straggling" branch on this type.  Carries
    the failing ``endpoint``, the message ``seq`` and how many ``attempts``
    were made (each attempt = one send + one ack wait).
    """

    def __init__(self, endpoint: Endpoint, *, seq: int, attempts: int):
        super().__init__(
            f"no ack after {attempts} sends on {tuple(endpoint)} "
            f"(seq {seq})"
        )
        self.endpoint = tuple(endpoint)
        self.seq = seq
        self.attempts = attempts


class Transport(ABC):
    """One reliable, per-endpoint-ordered message fabric."""

    #: Registry name (set on subclasses).
    name: str = "abstract"
    #: Whether endpoints of this transport can span OS processes.  The
    #: multiprocess backend refuses transports that cannot.
    crosses_processes: bool = False

    def open(self, endpoint: Endpoint) -> None:
        """Declare an endpoint before use (optional; default no-op)."""

    @abstractmethod
    def send(self, endpoint: Endpoint, data_name: str, payload: Any) -> None:
        """Deliver one message; blocks until accepted, exactly once."""

    def send_many(
        self, endpoint: Endpoint, items: "Iterable[tuple[str, Any]]"
    ) -> None:
        """Deliver a burst of messages on one endpoint, in order.

        Same delivery contract as per-message :meth:`send` (exactly-once
        effect, FIFO).  The default just loops; wire transports override
        it to amortise framing and the ack round trip over the burst —
        the receiver still sees ``len(items)`` ordinary messages.
        """
        for data_name, payload in items:
            self.send(endpoint, data_name, payload)

    def scatter(
        self,
        sends: "Iterable[tuple[Endpoint, Iterable[tuple[str, Any]]]]",
    ) -> None:
        """Deliver bursts to several endpoints as one fan-out exchange.

        Same delivery contract as calling :meth:`send_many` per endpoint.
        The default does exactly that; wire transports override it to
        put every destination's frame on the wire before waiting for any
        acknowledgement, so the receivers' decode work overlaps instead
        of serialising behind one ack round trip at a time.
        """
        for endpoint, items in sends:
            self.send_many(endpoint, items)

    @abstractmethod
    def recv(
        self, endpoint: Endpoint, timeout: float | None = None
    ) -> Message:
        """Next message on ``endpoint`` (FIFO); TimeoutError on timeout."""

    def close(self) -> None:
        """Tear down; idempotent, wakes blocked receivers (ChannelClosed)."""

    def stats(self) -> dict[str, Any]:
        return {}

    @classmethod
    def conformance(
        cls,
        tmp_path: str,
        locations: Iterable[str],
        *,
        loss: float = 0.0,
        ack_loss: float = 0.0,
        seed: int = 0,
    ) -> "Transport":
        """Build an instance for the conformance suite.

        Must return a transport able to both send and receive between the
        given ``locations`` within one process, with ``loss``/``ack_loss``
        injected unreliability (ignore what does not apply).  Implementing
        this is what opts a registered transport into
        ``tests/test_transport.py``.
        """
        raise NotImplementedError


# ---------------------------------------------------------------------------
# In-memory transport — the refactored historical queues
# ---------------------------------------------------------------------------


class InMemoryTransport(Transport):
    """The in-process channel queues behind the :class:`Transport` API.

    Wraps a :class:`~repro.workflow.channels.ChannelRegistry`; behaviour is
    exactly the pre-transport ``threaded`` backend's (including per-endpoint
    fault injection via the registry's ``drop_prob``/``delay_s``/``seed``).
    """

    name = "memory"
    crosses_processes = False

    def __init__(
        self, registry: ChannelRegistry | None = None, **channel_kwargs: Any
    ):
        if registry is not None and channel_kwargs:
            raise TypeError(
                "pass either registry= or per-channel options "
                f"({sorted(channel_kwargs)}), not both"
            )
        self.registry = registry or ChannelRegistry(**channel_kwargs)

    def open(self, endpoint: Endpoint) -> None:
        self.registry.channel(*endpoint)

    def send(self, endpoint: Endpoint, data_name: str, payload: Any) -> None:
        self.registry.channel(*endpoint).put_reliable(data_name, payload)

    def recv(
        self, endpoint: Endpoint, timeout: float | None = None
    ) -> Message:
        return self.registry.channel(*endpoint).get(timeout)

    def close(self) -> None:
        self.registry.close()

    def stats(self) -> dict[str, Any]:
        return self.registry.stats()

    @classmethod
    def conformance(
        cls,
        tmp_path: str,
        locations: Iterable[str],
        *,
        loss: float = 0.0,
        ack_loss: float = 0.0,
        seed: int = 0,
    ) -> "InMemoryTransport":
        # The queue transport has no separate ack channel: a lost ack and a
        # lost message are both "the transport did not accept it", retried
        # by put_reliable.
        return cls(drop_prob=max(loss, ack_loss), seed=seed)


# ---------------------------------------------------------------------------
# Socket transport — multiprocessing.connection with acks + resend
# ---------------------------------------------------------------------------


class _Inbox:
    """Per-endpoint delivery queue with close-aware blocking get."""

    __slots__ = ("_items", "_cond", "_closed")

    def __init__(self) -> None:
        self._items: deque[Message] = deque()
        self._cond = threading.Condition()
        self._closed = False

    def put(self, msg: Message) -> None:
        with self._cond:
            self._items.append(msg)
            self._cond.notify()

    def get(self, timeout: float | None, endpoint: Endpoint) -> Message:
        with self._cond:
            ok = self._cond.wait_for(
                lambda: self._items or self._closed, timeout
            )
            if self._items:
                return self._items.popleft()
            if self._closed:
                raise ChannelClosed(
                    f"transport closed while receiving on {endpoint}"
                )
            assert not ok
            raise TimeoutError(f"recv timed out on {endpoint}")

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()


def socket_addresses(
    locations: Iterable[str],
    *,
    base_dir: str | os.PathLike | None = None,
    family: str | None = None,
) -> dict[str, Any]:
    """Assign one listener address per location, upfront.

    AF_UNIX paths under ``base_dir`` (or a fresh temp dir) where available —
    no port collisions, cleaned up with the directory; ``127.0.0.1``
    ephemeral ports otherwise.  Addresses are allocated *before* any worker
    starts so every process gets the same address book.
    """
    locs = sorted(set(locations))
    if family is None:
        family = "AF_UNIX" if hasattr(_socket, "AF_UNIX") else "AF_INET"
    if family == "AF_UNIX":
        if base_dir is not None:
            base = os.fspath(base_dir)
            os.makedirs(base, exist_ok=True)
        else:
            base = tempfile.mkdtemp(prefix="swirl-net-")
        paths = {
            loc: os.path.join(base, f"{i}.sock") for i, loc in enumerate(locs)
        }
        if all(len(p) <= _MAX_UNIX_PATH for p in paths.values()):
            return paths
        family = "AF_INET"  # path too long for sockaddr_un — fall back
    addrs: dict[str, Any] = {}
    for loc in locs:
        s = _socket.socket(_socket.AF_INET, _socket.SOCK_STREAM)
        s.bind(("127.0.0.1", 0))
        addrs[loc] = ("127.0.0.1", s.getsockname()[1])
        s.close()
    return addrs


class SocketTransport(Transport):
    """COMM over ``multiprocessing.connection`` sockets, ack + resend.

    Every location in ``serve`` gets a listener at ``addresses[location]``;
    inbound frames are demultiplexed into per-endpoint inboxes by reader
    threads.  ``send`` opens (and caches) one client connection per endpoint,
    writes a ``("msg", endpoint, seq, name, payload)`` frame, and blocks
    until the matching ``("ack", endpoint, seq)`` arrives — resending after
    ``ack_timeout``, up to ``max_sends`` times (at-least-once).  The
    receiving side acks every copy but delivers each sequence number once
    (idempotent receive), so a lost ack never duplicates a message.

    Frames are serialised with ``pickle.HIGHEST_PROTOCOL`` and protocol-5
    out-of-band buffers: large buffer-backed payloads (numpy arrays,
    ``bytes``) travel as raw multipart segments after a small header
    instead of being copied into the pickle stream — one fewer full copy
    per side.  The receive side reads each out-of-band segment straight
    into a fresh ``bytearray`` and reconstructs arrays viewing it, so
    payloads stay writable.

    ``drop_prob`` (sender swallows the frame) and ``drop_ack_prob``
    (receiver swallows the ack) inject wire faults for the conformance and
    fault-tolerance tests, seeded per endpoint like the channel registry.

    Subclass hooks: :meth:`_encode_payload` / :meth:`_decode_payload`
    rewrite a payload on its way onto / off the wire (the shared-memory
    transport swaps arrays for segment references there), and
    :meth:`_on_acked` fires once per logical message when its ack lands
    (where segment ownership is handed off).
    """

    name = "socket"
    crosses_processes = True

    def __init__(
        self,
        addresses: Mapping[str, Any],
        *,
        serve: Iterable[str] = (),
        authkey: bytes = b"swirl-transport",
        ack_timeout: float = 1.0,
        max_sends: int = 20,
        connect_timeout: float = 15.0,
        drop_prob: float = 0.0,
        drop_ack_prob: float = 0.0,
        seed: int = 0,
    ):
        from multiprocessing.connection import Listener

        self._addresses = dict(addresses)
        self._serve = tuple(sorted(set(serve)))
        unknown = [l for l in self._serve if l not in self._addresses]
        if unknown:
            raise KeyError(f"serve locations without addresses: {unknown}")
        self._authkey = bytes(authkey)
        self.ack_timeout = float(ack_timeout)
        self.max_sends = int(max_sends)
        self.connect_timeout = float(connect_timeout)
        self.drop_prob = float(drop_prob)
        self.drop_ack_prob = float(drop_ack_prob)
        self._seed = int(seed)

        self._closed = threading.Event()
        self._inboxes: dict[Endpoint, _Inbox] = {}
        self._inbox_lock = threading.Lock()
        self._delivered: dict[Endpoint, int] = {}
        self._deliver_lock = threading.Lock()
        self._conns: dict[Endpoint, Any] = {}
        self._send_locks: dict[Endpoint, threading.Lock] = {}
        self._seq: dict[Endpoint, int] = {}
        self._drop_rngs: dict[Endpoint, Any] = {}
        self._ack_rngs: dict[Endpoint, Any] = {}
        self._server_conns: list[Any] = []
        self._threads: list[threading.Thread] = []
        # Counters are bumped from reader threads and concurrent senders —
        # serialise the read-modify-write or increments get lost.
        self._stats_lock = threading.Lock()
        self._stats = {
            "sent": 0,
            "delivered": 0,
            "duplicates": 0,
            "resends": 0,
            "dropped": 0,
            "acks_dropped": 0,
            "decode_failures": 0,
        }
        self._listeners = {}
        for loc in self._serve:
            listener = Listener(self._addresses[loc], authkey=self._authkey)
            self._listeners[loc] = listener
            th = threading.Thread(
                target=self._accept_loop,
                args=(listener,),
                name=f"swirl-accept-{loc}",
                daemon=True,
            )
            th.start()
            self._threads.append(th)

    def _bump(self, key: str) -> None:
        with self._stats_lock:
            self._stats[key] = self._stats.get(key, 0) + 1

    # -- receive path --------------------------------------------------------

    def _inbox(self, endpoint: Endpoint) -> _Inbox:
        with self._inbox_lock:
            box = self._inboxes.get(endpoint)
            if box is None:
                box = self._inboxes[endpoint] = _Inbox()
                if self._closed.is_set():
                    box.close()
            return box

    def _accept_loop(self, listener) -> None:
        while not self._closed.is_set():
            try:
                conn = listener.accept()
            except Exception:  # closed listener or failed auth handshake
                if self._closed.is_set():
                    return
                continue
            self._server_conns.append(conn)
            th = threading.Thread(
                target=self._reader, args=(conn,), daemon=True
            )
            th.start()
            self._threads.append(th)

    # -- wire framing --------------------------------------------------------

    def _send_frame(self, conn, frame: tuple) -> None:
        """Write one frame with protocol-5 out-of-band buffer segments.

        Buffer-backed payload leaves (contiguous arrays, ``bytes``) are
        extracted by ``buffer_callback`` and written raw after a small
        ``("oob", sizes, meta)`` header — the array body is never copied
        into the pickle stream.  Frames without extractable buffers go as
        one plain pickle (also what every ack uses).
        """
        buffers: list[pickle.PickleBuffer] = []
        meta = pickle.dumps(
            frame, protocol=pickle.HIGHEST_PROTOCOL,
            buffer_callback=buffers.append,
        )
        if not buffers:
            conn.send_bytes(meta)
            return
        try:
            raws = [b.raw() for b in buffers]
        except BufferError:  # non-contiguous exotic buffer — inline it
            conn.send_bytes(pickle.dumps(frame, pickle.HIGHEST_PROTOCOL))
            return
        header = ("oob", [r.nbytes for r in raws], meta)
        conn.send_bytes(pickle.dumps(header, pickle.HIGHEST_PROTOCOL))
        for r in raws:
            if r.nbytes:  # the reader skips empty parts — mirror it
                conn.send_bytes(r)

    @staticmethod
    def _recv_frame(conn) -> Any:
        """Read one frame; reassemble out-of-band multipart segments.

        Each out-of-band segment lands in a fresh writable ``bytearray``
        via ``recv_bytes_into`` and the reconstructed arrays view those
        buffers directly — the receive side pays exactly one copy (kernel
        socket buffer → bytearray), not pickle-decode plus array-build.
        """
        obj = pickle.loads(conn.recv_bytes())
        if not (isinstance(obj, tuple) and obj and obj[0] == "oob"):
            return obj
        _, sizes, meta = obj
        bufs = []
        for n in sizes:
            buf = bytearray(n)
            if n:
                conn.recv_bytes_into(memoryview(buf))
            bufs.append(buf)
        return pickle.loads(meta, buffers=bufs)

    # -- payload hooks (overridden by SharedMemoryTransport) -----------------

    def _encode_payload(self, endpoint: Endpoint, seq: int, payload: Any):
        """Rewrite a payload before it is framed (once per logical send)."""
        return payload

    def _decode_payload(self, endpoint: Endpoint, payload: Any) -> Any:
        """Rewrite a payload after the frame is read, before delivery."""
        return payload

    def _on_acked(self, endpoint: Endpoint, seq: int) -> None:
        """The ack for ``(endpoint, seq)`` landed — the message arrived."""

    def _ack_frame(self, conn, endpoint: Endpoint, seq: int) -> tuple:
        """Build the ack for a delivered message (hook: shm piggybacks
        payload releases here so receivers never write control frames
        from consumer threads)."""
        return ("ack", endpoint, seq)

    def _reader(self, conn) -> None:
        while not self._closed.is_set():
            try:
                frame = self._recv_frame(conn)
            except (EOFError, OSError):
                break
            if not (isinstance(frame, tuple) and frame):
                continue
            if frame[0] == "msg":
                _, endpoint, seq, name, payload = frame
                first, batch = seq, [(name, payload)]
            elif frame[0] == "msgs":
                _, endpoint, first, batch = frame
            else:
                continue
            endpoint = tuple(endpoint)
            duplicates = delivered = 0
            with self._deliver_lock:
                hwm = self._delivered.get(endpoint, 0)
                fresh: list[tuple[str, Any, int]] = []
                decode_ok = True
                for i, (name, payload) in enumerate(batch):
                    seq_i = first + i
                    if seq_i <= hwm:
                        duplicates += 1  # resend of a delivered prefix
                        continue
                    try:
                        payload = self._decode_payload(endpoint, payload)
                    except Exception:
                        # A fresh payload we cannot decode (e.g. its
                        # segment vanished): stop here and ack only the
                        # progress made, so the sender's at-least-once
                        # resend retries the rest rather than losing it.
                        self._bump("decode_failures")
                        decode_ok = False
                        break
                    fresh.append((name, payload, seq_i))
                if not fresh and not decode_ok:
                    continue  # no progress at all: withhold the ack
                if fresh:
                    self._delivered[endpoint] = fresh[-1][2]
                ack_seq = fresh[-1][2] if fresh else first + len(batch) - 1
                # Ack BEFORE the messages become consumable: once they are
                # in the inbox the receiving worker may finish its program
                # and close this transport, and an ack queued after that
                # close is lost — the sender then dies awaiting it.  Socket
                # buffers survive close, so an ack already on the wire is
                # always readable by the sender.
                if (
                    self.drop_ack_prob
                    and self._rng(self._ack_rngs, endpoint, salt=1).random()
                    < self.drop_ack_prob
                ):
                    self._bump("acks_dropped")
                    acked = True  # simulated loss: keep serving
                else:
                    try:
                        conn.send(self._ack_frame(conn, endpoint, ack_seq))
                        acked = True
                    except (EOFError, OSError, BrokenPipeError):
                        acked = False  # sender gone; deliver, then stop
                # Deliver under the lock so two connections carrying the
                # same endpoint cannot reorder fresh sequence numbers.
                for name, payload, seq_i in fresh:
                    self._inbox(endpoint).put(Message(name, payload, seq_i))
                    delivered += 1
            with self._stats_lock:
                self._stats["duplicates"] += duplicates
                self._stats["delivered"] += delivered
            if not acked:
                break

    def recv(
        self, endpoint: Endpoint, timeout: float | None = None
    ) -> Message:
        return self._inbox(tuple(endpoint)).get(timeout, tuple(endpoint))

    # -- send path -----------------------------------------------------------

    def _rng(self, cache: dict, endpoint: Endpoint, *, salt: int = 0):
        rng = cache.get(endpoint)
        if rng is None:
            rng = cache[endpoint] = endpoint_rng(self._seed + salt, endpoint)
        return rng

    def _connect(self, endpoint: Endpoint):
        from multiprocessing.connection import Client

        conn = self._conns.get(endpoint)
        if conn is not None:
            return conn
        dst = endpoint[1]
        try:
            address = self._addresses[dst]
        except KeyError:
            raise KeyError(
                f"no address for destination {dst!r}; "
                f"known: {sorted(self._addresses)}"
            ) from None
        deadline = time.monotonic() + self.connect_timeout
        while True:
            if self._closed.is_set():
                raise ChannelClosed(f"transport closed; cannot reach {dst!r}")
            try:
                conn = Client(address, authkey=self._authkey)
                break
            except (OSError, EOFError) as e:
                # Peer's listener may not be bound yet — retry briefly.
                if time.monotonic() >= deadline:
                    raise ChannelClosed(
                        f"cannot connect to {dst!r} at {address!r}: {e}"
                    ) from e
                time.sleep(0.02)
        self._conns[endpoint] = conn
        return conn

    def send(self, endpoint: Endpoint, data_name: str, payload: Any) -> None:
        endpoint = tuple(endpoint)
        if self._closed.is_set():
            raise ChannelClosed(f"transport closed; cannot send on {endpoint}")
        lock = self._send_locks.setdefault(endpoint, threading.Lock())
        with lock:
            conn = self._connect(endpoint)
            self._seq[endpoint] = seq = self._seq.get(endpoint, 0) + 1
            self._bump("sent")
            # Encode once per logical message — resends reuse the frame
            # (and, for the shm transport, the already-written segment).
            payload = self._encode_payload(endpoint, seq, payload)
            frame = ("msg", endpoint, seq, data_name, payload)
            rng = self._rng(self._drop_rngs, endpoint)
            for attempt in range(self.max_sends):
                if self._closed.is_set():
                    raise ChannelClosed(
                        f"transport closed; cannot send on {endpoint}"
                    )
                if attempt:
                    self._bump("resends")
                if self.drop_prob and rng.random() < self.drop_prob:
                    self._bump("dropped")  # simulated wire loss
                else:
                    try:
                        self._send_frame(conn, frame)
                    except (OSError, BrokenPipeError, ValueError) as e:
                        raise ChannelClosed(
                            f"connection lost on {endpoint}: {e}"
                        ) from e
                if self._await_ack(conn, endpoint, seq):
                    self._on_acked(endpoint, seq)
                    return
            raise AckTimeout(endpoint, seq=seq, attempts=self.max_sends)

    def send_many(
        self, endpoint: Endpoint, items: "Iterable[tuple[str, Any]]"
    ) -> None:
        """Burst send: one wire frame and one ack round trip for the lot.

        The per-message protocol cost (framing, syscalls, the receiver
        wake-up and the ack wait) is paid once per burst instead of once
        per payload — on a busy fleet the round trip dominates small
        payload costs, so rank-synchronous exchanges batch naturally.
        Delivery semantics are exactly ``len(items)`` ordered sends: the
        receiver acks the highest consecutive sequence it has decoded,
        and a resend after partial progress skips the delivered prefix.
        """
        items = list(items)
        if not items:
            return
        if len(items) == 1:
            return self.send(endpoint, items[0][0], items[0][1])
        endpoint = tuple(endpoint)
        if self._closed.is_set():
            raise ChannelClosed(f"transport closed; cannot send on {endpoint}")
        lock = self._send_locks.setdefault(endpoint, threading.Lock())
        with lock:
            conn = self._connect(endpoint)
            first = self._seq.get(endpoint, 0) + 1
            last = first + len(items) - 1
            self._seq[endpoint] = last
            with self._stats_lock:
                self._stats["sent"] += len(items)
            encoded = [
                (name, self._encode_payload(endpoint, first + i, payload))
                for i, (name, payload) in enumerate(items)
            ]
            frame = ("msgs", endpoint, first, encoded)
            rng = self._rng(self._drop_rngs, endpoint)
            for attempt in range(self.max_sends):
                if self._closed.is_set():
                    raise ChannelClosed(
                        f"transport closed; cannot send on {endpoint}"
                    )
                if attempt:
                    self._bump("resends")
                if self.drop_prob and rng.random() < self.drop_prob:
                    self._bump("dropped")  # simulated wire loss
                else:
                    try:
                        self._send_frame(conn, frame)
                    except (OSError, BrokenPipeError, ValueError) as e:
                        raise ChannelClosed(
                            f"connection lost on {endpoint}: {e}"
                        ) from e
                if self._await_ack(conn, endpoint, last):
                    for i in range(len(items)):
                        self._on_acked(endpoint, first + i)
                    return
            raise AckTimeout(endpoint, seq=last, attempts=self.max_sends)

    def scatter(
        self,
        sends: "Iterable[tuple[Endpoint, Iterable[tuple[str, Any]]]]",
    ) -> None:
        """Pipelined fan-out: frames to every destination, then the acks.

        A serial ``send_many`` loop leaves every other receiver idle
        while the sender blocks on one ack; here all frames hit the wire
        first, so the receivers decode concurrently and the sender pays
        roughly one ack latency for the whole exchange instead of one
        per destination.  Endpoint locks are taken in sorted order so
        concurrent scatters over overlapping destinations cannot
        deadlock.
        """
        sends = [(tuple(ep), list(items)) for ep, items in sends]
        sends = [(ep, items) for ep, items in sends if items]
        if not sends:
            return
        if len(sends) == 1:
            return self.send_many(sends[0][0], sends[0][1])
        if self._closed.is_set():
            raise ChannelClosed("transport closed; cannot scatter")
        sends.sort(key=lambda s: s[0])
        acquired: list[threading.Lock] = []
        pending: list[tuple] = []
        try:
            for endpoint, items in sends:
                lock = self._send_locks.setdefault(endpoint, threading.Lock())
                lock.acquire()
                acquired.append(lock)
                conn = self._connect(endpoint)
                first = self._seq.get(endpoint, 0) + 1
                last = first + len(items) - 1
                self._seq[endpoint] = last
                with self._stats_lock:
                    self._stats["sent"] += len(items)
                encoded = [
                    (name, self._encode_payload(endpoint, first + i, payload))
                    for i, (name, payload) in enumerate(items)
                ]
                if len(encoded) == 1:
                    frame = ("msg", endpoint, first, encoded[0][0], encoded[0][1])
                else:
                    frame = ("msgs", endpoint, first, encoded)
                rng = self._rng(self._drop_rngs, endpoint)
                if self.drop_prob and rng.random() < self.drop_prob:
                    self._bump("dropped")  # simulated wire loss
                else:
                    try:
                        self._send_frame(conn, frame)
                    except (OSError, BrokenPipeError, ValueError) as e:
                        raise ChannelClosed(
                            f"connection lost on {endpoint}: {e}"
                        ) from e
                pending.append((endpoint, conn, frame, first, last, rng))
            for endpoint, conn, frame, first, last, rng in pending:
                for attempt in range(self.max_sends):
                    if self._await_ack(conn, endpoint, last):
                        for seq in range(first, last + 1):
                            self._on_acked(endpoint, seq)
                        break
                    if self._closed.is_set():
                        raise ChannelClosed(
                            f"transport closed; cannot send on {endpoint}"
                        )
                    self._bump("resends")
                    if self.drop_prob and rng.random() < self.drop_prob:
                        self._bump("dropped")  # simulated wire loss
                    else:
                        try:
                            self._send_frame(conn, frame)
                        except (OSError, BrokenPipeError, ValueError) as e:
                            raise ChannelClosed(
                                f"connection lost on {endpoint}: {e}"
                            ) from e
                else:
                    raise AckTimeout(
                        endpoint, seq=last, attempts=self.max_sends
                    )
        finally:
            for lock in acquired:
                lock.release()

    def _await_ack(self, conn, endpoint: Endpoint, seq: int) -> bool:
        deadline = time.monotonic() + self.ack_timeout
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return False
            try:
                if conn.poll(min(remaining, _POLL_S)):
                    frame = conn.recv()
                    if (
                        isinstance(frame, tuple)
                        and len(frame) == 3
                        and frame[0] == "ack"
                        and tuple(frame[1]) == endpoint
                        and frame[2] == seq
                    ):
                        return True
                    # Stale ack from an earlier resend — keep waiting.
            except (EOFError, OSError) as e:
                if self._closed.is_set():
                    raise ChannelClosed(
                        f"transport closed; cannot send on {endpoint}"
                    ) from e
                raise ChannelClosed(
                    f"connection lost awaiting ack on {endpoint}: {e}"
                ) from e

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        if self._closed.is_set():
            return
        self._closed.set()
        for listener in self._listeners.values():
            try:
                listener.close()
            except OSError:
                pass
        for conn in list(self._conns.values()) + list(self._server_conns):
            try:
                conn.close()
            except OSError:
                pass
        with self._inbox_lock:
            for box in self._inboxes.values():
                box.close()
        for th in self._threads:
            th.join(0.2)

    def stats(self) -> dict[str, Any]:
        with self._stats_lock:
            return dict(self._stats, serving=list(self._serve))

    @classmethod
    def conformance(
        cls,
        tmp_path: str,
        locations: Iterable[str],
        *,
        loss: float = 0.0,
        ack_loss: float = 0.0,
        seed: int = 0,
    ) -> "SocketTransport":
        return cls(
            socket_addresses(locations, base_dir=tmp_path),
            serve=locations,
            ack_timeout=0.1,
            connect_timeout=5.0,
            drop_prob=loss,
            drop_ack_prob=ack_loss,
            seed=seed,
        )


# ---------------------------------------------------------------------------
# Shared-memory transport — zero-copy array payloads over the socket plane
# ---------------------------------------------------------------------------


def shm_namespace(authkey: bytes) -> str:
    """Segment-name prefix for one transport fleet.

    Derived from the fleet's ``authkey`` so every worker of one attempt —
    and the coordinator that tears the attempt down — agrees on the prefix
    without an extra configuration channel.  Crash cleanup is a glob over
    this prefix (:meth:`SharedMemoryTransport.sweep`).
    """
    return "swirl-" + hashlib.blake2s(bytes(authkey), digest_size=5).hexdigest()


def _untrack_segment(shm) -> None:
    """Withdraw a segment from ``multiprocessing.resource_tracker``.

    The stdlib registers every created *and* attached segment and its
    tracker both warns about and force-unlinks whatever is still
    registered at shutdown — unacceptable for segments whose ownership
    crosses processes (the sender creates, the receiver may outlive the
    name).  Unregistering immediately after the stdlib's register keeps
    the tracker's pipe balanced (adjacent add/remove pairs are safe
    whether the tracker is shared via fork or per-process via spawn) and
    leaves reclamation entirely to the transport protocol.
    """
    try:
        from multiprocessing import resource_tracker

        resource_tracker.unregister(shm._name, "shared_memory")
    except Exception:
        pass


def _unlink_segment_name(name: str) -> None:
    """Remove a segment's name (POSIX ``shm_unlink``); mappings survive."""
    try:
        import _posixshmem

        _posixshmem.shm_unlink("/" + name)
    except FileNotFoundError:
        pass
    except ImportError:  # non-POSIX: fall back to the stdlib path
        from multiprocessing import shared_memory

        try:
            seg = shared_memory.SharedMemory(name=name)
        except FileNotFoundError:
            return
        _untrack_segment(seg)
        seg.close()
        try:
            seg.unlink()
        except FileNotFoundError:
            pass


def _close_mapping(shm) -> None:
    """Finalizer target: drop one received segment mapping."""
    try:
        shm.close()
    except BufferError:  # a stray export still alive — freed with process
        pass


class _SegmentRef:
    """Wire header standing in for an array payload: where + what shape.

    Pickles to a few dozen bytes regardless of payload size — the whole
    point: the receiver maps ``name`` (once per arena) and views the
    bytes at ``offset`` instead of deserialising the body.
    """

    __slots__ = ("name", "offset", "dtype", "shape", "nbytes")

    def __init__(
        self, name: str, offset: int, dtype: str, shape: tuple, nbytes: int
    ):
        self.name = name
        self.offset = offset
        self.dtype = dtype
        self.shape = shape
        self.nbytes = nbytes

    def __reduce__(self):
        return (
            _SegmentRef,
            (self.name, self.offset, self.dtype, self.shape, self.nbytes),
        )


class _Arena:
    """One sender-owned shared-memory slab, bump-allocated per payload."""

    __slots__ = ("seg", "offset", "live", "gen")

    def __init__(self, seg):
        self.seg = seg
        self.offset = 0
        self.live = 0  # payloads written here whose receiver view is alive
        self.gen = 0  # bumped on rewind: invalidates broadcast-dedup refs


class SharedMemoryTransport(SocketTransport):
    """Zero-copy IPC: socket control plane, shared-memory data plane.

    Array payloads of at least ``min_frame_bytes`` are written into a
    pooled POSIX shared-memory slab (``multiprocessing.shared_memory``);
    the wire then carries only a :class:`_SegmentRef` header (segment
    name, dtype, shape).  The receiver maps the segment — once per
    segment, cached — and delivers an ndarray *view* over the mapping: no
    pickle of the body, no receive-side copy, no per-message mmap.
    Everything else (acks, resend, dedup, fault injection) is inherited
    from :class:`SocketTransport`, so the reliability contract and the
    conformance suite carry over unchanged.

    The arena allocator with refcounted reclamation is what makes this
    fast: creating and mapping a fresh segment per message costs as much
    in page faults as pickling the payload would (~0.5 ms for 512 KiB).
    Instead:

    * payloads are bump-allocated at 64-byte-aligned offsets inside big
      (``arena_bytes``, default 8 MiB) segments, so segment creation and
      the receiver's ``mmap`` are paid once per *arena*, not per message
      — and a background thread pre-creates and pre-faults the next
      arena (``os.pwrite`` into the tmpfs backing file, GIL released)
      while the sender is blocked in ack waits, keeping cold page faults
      off the critical path entirely.
    * the receiver maps each arena on first sight and caches the
      mapping; the delivered view carries a ``weakref.finalize`` that
      fires when the last reference to the payload dies and sends a tiny
      ``("rel", name)`` frame back over the control plane — the refcount
      drop that lets the sender rewind the arena once every payload in
      it has been consumed.  A receiver that *retains* payloads (the
      normal case: data scopes hold them for the program's lifetime)
      simply keeps arenas pinned — the sender rolls on to fresh
      pre-faulted arenas at the same per-message cost.
    * the sender drains release frames while it waits for acks (and
      opportunistically before each send), recycling arenas without any
      extra round trip.
    * ``close`` unlinks every arena this transport created (current,
      spare, pinned, or free) and drops cached receive mappings;
      :meth:`sweep` lets a coordinator bulk-remove a crashed fleet's
      segments by namespace prefix.

    Non-array payloads (and tiny arrays, where the header round trip
    costs more than pickling) spill to the inherited pickle-5 path
    untouched.
    """

    name = "shm"
    crosses_processes = True

    def __init__(
        self,
        addresses: Mapping[str, Any],
        *,
        serve: Iterable[str] = (),
        authkey: bytes = b"swirl-transport",
        ack_timeout: float = 1.0,
        max_sends: int = 20,
        connect_timeout: float = 15.0,
        drop_prob: float = 0.0,
        drop_ack_prob: float = 0.0,
        seed: int = 0,
        min_frame_bytes: int = 1024,
        arena_bytes: int = 1 << 23,
        namespace: str | None = None,
    ):
        # Everything the reader threads touch must exist before
        # super().__init__ binds listeners (a peer can connect — and a
        # reader can start decoding — before this constructor returns).
        self.min_frame_bytes = int(min_frame_bytes)
        self.arena_bytes = int(arena_bytes)
        self.namespace = namespace or shm_namespace(authkey)
        self._segment_ids = itertools.count()
        self._seg_lock = threading.Lock()
        #: The arena currently being filled by sends.
        self._arena: _Arena | None = None
        #: Every arena this transport created: segment name -> _Arena.
        self._arenas: dict[str, _Arena] = {}
        #: Drained arenas (live == 0, rewound) ready for reuse.
        self._free_arenas: deque = deque()
        #: Pre-created, pre-faulted arenas maintained by the prefault
        #: thread.  Depth 2: one arena is consumed in ~the time one is
        #: prefaulted, so a single spare is chronically late.
        self._spare_arenas: deque = deque()
        self._spare_target = 2
        self._spare_evt = threading.Event()
        self._spare_thread: threading.Thread | None = None
        #: Broadcast dedup: id(array) -> (weakref, arena, gen, ref).  A
        #: fan-out resend of the *same array object* reuses the already
        #: written segment bytes — header-only repeat sends.
        self._payload_cache: dict[int, tuple] = {}
        #: Receiver-side mapping cache: segment name -> SharedMemory.
        self._attach_cache: dict[str, Any] = {}
        #: Consumed-payload names queued per connection, flushed onto the
        #: next outgoing ack (releases fire from whichever thread drops
        #: the last delivered view — they must not write to the socket).
        self._rel_lock = threading.Lock()
        self._pending_rels: dict[int, list[str]] = {}
        #: Per-reader-thread connection, so _decode_payload can route
        #: release frames back to the sender that owns the arena.
        self._reader_state = threading.local()
        super().__init__(
            addresses,
            serve=serve,
            authkey=authkey,
            ack_timeout=ack_timeout,
            max_sends=max_sends,
            connect_timeout=connect_timeout,
            drop_prob=drop_prob,
            drop_ack_prob=drop_ack_prob,
            seed=seed,
        )
        with self._stats_lock:
            self._stats.setdefault("segments_created", 0)
            self._stats.setdefault("segments_reused", 0)
            self._stats.setdefault("segments_released", 0)
            self._stats.setdefault("mapped_recvs", 0)
            self._stats.setdefault("spilled_sends", 0)
            self._stats.setdefault("dedup_sends", 0)

    # -- arena allocator -----------------------------------------------------

    def _create_arena(self, size: int, *, prefault: bool = False) -> _Arena:
        from multiprocessing import shared_memory

        name = f"{self.namespace}-{os.getpid()}-{next(self._segment_ids)}"
        seg = shared_memory.SharedMemory(name=name, create=True, size=size)
        _untrack_segment(seg)
        if prefault:
            self._prefault(seg)
        self._bump("segments_created")
        return _Arena(seg)

    @staticmethod
    def _prefault(seg) -> None:
        """Touch every page so first payload writes find them warm.

        One byte stored per page is enough: the fault allocates the page
        (the kernel zeroes it — no explicit memset needed) and installs
        the page-table entry in *this* mapping, which is what makes the
        later payload write ~7x faster.  Chunked with a yield between
        chunks because a CPython thread that never blocks only
        surrenders the GIL every switch interval (5 ms default), and a
        5 ms stall on a sender's ack path would dwarf the whole message
        cost.
        """
        page, chunk = 4096, 1 << 17
        try:
            mem = np.frombuffer(seg.buf, dtype=np.uint8)
        except (ValueError, TypeError):
            return  # exotic mapping; first writes fault instead
        for off in range(0, seg.size, chunk):
            mem[off : off + chunk : page] = 0
            time.sleep(0)
        del mem

    def _spawn_prefault(self) -> None:
        """Start the standing prefault thread (idempotent)."""
        if self._spare_thread is not None or self._closed.is_set():
            return
        with self._seg_lock:
            if self._spare_thread is not None:
                return
            th = self._spare_thread = threading.Thread(
                target=self._prefault_loop, name="swirl-shm-prefault",
                daemon=True,
            )
        self._spare_evt.set()
        th.start()

    def _prefault_loop(self) -> None:
        """Keep ``_spare_target`` pre-faulted arenas ready off the
        critical path; senders that outrun the recycle stream (receivers
        retaining payloads — the common case) roll onto these instead of
        paying ~0.9 ms of page faults inline per 512 KiB payload."""
        while not self._closed.is_set():
            with self._seg_lock:
                sated = (
                    len(self._spare_arenas) >= self._spare_target
                    or bool(self._free_arenas)
                )
            if sated:
                self._spare_evt.clear()
                self._spare_evt.wait(0.5)
                continue
            try:
                arena = self._create_arena(self.arena_bytes, prefault=True)
            except Exception:
                return  # /dev/shm exhausted or gone: senders fault inline
            with self._seg_lock:
                if self._closed.is_set():
                    stale = arena
                else:
                    self._arenas[arena.seg.name] = arena
                    self._spare_arenas.append(arena)
                    stale = None
            if stale is not None:
                _close_mapping(stale.seg)
                _unlink_segment_name(stale.seg.name)
                return

    def _take_arena(self, need: int) -> _Arena:
        """Current arena lacks ``need`` bytes — roll to the next one.

        Preference order: a drained recycled arena (its pages are warm
        from the last pass), then a pre-faulted spare, then (pool miss —
        pays the faults inline) a fresh one.  Oversize payloads get a
        dedicated arena of their own size.  Callers hold ``_seg_lock``.
        """
        if need > self.arena_bytes:
            arena = self._create_arena(need)
            self._arenas[arena.seg.name] = arena
            return arena
        if self._free_arenas:
            arena = self._free_arenas.popleft()
            self._bump("segments_reused")
            return arena
        if self._spare_arenas:
            arena = self._spare_arenas.popleft()
            self._spare_evt.set()
            return arena
        arena = self._create_arena(self.arena_bytes)
        self._arenas[arena.seg.name] = arena
        return arena

    def _release_payload(self, name: str) -> None:
        """A ``("rel", name)`` frame arrived: one delivered view into
        arena ``name`` died.  When the arena's last live payload goes,
        rewind it — every byte is reusable again."""
        recycled = False
        with self._seg_lock:
            arena = self._arenas.get(name)
            if arena is None:
                return  # already reclaimed by close()
            arena.live -= 1
            if arena.live <= 0:
                arena.live = 0
                arena.offset = 0
                arena.gen += 1  # stored bytes are no longer addressable
                if arena is not self._arena:
                    self._free_arenas.append(arena)
                recycled = True
        if recycled:
            self._bump("segments_released")

    def _handle_control(self, frame: Any) -> bool:
        """Process the release content of a control frame.

        Returns True for pure ``("rel", name)`` frames (fully consumed);
        releases piggybacked on a 4-tuple ack are processed here too, but
        the ack itself is left for the caller to match.
        """
        if isinstance(frame, tuple):
            if len(frame) == 2 and frame[0] == "rel":
                self._release_payload(frame[1])
                return True
            if len(frame) == 4 and frame[0] == "ack":
                for name in frame[3]:
                    self._release_payload(name)
        return False

    def _drain_control(self, conn) -> None:
        """Consume queued release/stale-ack frames outside an ack wait."""
        try:
            while conn.poll(0):
                self._handle_control(conn.recv())
        except (EOFError, OSError):
            pass

    # -- payload hooks -------------------------------------------------------

    def _encode_payload(self, endpoint: Endpoint, seq: int, payload: Any):
        if (
            not isinstance(payload, np.ndarray)
            or payload.nbytes < self.min_frame_bytes
            or payload.dtype.hasobject
        ):
            self._bump("spilled_sends")
            return payload
        self._drain_control(self._conns[endpoint])
        # Fan-out dedup: the same array object sent again (a broadcast to
        # another location) reuses its segment bytes — one copy total, a
        # header-only frame per extra destination.  Callers must treat
        # payloads as frozen once handed to the transport (the resend
        # loop already requires this); dedup extends that window until
        # the last recipient has consumed the payload.
        key = id(payload)
        with self._seg_lock:
            hit = self._payload_cache.get(key)
            if hit is not None:
                wref, arena, gen, ref = hit
                if (
                    wref() is payload
                    and self._arenas.get(ref.name) is arena
                    and arena.gen == gen
                ):
                    arena.live += 1
                    hit = ref
                else:
                    del self._payload_cache[key]
                    hit = None
        if hit is not None:
            self._bump("dedup_sends")
            return hit
        arr = np.ascontiguousarray(payload)
        need = max((arr.nbytes + 63) & ~63, 64)  # 64-byte aligned slots
        with self._seg_lock:
            arena = self._arena
            if arena is None or arena.offset + need > arena.seg.size:
                arena = self._arena = self._take_arena(need)
            off = arena.offset
            arena.offset += need
            arena.live += 1
            want_spare = (
                len(self._spare_arenas) < self._spare_target
                and not self._free_arenas
            )
        if want_spare:
            if self._spare_thread is None:
                self._spawn_prefault()
            else:
                self._spare_evt.set()
        dst = np.ndarray(
            arr.shape, dtype=arr.dtype, buffer=arena.seg.buf, offset=off
        )
        dst[...] = arr  # the one copy on the whole path
        del dst
        ref = _SegmentRef(
            arena.seg.name, off, arr.dtype.str, arr.shape, arr.nbytes
        )
        try:
            wref = weakref.ref(payload)
        except TypeError:
            return ref  # subclass without weakref support: no dedup
        with self._seg_lock:
            if len(self._payload_cache) > 512:
                for k in [
                    k
                    for k, (w, *_rest) in self._payload_cache.items()
                    if w() is None
                ]:
                    del self._payload_cache[k]
            self._payload_cache[key] = (wref, arena, arena.gen, ref)
        return ref

    def _queue_release(self, conn, name: str) -> None:
        """Finalizer target: mark one delivered payload as consumed.

        No socket I/O here — finalizers run on whichever thread drops the
        last view, and a per-message control write from the consumer
        thread stalls the reader (measured: it triples the ack round
        trip).  The name is queued and rides out on the next ack the
        reader sends over the same connection (:meth:`_ack_frame`).
        """
        if self._closed.is_set():
            return
        with self._rel_lock:
            self._pending_rels.setdefault(id(conn), []).append(name)

    def _ack_frame(self, conn, endpoint: Endpoint, seq: int) -> tuple:
        with self._rel_lock:
            rels = self._pending_rels.pop(id(conn), None)
        if rels:
            return ("ack", endpoint, seq, tuple(rels))
        return ("ack", endpoint, seq)

    def _decode_payload(self, endpoint: Endpoint, payload: Any) -> Any:
        if type(payload) is not _SegmentRef:
            return payload
        conn = self._reader_state.conn
        with self._seg_lock:
            seg = self._attach_cache.get(payload.name)
        if seg is None:
            from multiprocessing import shared_memory

            seg = shared_memory.SharedMemory(name=payload.name)
            _untrack_segment(seg)
            with self._seg_lock:
                seg = self._attach_cache.setdefault(payload.name, seg)
        arr = np.ndarray(
            payload.shape,
            dtype=np.dtype(payload.dtype),
            buffer=seg.buf,
            offset=payload.offset,
        )
        # Refcounted reclamation: when the last reference to the payload
        # (or any derived view) dies, tell the sender one more payload of
        # its arena has been consumed.
        weakref.finalize(arr, self._queue_release, conn, payload.name)
        self._bump("mapped_recvs")
        return arr

    # -- control-plane overrides ---------------------------------------------

    def _reader(self, conn) -> None:
        self._reader_state.conn = conn
        super()._reader(conn)

    def _await_ack(self, conn, endpoint: Endpoint, seq: int) -> bool:
        deadline = time.monotonic() + self.ack_timeout
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return False
            try:
                if conn.poll(min(remaining, _POLL_S)):
                    frame = conn.recv()
                    if self._handle_control(frame):
                        continue
                    if (
                        isinstance(frame, tuple)
                        and len(frame) in (3, 4)
                        and frame[0] == "ack"
                        and tuple(frame[1]) == endpoint
                        and frame[2] == seq
                    ):
                        return True
                    # Stale ack from an earlier resend — keep waiting.
            except (EOFError, OSError) as e:
                if self._closed.is_set():
                    raise ChannelClosed(
                        f"transport closed; cannot send on {endpoint}"
                    ) from e
                raise ChannelClosed(
                    f"connection lost awaiting ack on {endpoint}: {e}"
                ) from e

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        first = not self._closed.is_set()
        super().close()
        if not first:
            return
        self._spare_evt.set()  # unblock the prefault thread so it exits
        with self._seg_lock:
            own = [arena.seg for arena in self._arenas.values()]
            self._arenas.clear()
            self._free_arenas.clear()
            self._spare_arenas.clear()
            self._arena = None
            attached = dict(self._attach_cache)
        for seg in own:
            try:
                seg.close()
            except BufferError:
                pass
            _unlink_segment_name(seg.name)
        for name, seg in attached.items():
            # Drop mappings whose delivered views are all dead; a mapping
            # with live views stays cached (and valid — only the *name*
            # was the sender's to unlink) until the views are collected.
            try:
                seg.close()
            except BufferError:
                continue
            with self._seg_lock:
                self._attach_cache.pop(name, None)

    @classmethod
    def sweep(cls, authkey: bytes) -> int:
        """Crash teardown: unlink every leftover segment of one fleet.

        A worker killed mid-send cannot run its own cleanup; the
        coordinator knows the fleet's ``authkey`` and removes whatever the
        namespace glob still finds.  Returns the number of segments
        removed.  No-op where ``/dev/shm`` does not exist (non-Linux) —
        there the per-process ``close`` paths are the only cleanup.
        """
        prefix = shm_namespace(authkey)
        removed = 0
        for path in glob.glob(f"/dev/shm/{prefix}-*"):
            try:
                os.unlink(path)
                removed += 1
            except OSError:
                pass
        return removed

    @classmethod
    def conformance(
        cls,
        tmp_path: str,
        locations: Iterable[str],
        *,
        loss: float = 0.0,
        ack_loss: float = 0.0,
        seed: int = 0,
    ) -> "SharedMemoryTransport":
        return cls(
            socket_addresses(locations, base_dir=tmp_path),
            serve=locations,
            ack_timeout=0.1,
            connect_timeout=5.0,
            drop_prob=loss,
            drop_ack_prob=ack_loss,
            seed=seed,
            min_frame_bytes=64,  # exercise the segment path on small arrays
        )


# ---------------------------------------------------------------------------
# Hybrid transport — in-process hops for co-resident locations
# ---------------------------------------------------------------------------


class HybridTransport(Transport):
    """Route co-resident endpoints in memory, the rest over another wire.

    When several locations share one process (the multiprocess backend's
    schedule pinning / ``workers=`` packing), an endpoint whose ``src`` and
    ``dst`` are both local has no reason to pay pickling + socket loopback:
    it goes through ``local`` (an :class:`InMemoryTransport` by default)
    while every cross-process endpoint uses ``remote``.  This is what makes
    the cost model's "cheap intra-rack links" literal: pinned locations
    talk at memory speed.

    Not in the named-transport registry — it is a per-process composite
    built around an already-configured remote transport, not a wire you
    select by name.
    """

    name = "hybrid"
    crosses_processes = False

    def __init__(
        self,
        remote: Transport,
        local_locations,
        *,
        local: Transport | None = None,
    ):
        self.remote = remote
        self.local = local or InMemoryTransport()
        self._local_locs = frozenset(local_locations)

    def _pick(self, endpoint: Endpoint) -> Transport:
        src, dst, _ = endpoint
        if src in self._local_locs and dst in self._local_locs:
            return self.local
        return self.remote

    def open(self, endpoint: Endpoint) -> None:
        self._pick(endpoint).open(endpoint)

    def send(self, endpoint: Endpoint, data_name: str, payload: Any) -> None:
        self._pick(endpoint).send(endpoint, data_name, payload)

    def send_many(
        self, endpoint: Endpoint, items: "Iterable[tuple[str, Any]]"
    ) -> None:
        self._pick(endpoint).send_many(endpoint, items)

    def scatter(
        self,
        sends: "Iterable[tuple[Endpoint, Iterable[tuple[str, Any]]]]",
    ) -> None:
        by_transport: dict[int, tuple[Transport, list]] = {}
        for endpoint, items in sends:
            t = self._pick(endpoint)
            by_transport.setdefault(id(t), (t, []))[1].append(
                (endpoint, items)
            )
        for t, group in by_transport.values():
            t.scatter(group)

    def recv(
        self, endpoint: Endpoint, timeout: float | None = None
    ) -> Message:
        return self._pick(endpoint).recv(endpoint, timeout)

    def close(self) -> None:
        self.local.close()
        self.remote.close()

    def stats(self) -> dict[str, Any]:
        return {
            "local": self.local.stats(),
            "remote": self.remote.stats(),
            "local_locations": sorted(self._local_locs),
        }


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

TRANSPORTS: dict[str, type[Transport]] = {}


def register_transport(
    name: str, cls: type[Transport], *, overwrite: bool = False
) -> None:
    """Make ``cls`` selectable by name (backend ``transport=`` options)."""
    if not overwrite and name in TRANSPORTS:
        raise ValueError(f"transport {name!r} is already registered")
    TRANSPORTS[name] = cls


def get_transport(name: str) -> type[Transport]:
    try:
        return TRANSPORTS[name]
    except KeyError:
        raise KeyError(
            f"unknown transport {name!r}; available: {sorted(TRANSPORTS)}"
        ) from None


register_transport("memory", InMemoryTransport)
register_transport("socket", SocketTransport)
register_transport("shm", SharedMemoryTransport)
