"""Pluggable COMM transports — the wire under every decentralised backend.

A :class:`Transport` carries SWIRL COMM messages between locations, one
logically independent FIFO per ``(src, dst, port)`` endpoint.  The threaded
backend and the multiprocess backend both speak this interface; the only
difference between "threads over queues" and "processes over sockets" is
which transport the runtime is handed.

Contract (enforced by ``tests/test_transport.py`` against every registered
implementation):

* :meth:`Transport.send` blocks until the transport has durably accepted the
  message and returns exactly once per logical message.  Unreliable wires
  retransmit (at-least-once) and the receiving side deduplicates by sequence
  number, so the *effect* is exactly-once — sound because SWIRL data
  elements are immutable and COMM copies rather than consumes.
* Messages on one endpoint are delivered in send order; distinct endpoints
  never leak into each other.
* :meth:`Transport.recv` with a timeout raises :class:`TimeoutError`; a
  blocked ``recv`` (and any later one) raises :class:`ChannelClosed` once
  the transport is closed, after draining already-delivered messages.
* :meth:`Transport.close` is idempotent and unblocks every waiter.

Two implementations ship in-tree:

==========  ==============================================================
``memory``  :class:`InMemoryTransport` — the historical in-process queues
            (:class:`~repro.workflow.channels.ChannelRegistry`) behind the
            interface; what the ``threaded`` backend uses.
``socket``  :class:`SocketTransport` — ``multiprocessing.connection``
            sockets (AF_UNIX, TCP fallback) with pickle payload framing,
            per-message acks, and resend on ack timeout; what the
            ``multiprocess`` backend uses across OS processes.
==========  ==============================================================

Third-party transports join through :func:`register_transport` and get the
conformance suite for free by implementing :meth:`Transport.conformance`.
"""

from __future__ import annotations

import os
import socket as _socket
import tempfile
import threading
import time
from abc import ABC, abstractmethod
from collections import deque
from typing import Any, Iterable, Mapping

from .channels import (
    Channel,
    ChannelClosed,
    ChannelRegistry,
    Endpoint,
    Message,
    endpoint_rng,
)

__all__ = [
    "AckTimeout",
    "Transport",
    "InMemoryTransport",
    "SocketTransport",
    "HybridTransport",
    "ChannelClosed",
    "Message",
    "TRANSPORTS",
    "register_transport",
    "get_transport",
    "socket_addresses",
]

#: Poll interval for interruptible blocking waits.
_POLL_S = 0.05

#: AF_UNIX socket paths are limited to ~108 bytes; stay well under it.
_MAX_UNIX_PATH = 90


class AckTimeout(ChannelClosed):
    """A send exhausted its resend budget without ever seeing an ack.

    Distinct from a peer-initiated close (a bare :class:`ChannelClosed`):
    the peer may still be alive but silent — callers deciding between
    "peer is gone" and "peer is straggling" branch on this type.  Carries
    the failing ``endpoint``, the message ``seq`` and how many ``attempts``
    were made (each attempt = one send + one ack wait).
    """

    def __init__(self, endpoint: Endpoint, *, seq: int, attempts: int):
        super().__init__(
            f"no ack after {attempts} sends on {tuple(endpoint)} "
            f"(seq {seq})"
        )
        self.endpoint = tuple(endpoint)
        self.seq = seq
        self.attempts = attempts


class Transport(ABC):
    """One reliable, per-endpoint-ordered message fabric."""

    #: Registry name (set on subclasses).
    name: str = "abstract"
    #: Whether endpoints of this transport can span OS processes.  The
    #: multiprocess backend refuses transports that cannot.
    crosses_processes: bool = False

    def open(self, endpoint: Endpoint) -> None:
        """Declare an endpoint before use (optional; default no-op)."""

    @abstractmethod
    def send(self, endpoint: Endpoint, data_name: str, payload: Any) -> None:
        """Deliver one message; blocks until accepted, exactly once."""

    @abstractmethod
    def recv(
        self, endpoint: Endpoint, timeout: float | None = None
    ) -> Message:
        """Next message on ``endpoint`` (FIFO); TimeoutError on timeout."""

    def close(self) -> None:
        """Tear down; idempotent, wakes blocked receivers (ChannelClosed)."""

    def stats(self) -> dict[str, Any]:
        return {}

    @classmethod
    def conformance(
        cls,
        tmp_path: str,
        locations: Iterable[str],
        *,
        loss: float = 0.0,
        ack_loss: float = 0.0,
        seed: int = 0,
    ) -> "Transport":
        """Build an instance for the conformance suite.

        Must return a transport able to both send and receive between the
        given ``locations`` within one process, with ``loss``/``ack_loss``
        injected unreliability (ignore what does not apply).  Implementing
        this is what opts a registered transport into
        ``tests/test_transport.py``.
        """
        raise NotImplementedError


# ---------------------------------------------------------------------------
# In-memory transport — the refactored historical queues
# ---------------------------------------------------------------------------


class InMemoryTransport(Transport):
    """The in-process channel queues behind the :class:`Transport` API.

    Wraps a :class:`~repro.workflow.channels.ChannelRegistry`; behaviour is
    exactly the pre-transport ``threaded`` backend's (including per-endpoint
    fault injection via the registry's ``drop_prob``/``delay_s``/``seed``).
    """

    name = "memory"
    crosses_processes = False

    def __init__(
        self, registry: ChannelRegistry | None = None, **channel_kwargs: Any
    ):
        if registry is not None and channel_kwargs:
            raise TypeError(
                "pass either registry= or per-channel options "
                f"({sorted(channel_kwargs)}), not both"
            )
        self.registry = registry or ChannelRegistry(**channel_kwargs)

    def open(self, endpoint: Endpoint) -> None:
        self.registry.channel(*endpoint)

    def send(self, endpoint: Endpoint, data_name: str, payload: Any) -> None:
        self.registry.channel(*endpoint).put_reliable(data_name, payload)

    def recv(
        self, endpoint: Endpoint, timeout: float | None = None
    ) -> Message:
        return self.registry.channel(*endpoint).get(timeout)

    def close(self) -> None:
        self.registry.close()

    def stats(self) -> dict[str, Any]:
        return self.registry.stats()

    @classmethod
    def conformance(
        cls,
        tmp_path: str,
        locations: Iterable[str],
        *,
        loss: float = 0.0,
        ack_loss: float = 0.0,
        seed: int = 0,
    ) -> "InMemoryTransport":
        # The queue transport has no separate ack channel: a lost ack and a
        # lost message are both "the transport did not accept it", retried
        # by put_reliable.
        return cls(drop_prob=max(loss, ack_loss), seed=seed)


# ---------------------------------------------------------------------------
# Socket transport — multiprocessing.connection with acks + resend
# ---------------------------------------------------------------------------


class _Inbox:
    """Per-endpoint delivery queue with close-aware blocking get."""

    __slots__ = ("_items", "_cond", "_closed")

    def __init__(self) -> None:
        self._items: deque[Message] = deque()
        self._cond = threading.Condition()
        self._closed = False

    def put(self, msg: Message) -> None:
        with self._cond:
            self._items.append(msg)
            self._cond.notify()

    def get(self, timeout: float | None, endpoint: Endpoint) -> Message:
        with self._cond:
            ok = self._cond.wait_for(
                lambda: self._items or self._closed, timeout
            )
            if self._items:
                return self._items.popleft()
            if self._closed:
                raise ChannelClosed(
                    f"transport closed while receiving on {endpoint}"
                )
            assert not ok
            raise TimeoutError(f"recv timed out on {endpoint}")

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()


def socket_addresses(
    locations: Iterable[str],
    *,
    base_dir: str | os.PathLike | None = None,
    family: str | None = None,
) -> dict[str, Any]:
    """Assign one listener address per location, upfront.

    AF_UNIX paths under ``base_dir`` (or a fresh temp dir) where available —
    no port collisions, cleaned up with the directory; ``127.0.0.1``
    ephemeral ports otherwise.  Addresses are allocated *before* any worker
    starts so every process gets the same address book.
    """
    locs = sorted(set(locations))
    if family is None:
        family = "AF_UNIX" if hasattr(_socket, "AF_UNIX") else "AF_INET"
    if family == "AF_UNIX":
        if base_dir is not None:
            base = os.fspath(base_dir)
            os.makedirs(base, exist_ok=True)
        else:
            base = tempfile.mkdtemp(prefix="swirl-net-")
        paths = {
            loc: os.path.join(base, f"{i}.sock") for i, loc in enumerate(locs)
        }
        if all(len(p) <= _MAX_UNIX_PATH for p in paths.values()):
            return paths
        family = "AF_INET"  # path too long for sockaddr_un — fall back
    addrs: dict[str, Any] = {}
    for loc in locs:
        s = _socket.socket(_socket.AF_INET, _socket.SOCK_STREAM)
        s.bind(("127.0.0.1", 0))
        addrs[loc] = ("127.0.0.1", s.getsockname()[1])
        s.close()
    return addrs


class SocketTransport(Transport):
    """COMM over ``multiprocessing.connection`` sockets, ack + resend.

    Every location in ``serve`` gets a listener at ``addresses[location]``;
    inbound frames are demultiplexed into per-endpoint inboxes by reader
    threads.  ``send`` opens (and caches) one client connection per endpoint,
    writes a pickled ``("msg", endpoint, seq, name, payload)`` frame, and
    blocks until the matching ``("ack", endpoint, seq)`` arrives — resending
    after ``ack_timeout``, up to ``max_sends`` times (at-least-once).  The
    receiving side acks every copy but delivers each sequence number once
    (idempotent receive), so a lost ack never duplicates a message.

    ``drop_prob`` (sender swallows the frame) and ``drop_ack_prob``
    (receiver swallows the ack) inject wire faults for the conformance and
    fault-tolerance tests, seeded per endpoint like the channel registry.
    """

    name = "socket"
    crosses_processes = True

    def __init__(
        self,
        addresses: Mapping[str, Any],
        *,
        serve: Iterable[str] = (),
        authkey: bytes = b"swirl-transport",
        ack_timeout: float = 1.0,
        max_sends: int = 20,
        connect_timeout: float = 15.0,
        drop_prob: float = 0.0,
        drop_ack_prob: float = 0.0,
        seed: int = 0,
    ):
        from multiprocessing.connection import Listener

        self._addresses = dict(addresses)
        self._serve = tuple(sorted(set(serve)))
        unknown = [l for l in self._serve if l not in self._addresses]
        if unknown:
            raise KeyError(f"serve locations without addresses: {unknown}")
        self._authkey = bytes(authkey)
        self.ack_timeout = float(ack_timeout)
        self.max_sends = int(max_sends)
        self.connect_timeout = float(connect_timeout)
        self.drop_prob = float(drop_prob)
        self.drop_ack_prob = float(drop_ack_prob)
        self._seed = int(seed)

        self._closed = threading.Event()
        self._inboxes: dict[Endpoint, _Inbox] = {}
        self._inbox_lock = threading.Lock()
        self._delivered: dict[Endpoint, int] = {}
        self._deliver_lock = threading.Lock()
        self._conns: dict[Endpoint, Any] = {}
        self._send_locks: dict[Endpoint, threading.Lock] = {}
        self._seq: dict[Endpoint, int] = {}
        self._drop_rngs: dict[Endpoint, Any] = {}
        self._ack_rngs: dict[Endpoint, Any] = {}
        self._server_conns: list[Any] = []
        self._threads: list[threading.Thread] = []
        # Counters are bumped from reader threads and concurrent senders —
        # serialise the read-modify-write or increments get lost.
        self._stats_lock = threading.Lock()
        self._stats = {
            "sent": 0,
            "delivered": 0,
            "duplicates": 0,
            "resends": 0,
            "dropped": 0,
            "acks_dropped": 0,
        }
        self._listeners = {}
        for loc in self._serve:
            listener = Listener(self._addresses[loc], authkey=self._authkey)
            self._listeners[loc] = listener
            th = threading.Thread(
                target=self._accept_loop,
                args=(listener,),
                name=f"swirl-accept-{loc}",
                daemon=True,
            )
            th.start()
            self._threads.append(th)

    def _bump(self, key: str) -> None:
        with self._stats_lock:
            self._stats[key] += 1

    # -- receive path --------------------------------------------------------

    def _inbox(self, endpoint: Endpoint) -> _Inbox:
        with self._inbox_lock:
            box = self._inboxes.get(endpoint)
            if box is None:
                box = self._inboxes[endpoint] = _Inbox()
                if self._closed.is_set():
                    box.close()
            return box

    def _accept_loop(self, listener) -> None:
        while not self._closed.is_set():
            try:
                conn = listener.accept()
            except Exception:  # closed listener or failed auth handshake
                if self._closed.is_set():
                    return
                continue
            self._server_conns.append(conn)
            th = threading.Thread(
                target=self._reader, args=(conn,), daemon=True
            )
            th.start()
            self._threads.append(th)

    def _reader(self, conn) -> None:
        while not self._closed.is_set():
            try:
                frame = conn.recv()
            except (EOFError, OSError):
                break
            if not (isinstance(frame, tuple) and frame and frame[0] == "msg"):
                continue
            _, endpoint, seq, name, payload = frame
            endpoint = tuple(endpoint)
            with self._deliver_lock:
                duplicate = seq <= self._delivered.get(endpoint, 0)
                if not duplicate:
                    self._delivered[endpoint] = seq
                # Ack BEFORE the message becomes consumable: once it is in
                # the inbox the receiving worker may finish its program and
                # close this transport, and an ack queued after that close
                # is lost — the sender then dies awaiting it.  Socket
                # buffers survive close, so an ack already on the wire is
                # always readable by the sender.
                if (
                    self.drop_ack_prob
                    and self._rng(self._ack_rngs, endpoint, salt=1).random()
                    < self.drop_ack_prob
                ):
                    self._bump("acks_dropped")
                    acked = True  # simulated loss: keep serving
                else:
                    try:
                        conn.send(("ack", endpoint, seq))
                        acked = True
                    except (EOFError, OSError, BrokenPipeError):
                        acked = False  # sender gone; deliver, then stop
                if not duplicate:
                    # Deliver under the lock so two connections carrying the
                    # same endpoint cannot reorder fresh sequence numbers.
                    self._inbox(endpoint).put(Message(name, payload, seq))
            self._bump("duplicates" if duplicate else "delivered")
            if not acked:
                break

    def recv(
        self, endpoint: Endpoint, timeout: float | None = None
    ) -> Message:
        return self._inbox(tuple(endpoint)).get(timeout, tuple(endpoint))

    # -- send path -----------------------------------------------------------

    def _rng(self, cache: dict, endpoint: Endpoint, *, salt: int = 0):
        rng = cache.get(endpoint)
        if rng is None:
            rng = cache[endpoint] = endpoint_rng(self._seed + salt, endpoint)
        return rng

    def _connect(self, endpoint: Endpoint):
        from multiprocessing.connection import Client

        conn = self._conns.get(endpoint)
        if conn is not None:
            return conn
        dst = endpoint[1]
        try:
            address = self._addresses[dst]
        except KeyError:
            raise KeyError(
                f"no address for destination {dst!r}; "
                f"known: {sorted(self._addresses)}"
            ) from None
        deadline = time.monotonic() + self.connect_timeout
        while True:
            if self._closed.is_set():
                raise ChannelClosed(f"transport closed; cannot reach {dst!r}")
            try:
                conn = Client(address, authkey=self._authkey)
                break
            except (OSError, EOFError) as e:
                # Peer's listener may not be bound yet — retry briefly.
                if time.monotonic() >= deadline:
                    raise ChannelClosed(
                        f"cannot connect to {dst!r} at {address!r}: {e}"
                    ) from e
                time.sleep(0.02)
        self._conns[endpoint] = conn
        return conn

    def send(self, endpoint: Endpoint, data_name: str, payload: Any) -> None:
        endpoint = tuple(endpoint)
        if self._closed.is_set():
            raise ChannelClosed(f"transport closed; cannot send on {endpoint}")
        lock = self._send_locks.setdefault(endpoint, threading.Lock())
        with lock:
            conn = self._connect(endpoint)
            self._seq[endpoint] = seq = self._seq.get(endpoint, 0) + 1
            self._bump("sent")
            rng = self._rng(self._drop_rngs, endpoint)
            for attempt in range(self.max_sends):
                if self._closed.is_set():
                    raise ChannelClosed(
                        f"transport closed; cannot send on {endpoint}"
                    )
                if attempt:
                    self._bump("resends")
                if self.drop_prob and rng.random() < self.drop_prob:
                    self._bump("dropped")  # simulated wire loss
                else:
                    try:
                        conn.send(("msg", endpoint, seq, data_name, payload))
                    except (OSError, BrokenPipeError, ValueError) as e:
                        raise ChannelClosed(
                            f"connection lost on {endpoint}: {e}"
                        ) from e
                if self._await_ack(conn, endpoint, seq):
                    return
            raise AckTimeout(endpoint, seq=seq, attempts=self.max_sends)

    def _await_ack(self, conn, endpoint: Endpoint, seq: int) -> bool:
        deadline = time.monotonic() + self.ack_timeout
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return False
            try:
                if conn.poll(min(remaining, _POLL_S)):
                    frame = conn.recv()
                    if (
                        isinstance(frame, tuple)
                        and len(frame) == 3
                        and frame[0] == "ack"
                        and tuple(frame[1]) == endpoint
                        and frame[2] == seq
                    ):
                        return True
                    # Stale ack from an earlier resend — keep waiting.
            except (EOFError, OSError) as e:
                if self._closed.is_set():
                    raise ChannelClosed(
                        f"transport closed; cannot send on {endpoint}"
                    ) from e
                raise ChannelClosed(
                    f"connection lost awaiting ack on {endpoint}: {e}"
                ) from e

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        if self._closed.is_set():
            return
        self._closed.set()
        for listener in self._listeners.values():
            try:
                listener.close()
            except OSError:
                pass
        for conn in list(self._conns.values()) + list(self._server_conns):
            try:
                conn.close()
            except OSError:
                pass
        with self._inbox_lock:
            for box in self._inboxes.values():
                box.close()
        for th in self._threads:
            th.join(0.2)

    def stats(self) -> dict[str, Any]:
        with self._stats_lock:
            return dict(self._stats, serving=list(self._serve))

    @classmethod
    def conformance(
        cls,
        tmp_path: str,
        locations: Iterable[str],
        *,
        loss: float = 0.0,
        ack_loss: float = 0.0,
        seed: int = 0,
    ) -> "SocketTransport":
        return cls(
            socket_addresses(locations, base_dir=tmp_path),
            serve=locations,
            ack_timeout=0.1,
            connect_timeout=5.0,
            drop_prob=loss,
            drop_ack_prob=ack_loss,
            seed=seed,
        )


# ---------------------------------------------------------------------------
# Hybrid transport — in-process hops for co-resident locations
# ---------------------------------------------------------------------------


class HybridTransport(Transport):
    """Route co-resident endpoints in memory, the rest over another wire.

    When several locations share one process (the multiprocess backend's
    schedule pinning / ``workers=`` packing), an endpoint whose ``src`` and
    ``dst`` are both local has no reason to pay pickling + socket loopback:
    it goes through ``local`` (an :class:`InMemoryTransport` by default)
    while every cross-process endpoint uses ``remote``.  This is what makes
    the cost model's "cheap intra-rack links" literal: pinned locations
    talk at memory speed.

    Not in the named-transport registry — it is a per-process composite
    built around an already-configured remote transport, not a wire you
    select by name.
    """

    name = "hybrid"
    crosses_processes = False

    def __init__(
        self,
        remote: Transport,
        local_locations,
        *,
        local: Transport | None = None,
    ):
        self.remote = remote
        self.local = local or InMemoryTransport()
        self._local_locs = frozenset(local_locations)

    def _pick(self, endpoint: Endpoint) -> Transport:
        src, dst, _ = endpoint
        if src in self._local_locs and dst in self._local_locs:
            return self.local
        return self.remote

    def open(self, endpoint: Endpoint) -> None:
        self._pick(endpoint).open(endpoint)

    def send(self, endpoint: Endpoint, data_name: str, payload: Any) -> None:
        self._pick(endpoint).send(endpoint, data_name, payload)

    def recv(
        self, endpoint: Endpoint, timeout: float | None = None
    ) -> Message:
        return self._pick(endpoint).recv(endpoint, timeout)

    def close(self) -> None:
        self.local.close()
        self.remote.close()

    def stats(self) -> dict[str, Any]:
        return {
            "local": self.local.stats(),
            "remote": self.remote.stats(),
            "local_locations": sorted(self._local_locs),
        }


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

TRANSPORTS: dict[str, type[Transport]] = {}


def register_transport(
    name: str, cls: type[Transport], *, overwrite: bool = False
) -> None:
    """Make ``cls`` selectable by name (backend ``transport=`` options)."""
    if not overwrite and name in TRANSPORTS:
        raise ValueError(f"transport {name!r} is already registered")
    TRANSPORTS[name] = cls


def get_transport(name: str) -> type[Transport]:
    try:
        return TRANSPORTS[name]
    except KeyError:
        raise KeyError(
            f"unknown transport {name!r}; available: {sorted(TRANSPORTS)}"
        ) from None


register_transport("memory", InMemoryTransport)
register_transport("socket", SocketTransport)
