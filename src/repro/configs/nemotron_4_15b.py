"""nemotron-4-15b [dense] — GQA + squared-ReLU MLP.

32L, d_model=6144, 48H (GQA kv=8, head_dim=128), d_ff=24576,
vocab=256000 [arXiv:2402.16819; unverified].  Non-gated squared-ReLU MLP,
LayerNorm, untied embeddings.
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=256000,
    activation="relu_sq",
    norm="layernorm",
)
