"""granite-moe-1b-a400m [moe] — 32 experts, top-8.

24L, d_model=1024, 16H (GQA kv=8, head_dim=64), expert d_ff=512,
vocab=49155, MoE 32e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].
Tied embeddings; every layer is MoE (no dense FFN).
"""

from repro.models import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    pattern=(("attn", "moe"),),
    moe=MoECfg(n_experts=32, top_k=8, d_expert=512),
    tied_embeddings=True,
)
