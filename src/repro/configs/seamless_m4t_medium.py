"""seamless-m4t-medium [audio] — enc-dec multimodal backbone.

12L enc + 12L dec, d_model=1024, 16H (kv=16 ⇒ MHA), d_ff=4096,
vocab=256206 [arXiv:2308.11596; hf].  The speech frontend (w2v-BERT
feature extractor) is a STUB: ``input_specs()`` provides precomputed frame
embeddings of length ``frontend_len``.  Norm/activation choices beyond the
assignment row (LayerNorm + GELU) follow the NLLB-family defaults.
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    is_encoder_decoder=True,
    n_enc_layers=12,
    frontend="audio",
    frontend_len=1024,
    norm="layernorm",
    activation="gelu",
    rope_theta=10_000.0,
    tied_embeddings=True,
)
