"""Architecture registry: ``--arch <id>`` → ModelConfig (+ smoke variants)."""

from __future__ import annotations

from repro.models import ModelConfig, smoke_variant

from . import (
    deepseek_moe_16b,
    gemma2_27b,
    granite_moe_1b_a400m,
    internvl2_1b,
    jamba_v0_1_52b,
    llama3_2_3b,
    nemotron_4_15b,
    qwen1_5_110b,
    seamless_m4t_medium,
    xlstm_125m,
)
from .shapes import SHAPES, SUBQUADRATIC, Shape, cells, shape_applicable

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        seamless_m4t_medium,
        gemma2_27b,
        nemotron_4_15b,
        llama3_2_3b,
        qwen1_5_110b,
        xlstm_125m,
        internvl2_1b,
        granite_moe_1b_a400m,
        deepseek_moe_16b,
        jamba_v0_1_52b,
    )
}


def get_config(arch: str, *, smoke: bool = False) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    cfg = ARCHS[arch]
    return smoke_variant(cfg) if smoke else cfg


__all__ = [
    "ARCHS",
    "get_config",
    "SHAPES",
    "Shape",
    "cells",
    "shape_applicable",
    "SUBQUADRATIC",
]
