"""internvl2-1b [vlm] — InternViT frontend (stub) + Qwen2-0.5B-style LLM.

24L, d_model=896, 14H (GQA kv=2, head_dim=64), d_ff=4864, vocab=151655
[arXiv:2404.16821; hf].  The vision tower is a STUB: ``input_specs()``
provides ``frontend_len`` precomputed patch embeddings, projected and
prepended to the token stream.  QKV bias + tied embeddings follow the
Qwen2 backbone.
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151655,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tied_embeddings=True,
    frontend="vision",
    frontend_len=256,
)
