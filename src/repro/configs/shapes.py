"""Assigned input shapes and the (arch × shape) cell enumeration.

Shape semantics (per the assignment):

* ``train_4k``    — ``train_step``: seq 4 096 × global batch 256;
* ``prefill_32k`` — ``prefill_step``: seq 32 768 × global batch 32;
* ``decode_32k``  — ``serve_step``: ONE new token against a 32 768-row KV
  cache, global batch 128;
* ``long_500k``   — ``serve_step``: one token against 524 288 context,
  batch 1 — run only for sub-quadratic archs (SSM/hybrid); pure
  full-attention archs skip it (see DESIGN.md §Shape policy).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Literal

Kind = Literal["train", "prefill", "decode"]


@dataclass(frozen=True)
class Shape:
    name: str
    kind: Kind
    seq_len: int
    global_batch: int


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}

# Sub-quadratic decode state ⇒ long_500k is runnable.
SUBQUADRATIC = {"xlstm-125m", "jamba-v0.1-52b"}


def shape_applicable(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, "pure full-attention arch: 500k decode is quadratic-cost (assignment: skip)"
    return True, ""


def cells(arch_names: list[str]) -> Iterator[tuple[str, Shape]]:
    """All applicable (arch, shape) cells."""
    for a in arch_names:
        for s in SHAPES.values():
            ok, _ = shape_applicable(a, s.name)
            if ok:
                yield a, s
