"""xlstm-125m [ssm] — alternating mLSTM and sLSTM blocks.

12L, d_model=768, 4H (head_dim=192), d_ff=0 (projections inside blocks),
vocab=50304 [arXiv:2405.04517; unverified].  mLSTM is the chunked
matrix-memory (linear-attention) form; sLSTM is the sequential scalar cell.
Deviation noted in DESIGN.md: sLSTM recurrent weights are full d×d rather
than block-diagonal per head.
"""

from repro.models import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="xlstm-125m",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab=50304,
    pattern=(("mlstm", "none"), ("slstm", "none")),
    ssm=SSMCfg(chunk=512),
)
