"""gemma2-27b [dense] — local+global alternating attention, logit softcaps.

46L, d_model=4608, 32H (GQA kv=16, head_dim=128), d_ff=36864,
vocab=256000 [arXiv:2408.00118; hf].  Sliding window 4096 on local layers,
attn softcap 50, final softcap 30, pre+post RMSNorm, GeGLU, tied
embeddings with sqrt(d) embedding scale.
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    pattern=(("attn_local", "mlp"), ("attn", "mlp")),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_block_norm=True,
    activation="gelu_glu",
    embed_scale=True,
    tied_embeddings=True,
)
