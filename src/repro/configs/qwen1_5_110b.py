"""qwen1.5-110b [dense] — QKV bias.

80L, d_model=8192, 64H (GQA kv=8, head_dim=128), d_ff=49152, vocab=152064
[hf:Qwen/Qwen1.5 family; hf].  SiLU-GLU, RMSNorm, RoPE θ=1e6, QKV bias.
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
