"""deepseek-moe-16b [moe] — fine-grained 64 routed + 2 shared experts.

28L, d_model=2048, 16H (kv=16 ⇒ MHA, head_dim=128), expert d_ff=1408,
vocab=102400, 64 routed top-6 + 2 shared experts; layer 0 is a dense MLP
with d_ff=10944 [arXiv:2401.06066; hf].
"""

from repro.models import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,  # layer-0 dense MLP width
    vocab=102400,
    prefix_pattern=(("attn", "dense0"),),
    pattern=(("attn", "moe"),),
    moe=MoECfg(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
)
