"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

32L, d_model=4096, 32H (GQA kv=8, head_dim=128), d_ff=14336, vocab=65536,
MoE 16 experts top-2 on every other layer; attention on 1 of each 8 layers
(position 4 of the period, per the paper's Jamba block) [arXiv:2403.19887;
hf].  Mamba: d_state=16, d_conv=4, expand=2.  No positional encoding
(use_rope=False) — Mamba layers carry position information.
"""

from repro.models import ModelConfig, MoECfg, SSMCfg

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=65536,
    pattern=(
        ("mamba", "mlp"),
        ("mamba", "moe"),
        ("mamba", "mlp"),
        ("mamba", "moe"),
        ("attn", "mlp"),
        ("mamba", "moe"),
        ("mamba", "mlp"),
        ("mamba", "moe"),
    ),
    moe=MoECfg(n_experts=16, top_k=2, d_expert=14336),
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2, chunk=512),
    use_rope=False,
)
