"""SWIRL reproduction — an intermediate representation for scientific
workflows, grown into a staged, multi-backend compilation toolchain.

The single front door is the staged API (:mod:`repro.swirl`)::

    from repro import swirl

    plan = swirl.trace(edges, mapping=mapping).optimize()
    result = plan.lower("threaded").compile(step_fns).run()

Subpackages are imported lazily so that ``import repro`` stays cheap (the
``jax`` backend, models, and kernels only load when used).
"""

from importlib import import_module

__version__ = "0.1.0"

_SUBMODULES = (
    "api",
    "backends",
    "core",
    "exec",
    "obs",
    "sched",
    "serve",
    "swirl",
    "workflow",
)


def __getattr__(name: str):
    if name in _SUBMODULES:
        return import_module(f"repro.{name}")
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SUBMODULES))
