"""Deprecation plumbing for the legacy (pre-staged-API) entry points.

The staged pipeline (:mod:`repro.api`) is the single front door to the
toolchain; the historical free functions (``SWIRLTranslator.translate``,
``optimize``, ``compile_bundles``) and direct runtime construction keep
working but emit :class:`DeprecationWarning`.  The backends themselves reuse
the same building blocks, so they run under :func:`suppress_deprecations` —
a user going through ``swirl.trace(...).lower(...).compile(...)`` never sees
a warning for machinery the pipeline drives on their behalf.
"""

from __future__ import annotations

import threading
import warnings
from contextlib import contextmanager

_state = threading.local()


def _suppressed() -> bool:
    return getattr(_state, "depth", 0) > 0


@contextmanager
def suppress_deprecations():
    """Mark legacy calls made on behalf of the staged pipeline as internal."""
    _state.depth = getattr(_state, "depth", 0) + 1
    try:
        yield
    finally:
        _state.depth -= 1


def warn_legacy(old: str, new: str, *, stacklevel: int = 3) -> None:
    """Emit the standard deprecation message unless inside the pipeline."""
    if _suppressed():
        return
    warnings.warn(
        f"{old} is deprecated; use the staged API instead: {new}",
        DeprecationWarning,
        stacklevel=stacklevel,
    )
