"""Deterministic sharded token pipeline.

``SyntheticLM`` generates a reproducible pseudo-corpus: token ``t`` of
document ``i`` is a hash-mix of ``(seed, i, t)`` with a Zipf-ish skew, so the
stream is (a) deterministic per (seed, step, shard) — restart-safe without
saving cursor state beyond the step counter — and (b) *shardable by
construction*: shard ``s`` of ``S`` reads rows ``s::S`` of the global batch,
matching the SWIRL ``shard_<i>`` steps of the training workflow.

``ShardedLoader`` adds a background prefetch thread (double buffering): the
host assembles step ``n+1`` while the device chews on step ``n``.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np


def _mix(seed: int, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """64-bit splitmix-style hash of (seed, a, b) — vectorised."""
    x = (a.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)) ^ (
        b.astype(np.uint64) * np.uint64(0xBF58476D1CE4E5B9)
    )
    x ^= np.uint64(seed) * np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return x


@dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_s: float = 1.1  # skew: token = floor(V · u^s) biases small ids

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        """Return this shard's slice of the global batch for ``step``."""
        assert self.global_batch % n_shards == 0
        rows_per_shard = self.global_batch // n_shards
        row_ids = shard + np.arange(rows_per_shard, dtype=np.uint64) * n_shards
        doc = np.uint64(step) * np.uint64(self.global_batch) + row_ids
        t = np.arange(self.seq_len + 1, dtype=np.uint64)
        h = _mix(self.seed, doc[:, None], t[None, :])
        u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        tok = np.floor(self.vocab * np.power(u, self.zipf_s)).astype(np.int32)
        tok = np.clip(tok, 0, self.vocab - 1)
        return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}


class ShardedLoader:
    """Background-prefetching iterator over SyntheticLM steps."""

    def __init__(
        self,
        dataset: SyntheticLM,
        *,
        shard: int = 0,
        n_shards: int = 1,
        start_step: int = 0,
        prefetch: int = 2,
    ):
        self.dataset = dataset
        self.shard = shard
        self.n_shards = n_shards
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self) -> None:
        step = self.step
        while not self._stop.is_set():
            batch = self.dataset.batch(step, self.shard, self.n_shards)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        return self._q.get(timeout=30.0)

    def close(self) -> None:
        self._stop.set()


def make_batch_specs(vocab: int, seq_len: int, global_batch: int) -> dict:
    """ShapeDtypeStructs for one training batch (dry-run input stand-ins)."""
    import jax

    shape = (global_batch, seq_len)
    return {
        "tokens": jax.ShapeDtypeStruct(shape, np.int32),
        "labels": jax.ShapeDtypeStruct(shape, np.int32),
    }
