"""Data pipeline: sharded synthetic token streams with background prefetch."""

from .pipeline import SyntheticLM, ShardedLoader, make_batch_specs

__all__ = ["SyntheticLM", "ShardedLoader", "make_batch_specs"]
