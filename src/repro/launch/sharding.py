"""Sharding policy: param / batch / cache PartitionSpecs per architecture.

Megatron-style TP on the ``model`` axis:

* attention: Q/K/V column-parallel (output dim), O row-parallel (input dim);
* MLP: gate/up column-parallel, down row-parallel;
* MoE: expert-parallel — the leading expert axis shards on ``model`` (all
  assigned expert counts divide 16);
* Mamba: in_proj/conv column-parallel on d_inner, x_proj/out_proj
  row-parallel;
* embeddings / LM head: vocab-parallel (vocab is padded to a multiple of
  256, so it always divides);
* sLSTM: replicated (recurrent h→gates coupling makes TP a per-step
  all-reduce — at d=768 replication is cheaper);
* batch: sharded over ``("pod", "data")`` when divisible; ``long_500k``
  (batch=1) shards the KV-cache *sequence* axis over ``data`` instead (SP).

Every rule guards on divisibility, so the same policy serves full configs,
smoke variants and degraded elastic meshes.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import ModelConfig
from .mesh import axis_size, data_axes

PyTree = Any


def _div(n: int, mesh: Mesh, axes) -> bool:
    return n % axis_size(mesh, axes) == 0 and n >= axis_size(mesh, axes)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _param_rule(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    """PartitionSpec for one *unstacked* param leaf (no repeats dim)."""
    m = "model"

    def col(in_dim_idx: int = 0) -> P:
        # column-parallel: shard the LAST dim
        parts = [None] * len(shape)
        if _div(shape[-1], mesh, m):
            parts[-1] = m
        return P(*parts)

    def row() -> P:
        # row-parallel: shard the FIRST dim
        parts = [None] * len(shape)
        if _div(shape[0], mesh, m):
            parts[0] = m
        return P(*parts)

    leaf = path.rsplit("/", 1)[-1]

    if "embed" == path or path.endswith("/embed") or path == "embed":
        return row()  # [Vp, d] vocab-parallel
    if "lm_head" in path:
        return col() if leaf == "w" else row()
    if "/router/" in path or path.endswith("router/w") or path.endswith("router/b"):
        return P(*([None] * len(shape)))  # tiny, replicate
    if "/ffn/" in path and len(shape) == 3:
        # MoE expert stacks [E, d, de] / [E, de, d] — expert-parallel
        parts = [None] * len(shape)
        if _div(shape[0], mesh, m):
            parts[0] = m
        return P(*parts)
    if any(k in path for k in ("/g_i/", "/g_f/", "/g_z/", "/g_o/")):
        return P(*([None] * len(shape)))  # sLSTM cell: replicated
    if any(k in path for k in ("i_gate", "f_gate")):
        return P(*([None] * len(shape)))  # [d, H] — H small
    if "norm" in path:
        return P(*([None] * len(shape)))  # all norms replicated
    if leaf in ("b",) and len(shape) == 1:
        # biases follow their matrix: column-parallel ones shard
        if any(k in path for k in ("/o/", "down", "out_proj", "x_proj")):
            return P(None)  # row-parallel output bias is replicated
        return P(m) if _div(shape[0], mesh, m) else P(None)
    if any(k in path for k in ("/q/", "/k/", "/v/", "gate/", "up/", "in_proj", "dt_proj", "vision_proj")):
        return col()
    if any(k in path for k in ("/o/", "down/", "out_proj", "x_proj")):
        return row()
    if "conv_w" in path:  # [dc, di]
        return col()
    if "conv_b" in path or path.endswith("/D"):
        return P(m) if _div(shape[0], mesh, m) else P(None)
    if "A_log" in path:  # [di, ds]
        return row()
    # norms, scalars, anything else: replicate
    return P(*([None] * len(shape)))


def param_specs(cfg: ModelConfig, params_shape: PyTree, mesh: Mesh) -> PyTree:
    """PartitionSpec pytree matching ``jax.eval_shape(model.init, ...)``."""
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    specs = []
    for path, leaf in flat:
        p = _path_str(path)
        shape = tuple(leaf.shape)
        stacked = "/body/" in f"/{p}/"
        if stacked and len(shape) >= 1:
            inner = _param_rule(p, shape[1:], mesh)
            specs.append(P(None, *inner))
        else:
            specs.append(_param_rule(p, shape, mesh))
    treedef = jax.tree.structure(params_shape)
    return jax.tree.unflatten(treedef, specs)


def batch_specs(cfg: ModelConfig, batch_shape: PyTree, mesh: Mesh) -> PyTree:
    """Token/label/frontend inputs: batch over ("pod","data")."""
    dp = data_axes(mesh)

    def rule(path, leaf):
        shape = tuple(leaf.shape)
        parts = [None] * len(shape)
        if shape and _div(shape[0], mesh, dp):
            parts[0] = dp if len(dp) > 1 else dp[0]
        return P(*parts)

    flat = jax.tree_util.tree_flatten_with_path(batch_shape)[0]
    specs = [rule(p, l) for p, l in flat]
    return jax.tree.unflatten(jax.tree.structure(batch_shape), specs)


def _cache_rule(
    path: str, shape: tuple[int, ...], cfg: ModelConfig, mesh: Mesh,
    *, optimized: bool = True,
) -> P:
    dp = data_axes(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    m = "model"
    leaf = path.rsplit("/", 1)[-1]
    parts: list = [None] * len(shape)
    if not shape:
        return P()
    batch_shardable = _div(shape[0], mesh, dp)

    if leaf in ("k", "v") and len(shape) == 4:
        # [B, M, Hkv, hd]
        if optimized:
            # H3: shard the cache SEQUENCE over `model`.  Decode attention
            # then reduces softmax/PV over the sharded axis with tiny
            # [B, H]-sized collectives instead of all-gathering the cache
            # (the baseline GSPMD choice: ~0.5 GB/layer on granite decode).
            if batch_shardable:
                parts[0] = dp_spec
                if _div(shape[1], mesh, m):
                    parts[1] = m
            elif _div(shape[1], mesh, dp + (m,)):
                parts[1] = dp + (m,)  # batch=1 long-context: full SP
            return P(*parts)
        if batch_shardable:
            parts[0] = dp_spec
        elif _div(shape[1], mesh, ("data",)) and "data" in mesh.axis_names:
            parts[1] = "data"  # SP: batch=1 long-context → shard sequence
        if _div(shape[2], mesh, m):
            parts[2] = m
        elif _div(shape[3], mesh, m):
            parts[3] = m
        return P(*parts)
    if leaf == "conv":  # [B, dc-1, di]
        if batch_shardable:
            parts[0] = dp_spec
        if _div(shape[-1], mesh, m):
            parts[-1] = m
        return P(*parts)
    if leaf == "ssm":  # [B, di, ds]
        if batch_shardable:
            parts[0] = dp_spec
        if _div(shape[1], mesh, m):
            parts[1] = m
        return P(*parts)
    if leaf == "enc_out":  # [B, S, d]
        if batch_shardable:
            parts[0] = dp_spec
        return P(*parts)
    # recurrent xLSTM states & scalars: shard batch if possible, else replicate
    if batch_shardable:
        parts[0] = dp_spec
    return P(*parts)


def cache_specs(
    cfg: ModelConfig, cache_shape: PyTree, mesh: Mesh, *, optimized: bool = True
) -> PyTree:
    flat = jax.tree_util.tree_flatten_with_path(cache_shape)[0]
    specs = []
    for path, leaf in flat:
        p = _path_str(path)
        shape = tuple(leaf.shape)
        stacked = "/body/" in f"/{p}/"
        if stacked and len(shape) >= 1:
            inner = _cache_rule(p, shape[1:], cfg, mesh, optimized=optimized)
            specs.append(P(None, *inner))
        else:
            specs.append(_cache_rule(p, shape, cfg, mesh, optimized=optimized))
    return jax.tree.unflatten(jax.tree.structure(cache_shape), specs)


def to_shardings(mesh: Mesh, specs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
