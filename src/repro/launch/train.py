"""SWIRL-planned multi-pod training driver.

The distribution logic is NOT hand-written: each training iteration is a
*distributed workflow instance* (steps: per-pod ``shard`` → ``fwdbwd`` →
synchronised ``gradsync`` → per-pod ``update`` → ``ckpt``), translated by
the paper's encoding ``⟦·⟧`` into per-pod SWIRL traces, rewritten by the
paper's optimisation (R1 removes same-pod transfers, R2 coalesces duplicate
broadcasts), and executed by the fault-tolerant workflow runtime.  Inside a
pod, each step body is a jitted SPMD program (GSPMD over the pod mesh).

Cross-pod gradient traffic goes through int8 error-feedback compression
(:mod:`repro.optim.compress`) — the explicit send/recv structure of the
SWIRL plan is what makes the compression insertion point well-defined.

CPU-offline note: all "pods" share this host's device; the orchestration
path (plans, channels, checkpoints, recovery) is identical to the
multi-controller deployment, where each pod process executes its own trace.

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
        --steps 20 --pods 2 --global-batch 8 --seq-len 64
"""

from __future__ import annotations

import argparse
import time
from functools import partial
from typing import Any

import jax
import numpy as np

from repro import swirl
from repro.configs import get_config
from repro.core.translate import TrainPipelineTranslator
from repro.data import SyntheticLM
from repro.models import Model
from repro.optim import AdamWConfig
from repro.optim import adamw as adamw_mod
from repro.optim.compress import allreduce_mean, compress, decompress
from repro.workflow import RetryPolicy
from repro.ckpt import async_save, latest_step, load_checkpoint
from .steps import make_grad_step

PyTree = Any


def build_step_fns(
    grad_fn,
    update_fn,
    dataset: SyntheticLM,
    n_pods: int,
    *,
    compress_grads: bool = True,
    error_feedback: dict[int, PyTree] | None = None,
    ckpt_dir: str | None = None,
):
    """Step-name → pure-fn registry for one training iteration."""
    err = error_feedback if error_feedback is not None else {}

    fns: dict[str, Any] = {}
    for i in range(n_pods):

        def shard(inputs, i=i):
            step = int(inputs[f"iter_{i}"])
            b = dataset.batch(step, shard=i, n_shards=n_pods)
            return {f"batch_{i}": b}

        def fwdbwd(inputs, i=i):
            params = inputs[f"params_{i}"]
            grads, metrics = grad_fn(params, inputs[f"batch_{i}"])
            if compress_grads:
                c, err[i] = compress(grads, err.get(i))
                payload = ("int8", c)
            else:
                payload = ("raw", grads)
            return {f"grad_{i}": (payload, metrics)}

        def update(inputs, i=i):
            params = inputs[f"params_{i}"]
            opt_state = inputs[f"opt_{i}"]
            mean_grads, metrics = inputs["grad_sync"]
            new_params, new_opt, om = update_fn(mean_grads, opt_state, params)
            return {
                f"state_{i}": {
                    "params": new_params,
                    "opt": new_opt,
                    "metrics": {**metrics, **{k: float(v) for k, v in om.items()}},
                }
            }

        fns[f"shard_{i}"] = shard
        fns[f"fwdbwd_{i}"] = fwdbwd
        fns[f"update_{i}"] = update

    def gradsync(inputs):
        parts = []
        metrics = {}
        for i in range(n_pods):
            (kind, payload), metrics = inputs[f"grad_{i}"]
            parts.append(decompress(payload) if kind == "int8" else payload)
        mean = allreduce_mean(parts)
        return {"grad_sync": (mean, {k: float(v) for k, v in metrics.items()})}

    def ckpt(inputs):
        state = inputs["state_0"]
        if ckpt_dir:
            saver = async_save(
                ckpt_dir,
                int(state["opt"].step),
                {"params": state["params"], "opt": state["opt"]._asdict()},
            )
            saver.wait()
        return {}

    fns["gradsync"] = gradsync
    fns["ckpt"] = ckpt
    return fns, err


def train(
    arch: str,
    *,
    smoke: bool,
    steps: int,
    n_pods: int,
    global_batch: int,
    seq_len: int,
    ckpt_dir: str | None,
    compress_grads: bool = True,
    log_every: int = 5,
) -> dict:
    cfg = get_config(arch, smoke=smoke)
    model = Model(cfg)
    dataset = SyntheticLM(
        vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch
    )
    opt_cfg = AdamWConfig(warmup_steps=max(2, steps // 10), total_steps=steps)

    # The SWIRL plan for one iteration (encode ∘ optimise).
    translator = TrainPipelineTranslator(
        n_pods=n_pods, with_checkpoint=ckpt_dir is not None
    )
    plan = swirl.trace(translator).optimize(rules=("R1R2", "R3"))
    opt_stats, r3_stats = (r.stats for r in plan.rewrites)
    print(
        f"[swirl] plan: {plan.system.total_actions()} actions, "
        f"{plan.system.comm_count()} comms (Def.15 removed "
        f"{opt_stats.removed}, R3 removed {r3_stats.removed})"
    )
    lowered = plan.lower("inprocess", retry=RetryPolicy(max_retries=2))

    # Resume or init per-pod replicas (identical params across pods).
    params = model.init(jax.random.key(0))
    opt_state = adamw_mod.init(params)
    start = 0
    if ckpt_dir and (last := latest_step(ckpt_dir)) is not None:
        restored = load_checkpoint(
            ckpt_dir, last,
            {"params": params, "opt": opt_state._asdict()},
        )
        params = restored["params"]
        opt_state = adamw_mod.AdamWState(**restored["opt"])
        start = int(np.asarray(restored["opt"]["step"]))
        print(f"[ckpt] resumed from step {start}")

    err: dict[int, PyTree] = {}
    history = []
    grad_fn = jax.jit(make_grad_step(model))
    update_fn = jax.jit(partial(adamw_mod.update, opt_cfg))
    t0 = time.monotonic()
    for it in range(start, start + steps):
        fns, err = build_step_fns(
            grad_fn, update_fn, dataset, n_pods,
            compress_grads=compress_grads, error_feedback=err,
            ckpt_dir=ckpt_dir,
        )
        payloads = {}
        for i in range(n_pods):
            payloads[(f"pod{i}", f"iter_{i}")] = it
            payloads[(f"pod{i}", f"params_{i}")] = params
            payloads[(f"pod{i}", f"opt_{i}")] = opt_state
        # ``shard_i``/``fwdbwd_i`` read iter/params from the pod's local data
        # scope: declare them as part of each pod's initial D set.
        result = lowered.compile(fns).run(initial_payloads=payloads)
        state = result.payload("pod0", "state_0")
        params, opt_state = state["params"], state["opt"]
        m = state["metrics"]
        history.append(m)
        if (it - start) % log_every == 0:
            print(
                f"step {it:5d} loss={m['loss']:.4f} ce={m['ce']:.4f} "
                f"gnorm={m.get('grad_norm', 0):.3f}"
            )
    wall = time.monotonic() - t0
    print(f"[done] {steps} steps in {wall:.1f}s ({wall / steps:.2f}s/step)")
    return {"history": history, "params": params, "opt": opt_state}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--no-compress", dest="compress", action="store_false")
    args = ap.parse_args()
    train(
        args.arch,
        smoke=args.smoke,
        steps=args.steps,
        n_pods=args.pods,
        global_batch=args.global_batch,
        seq_len=args.seq_len,
        ckpt_dir=args.ckpt_dir,
        compress_grads=args.compress,
    )


if __name__ == "__main__":
    main()
