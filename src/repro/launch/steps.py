"""Step functions (train / prefill / serve) and dry-run input specs."""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import Shape
from repro.models import Model, ModelConfig
from repro.optim import AdamWConfig
from repro.optim import adamw

PyTree = Any


def make_train_step(model: Model, opt_cfg: AdamWConfig):
    def train_step(params: PyTree, opt_state: adamw.AdamWState, batch: PyTree):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        new_params, new_opt, opt_metrics = adamw.update(
            opt_cfg, grads, opt_state, params
        )
        return new_params, new_opt, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def make_grad_step(model: Model):
    """Forward+backward only — the SWIRL ``fwdbwd`` workflow step."""

    def grad_step(params: PyTree, batch: PyTree):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        return grads, {"loss": loss, **metrics}

    return grad_step


def make_prefill_step(model: Model):
    def prefill_step(params: PyTree, batch: PyTree, cache: PyTree):
        logits, cache = model.prefill(
            params,
            batch["tokens"],
            cache,
            src_embeds=batch.get("src_embeds"),
            patch_embeds=batch.get("patch_embeds"),
        )
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def make_serve_step(model: Model):
    """One greedy decode step — the lowered unit for decode_* shapes."""

    def serve_step(params: PyTree, cache: PyTree, token: jax.Array):
        logits, cache = model.decode_step(params, token, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1, keepdims=True).astype(
            jnp.int32
        )
        return next_tok, cache

    return serve_step


# ---------------------------------------------------------------------------
# Dry-run input stand-ins (ShapeDtypeStruct — no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: Shape) -> dict[str, jax.ShapeDtypeStruct]:
    """Batch ShapeDtypeStructs for one (arch, shape) cell.

    For the vision frontend the patch stub occupies ``frontend_len`` of the
    sequence budget (total context = assigned seq_len).  Enc-dec models get
    ``frontend_len`` encoder frames on top of the decoder's seq_len tokens.
    """
    b = shape.global_batch
    l = shape.seq_len
    tok_dtype = np.int32
    d = cfg.d_model
    specs: dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        tok_l = l - cfg.frontend_len if cfg.frontend == "vision" else l
        specs["tokens"] = jax.ShapeDtypeStruct((b, tok_l), tok_dtype)
        specs["labels"] = jax.ShapeDtypeStruct((b, tok_l), tok_dtype)
    elif shape.kind == "prefill":
        tok_l = l - cfg.frontend_len if cfg.frontend == "vision" else l
        specs["tokens"] = jax.ShapeDtypeStruct((b, tok_l), tok_dtype)
    else:  # decode: one token; the cache holds seq_len rows
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), tok_dtype)
    if cfg.is_encoder_decoder and shape.kind != "decode":
        specs["src_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, d), jnp.dtype(cfg.dtype)
        )
    if cfg.frontend == "vision" and shape.kind != "decode":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, d), jnp.dtype(cfg.dtype)
        )
    return specs


def abstract_params(model: Model) -> PyTree:
    return jax.eval_shape(model.init, jax.random.key(0))


def abstract_opt_state(params_shape: PyTree) -> PyTree:
    return jax.eval_shape(adamw.init, params_shape)


def abstract_cache(model: Model, batch: int, max_len: int) -> PyTree:
    return jax.eval_shape(
        partial(model.init_cache, batch, max_len)
    )
