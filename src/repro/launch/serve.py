"""Batched serving driver: prefill + greedy decode with a KV cache.

Demonstrates the serving path the ``decode_*`` dry-run shapes lower:
requests are batched, prompts prefilled in one jitted call, then tokens
decoded step-by-step against the (attention-KV / SSM-state) cache.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Model
from .steps import make_prefill_step, make_serve_step


def serve(
    arch: str,
    *,
    smoke: bool = True,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    seed: int = 0,
) -> dict:
    cfg = get_config(arch, smoke=smoke)
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    prefill = jax.jit(make_prefill_step(model))
    step = jax.jit(make_serve_step(model))

    prompts = jax.random.randint(
        jax.random.key(seed + 1), (batch, prompt_len), 0, cfg.vocab
    ).astype(jnp.int32)
    batch_in = {"tokens": prompts}
    if cfg.is_encoder_decoder:
        batch_in["src_embeds"] = (
            jax.random.normal(
                jax.random.key(seed + 2), (batch, cfg.frontend_len, cfg.d_model)
            )
            * 0.1
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.frontend == "vision":
        batch_in["patch_embeds"] = (
            jax.random.normal(
                jax.random.key(seed + 3), (batch, cfg.frontend_len, cfg.d_model)
            )
            * 0.1
        ).astype(jnp.dtype(cfg.dtype))

    max_len = prompt_len + gen + (
        cfg.frontend_len if cfg.frontend == "vision" else 0
    )
    cache = model.init_cache(batch, max_len)

    t0 = time.monotonic()
    next_tok, cache = prefill(params, batch_in, cache)
    jax.block_until_ready(next_tok)
    t_prefill = time.monotonic() - t0

    out = [np.asarray(next_tok)[:, None]]
    tok = next_tok[:, None]
    t0 = time.monotonic()
    for _ in range(gen - 1):
        tok, cache = step(params, cache, tok)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.monotonic() - t0

    tokens = np.concatenate(out, axis=1)
    tps = batch * (gen - 1) / t_decode if t_decode > 0 else float("inf")
    print(
        f"[serve] arch={cfg.name} batch={batch} prefill={prompt_len} "
        f"gen={gen}: prefill {t_prefill * 1e3:.0f} ms, "
        f"decode {t_decode * 1e3:.0f} ms ({tps:.0f} tok/s)"
    )
    return {
        "tokens": tokens,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tok_per_s": tps,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve(
        args.arch,
        smoke=args.smoke,
        batch=args.batch,
        prompt_len=args.prompt_len,
        gen=args.gen,
    )


if __name__ == "__main__":
    main()
