"""Production mesh construction.

``make_production_mesh`` is a *function* (not a module-level constant) so
importing this module never touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first JAX
init, smoke tests see the real single CPU device.

Mesh shapes:

* single-pod: ``(16, 16)`` with axes ``("data", "model")`` — one v5e pod of
  256 chips; DP over ``data``, TP/EP over ``model``;
* multi-pod: ``(2, 16, 16)`` with ``("pod", "data", "model")`` — the ``pod``
  axis is the outer data-parallel (gradient all-reduce crosses pods over
  DCN; SWIRL's ``gradsync`` step plans/compresses that transfer).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary mesh (elastic restarts build degraded meshes through this)."""
    return jax.make_mesh(shape, axes)


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The (possibly compound) batch-sharding axes of a mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh: jax.sharding.Mesh) -> str:
    return "model"


def axis_size(mesh: jax.sharding.Mesh, axes: tuple[str, ...] | str) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
