import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: JAX locks the host device count on
first init, and the production meshes need 512 placeholder devices.

Single-cell mode (one compile per process — compile memory is bounded)::

    python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k \
        --mesh pod1 --out experiments/dryrun/llama3.2-3b_train_4k_pod1.json

Fleet mode (fans out subprocesses, collects JSON)::

    python -m repro.launch.dryrun --all --jobs 4 --out-dir experiments/dryrun

Each record carries ``cost_analysis`` FLOPs/bytes, parsed collective
traffic, ``memory_analysis`` and the three roofline terms — EXPERIMENTS.md
§Dry-run/§Roofline are generated from these files.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402


MESHES = ("pod1", "pod2")  # 16×16 single pod; 2×16×16 multi-pod


def run_cell(
    arch: str, shape_name: str, mesh_name: str,
    *, unroll: bool = False, variant: str = "baseline",
) -> dict:
    import jax

    from repro.configs import get_config
    from repro.configs.shapes import SHAPES, shape_applicable
    from repro.models import Model
    from repro.optim import AdamWConfig
    from repro.roofline import (
        model_flops,
        parse_collectives,
        roofline,
        slstm_extra_flops,
    )
    from . import steps as S
    from .mesh import make_production_mesh
    from .sharding import (
        batch_specs,
        cache_specs,
        param_specs,
        to_shardings,
    )
    from repro.optim.zero import zero1_specs
    from jax.sharding import PartitionSpec as P

    t_start = time.monotonic()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape_name)
    if not ok:
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "skipped", "reason": why,
        }
    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    chips = mesh.devices.size
    repeats = cfg.repeats
    ssm_chunk = cfg.ssm.chunk
    if shape.kind in ("train", "prefill"):
        ssm_chunk = max(cfg.ssm.chunk, shape.seq_len // 16)
    if unroll:
        # Validation mode: unroll the layer stack so cost_analysis sees every
        # layer (used to calibrate the analytic model; ~10× slower compile).
        from repro.models import unrolled_variant

        cfg = unrolled_variant(cfg, ssm_chunk=ssm_chunk)
        repeats = 1
    elif ssm_chunk != cfg.ssm.chunk:
        import dataclasses as _dc

        cfg = _dc.replace(cfg, ssm=_dc.replace(cfg.ssm, chunk=ssm_chunk))
    model = Model(cfg)

    optimized = variant == "opt"
    if optimized:
        from repro.models.hints import ShardHints, set_hints
        from .mesh import data_axes

        set_hints(ShardHints(mesh=mesh, dp_axes=data_axes(mesh)))
    else:
        from repro.models.hints import set_hints

        set_hints(None)

    p_shape = S.abstract_params(model)
    p_specs = param_specs(cfg, p_shape, mesh)
    p_shard = to_shardings(mesh, p_specs)
    b_shape = S.input_specs(cfg, shape)
    b_specs = batch_specs(cfg, b_shape, mesh)
    b_shard = to_shardings(mesh, b_specs)

    rec: dict = {
        "variant": variant,
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "chips": chips,
        "params": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }

    with mesh:
        if shape.kind == "train":
            o_shape = S.abstract_opt_state(p_shape)
            # m/v specs: param specs augmented with a data-axis split (ZeRO-1)
            from repro.optim.adamw import AdamWState

            mv_spec = zero1_specs(
                param_specs(cfg, p_shape, mesh), p_shape,
                data_axis="data", data_size=mesh.shape["data"],
            )
            o_specs = AdamWState(step=P(), m=mv_spec, v=mv_spec)
            o_shard = to_shardings(mesh, o_specs)
            fn = S.make_train_step(model, AdamWConfig())
            metric_spec = jax.tree.map(
                lambda _: jax.sharding.NamedSharding(mesh, P()),
                {"loss": 0, "ce": 0, "aux": 0, "tokens": 0, "grad_norm": 0, "lr": 0},
            )
            jitted = jax.jit(
                fn,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, metric_spec),
            )
            t0 = time.monotonic()
            lowered = jitted.lower(p_shape, o_shape, b_shape)
        elif shape.kind == "prefill":
            c_shape = S.abstract_cache(model, shape.global_batch, shape.seq_len)
            c_specs = cache_specs(cfg, c_shape, mesh, optimized=optimized)
            c_shard = to_shardings(mesh, c_specs)
            fn = S.make_prefill_step(model)
            tok_out = jax.sharding.NamedSharding(
                mesh, batch_specs(cfg, {"t": jax.ShapeDtypeStruct((shape.global_batch,), 'int32')}, mesh)["t"]
            )
            jitted = jax.jit(
                fn,
                in_shardings=(p_shard, b_shard, c_shard),
                out_shardings=(tok_out, c_shard),
            )
            t0 = time.monotonic()
            lowered = jitted.lower(p_shape, b_shape, c_shape)
        else:  # decode
            c_shape = S.abstract_cache(model, shape.global_batch, shape.seq_len)
            c_specs = cache_specs(cfg, c_shape, mesh, optimized=optimized)
            c_shard = to_shardings(mesh, c_specs)
            fn = S.make_serve_step(model)
            tok_in = b_shard["tokens"]
            tok_out = jax.sharding.NamedSharding(
                mesh, batch_specs(cfg, {"t": jax.ShapeDtypeStruct((shape.global_batch, 1), 'int32')}, mesh)["t"]
            )
            jitted = jax.jit(
                fn,
                in_shardings=(p_shard, c_shard, tok_in),
                out_shardings=(tok_out, c_shard),
            )
            t0 = time.monotonic()
            lowered = jitted.lower(
                p_shape, c_shape, b_shape["tokens"]
            )

        rec["lower_s"] = time.monotonic() - t0
        t0 = time.monotonic()
        compiled = lowered.compile()
        rec["compile_s"] = time.monotonic() - t0

        cost = compiled.cost_analysis() or {}
        flops = float(cost.get("flops", 0.0))
        hbm_bytes = float(cost.get("bytes accessed", 0.0))
        rec["cost_analysis"] = {
            "flops": flops,
            "bytes_accessed": hbm_bytes,
            "utilization_ops": float(cost.get("utilization", 0.0)),
        }
        try:
            mem = compiled.memory_analysis()
            rec["memory_analysis"] = {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "generated_code_bytes": int(mem.generated_code_size_in_bytes),
            }
        except Exception as e:  # noqa: BLE001 — backend-dependent
            rec["memory_analysis"] = {"error": str(e)}

        hlo = compiled.as_text()
        # Scale collectives inside while-loop bodies by the layer-scan trip
        # count (the HLO shows the body once; it runs `repeats` times).
        stats = parse_collectives(hlo, body_scale=max(1, repeats))
        rec["collectives"] = stats.as_dict()
        rec["hlo_bytes"] = len(hlo)

        # Analytic FLOP/HBM models (validated vs. the unrolled cell — see
        # EXPERIMENTS.md §Roofline): scanned-body cost_analysis undercounts
        # FLOPs ×repeats and the CPU backend overcounts unfused bytes.
        from repro.configs import get_config as _gc
        from repro.roofline.analytic import (
            analytic_flops_global,
            analytic_hbm_bytes_per_device,
        )

        base_cfg = _gc(arch)
        a_flops = analytic_flops_global(base_cfg, shape)
        mm = analytic_hbm_bytes_per_device(
            base_cfg, shape,
            model_ways=mesh.shape["model"],
            data_ways=chips // mesh.shape["model"],
        )
        rec["analytic"] = {
            "flops_global": a_flops,
            "hbm_bytes_per_device": mm.total,
            "hbm_breakdown": {
                "params": mm.params_bytes,
                "opt": mm.opt_bytes,
                "grads": mm.grad_bytes,
                "acts": mm.act_bytes,
                "kv": mm.kv_bytes,
                "logits": mm.logits_bytes,
            },
        }
        rl = roofline(
            flops_per_device=a_flops / chips,
            hbm_bytes_per_device=mm.total,
            link_bytes_per_device=stats.total_link_bytes,
            model_flops_global=model_flops(base_cfg, shape),
            chips=chips,
        )
        rec["roofline"] = rl.as_dict()
        rec["status"] = "ok"
        rec["total_s"] = time.monotonic() - t_start
    return rec


def _cell_out(out_dir: Path, arch: str, shape: str, mesh: str) -> Path:
    safe = arch.replace("/", "_")
    return out_dir / f"{safe}__{shape}__{mesh}.json"


def run_all(out_dir: Path, jobs: int, meshes: tuple[str, ...], timeout: int, force: bool, variant: str = "baseline") -> int:
    from repro.configs import ARCHS
    from repro.configs.shapes import SHAPES

    out_dir.mkdir(parents=True, exist_ok=True)
    cells = [
        (a, s, m)
        for a in ARCHS
        for s in SHAPES
        for m in meshes
    ]
    pending = []
    for cell in cells:
        out = _cell_out(out_dir, *cell)
        if force or not out.exists():
            pending.append(cell)
    print(f"{len(cells)} cells total, {len(pending)} to run, jobs={jobs}")

    procs: dict = {}
    failures = []
    queue = list(pending)
    while queue or procs:
        while queue and len(procs) < jobs:
            cell = queue.pop(0)
            out = _cell_out(out_dir, *cell)
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", cell[0], "--shape", cell[1], "--mesh", cell[2],
                "--out", str(out), "--variant", variant,
            ]
            procs[subprocess.Popen(cmd)] = (cell, out, time.monotonic())
        done = [p for p in procs if p.poll() is not None]
        for p in done:
            cell, out, t0 = procs.pop(p)
            dt = time.monotonic() - t0
            if p.returncode != 0 or not out.exists():
                failures.append(cell)
                print(f"FAIL {cell} rc={p.returncode} ({dt:.0f}s)")
            else:
                rec = json.loads(out.read_text())
                print(
                    f"ok   {cell} status={rec.get('status')} "
                    f"compile={rec.get('compile_s', 0):.0f}s ({dt:.0f}s)"
                )
        for p, (cell, out, t0) in list(procs.items()):
            if time.monotonic() - t0 > timeout:
                p.kill()
                failures.append(cell)
                print(f"TIMEOUT {cell}")
                procs.pop(p)
        time.sleep(0.5)
    print(f"done; {len(failures)} failures: {failures}")
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=MESHES, default="pod1")
    ap.add_argument("--out")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", choices=("baseline", "opt"), default="baseline")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer stack (analytic-model validation)")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        return run_all(
            Path(args.out_dir), args.jobs, MESHES, args.timeout, args.force,
            variant=args.variant,
        )

    assert args.arch and args.shape, "--arch and --shape required"
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, unroll=args.unroll, variant=args.variant)
    except Exception as e:  # noqa: BLE001
        rec = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "status": "error", "error": f"{type(e).__name__}: {e}",
        }
    text = json.dumps(rec, indent=1)
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(text)
    print(text)
    return 0 if rec.get("status") in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
