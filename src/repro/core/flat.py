"""Flat indexed trace IR — the array view of a :class:`WorkflowSystem`.

The recursive tree walkers in :mod:`repro.core.optimizer` are fine at the
paper's 5–20-step scale but superlinear at 10k-step scale: R3's
``_remove_one`` rebuilds the full immutable trace tree once per removed
action, and every tree rewrite re-allocates the entire trace.  The flat IR
stores each location's trace as

* ``actions`` — the predicate occurrences in *program order* (exactly the
  traversal order of :func:`repro.core.syntax.actions`),
* ``ops``     — a preorder structure skeleton (``SEQ``/``PAR`` arity plus
  leaf slots) that makes the flattening lossless,
* ``alive``   — one flag per occurrence: rewriting deletes by index instead
  of rebuilding immutable trees,

plus hash indexes over communication keys (``(data, port, src, dst)`` for
sends, ``(port, src, dst)`` for recvs) so R2/R3 matching is O(1) per
occurrence.

Contracts, checked by the property suite in ``tests/test_flat_ir.py``:

* **Round-trip** — ``FlatSystem.from_system(w).to_system() == w`` exactly
  (node-for-node raw reconstruction) while nothing has been deleted.
* **Engine equivalence** — :func:`rewrite_r1r2` / :func:`rewrite_r3`
  followed by :meth:`FlatSystem.rebuild_system` return a system equal to
  the recursive reference engines
  (:func:`repro.core.optimizer.rewrite_system_tree` /
  :func:`~repro.core.optimizer.rewrite_spatial_tree`), with identical
  :class:`~repro.core.optimizer.OptimizationStats`, on every system in
  smart-constructor normal form — anything produced by
  :func:`~repro.core.encoding.encode`, the ``.swirl`` parser, or the
  ``seq``/``par`` smart constructors.  (The reference R1/R2 engine rebuilds
  every path through the smart constructors, so a non-normal input — e.g. a
  raw ``Seq`` holding a ``Nil`` — is normalised differently by the two R3
  engines; such trees cannot be produced by any front end.)

``bisim``, ``semantics`` and the parser never see the flat form: it is an
internal acceleration structure with a lossless bridge to the tree syntax.
"""

from __future__ import annotations

from collections import deque
from typing import TYPE_CHECKING, Iterator

from .syntax import (
    NIL,
    Action,
    Exec,
    LocationConfig,
    Nil,
    Par,
    Recv,
    Send,
    Seq,
    Trace,
    WorkflowSystem,
    is_action,
    par,
    seq,
)

if TYPE_CHECKING:  # pragma: no cover - import cycle guard for type checkers
    from .optimizer import OptimizationStats

__all__ = [
    "FlatTrace",
    "FlatConfig",
    "FlatSystem",
    "flatten_trace",
    "rewrite_r1r2",
    "rewrite_r3",
    "rewrite_flat_pipeline",
    "FLAT_RULES",
]

# Structure opcodes.  ``ops`` is a preorder list of ``(code, arg)`` pairs:
# SEQ/PAR carry their child count, ACT the index into ``actions``.
OP_NIL = 0
OP_ACT = 1
OP_SEQ = 2
OP_PAR = 3


class FlatTrace:
    """One trace as (preorder skeleton, program-order actions, alive flags)."""

    __slots__ = ("ops", "actions", "alive")

    def __init__(
        self,
        ops: list[tuple[int, int]],
        actions: list[Action],
        alive: list[bool] | None = None,
    ) -> None:
        self.ops = ops
        self.actions = actions
        self.alive = [True] * len(actions) if alive is None else alive

    # -- tree -> flat -------------------------------------------------------
    @classmethod
    def from_trace(cls, t: Trace) -> "FlatTrace":
        ops: list[tuple[int, int]] = []
        actions: list[Action] = []
        stack: list[Trace] = [t]
        while stack:
            node = stack.pop()
            if isinstance(node, Nil):
                ops.append((OP_NIL, 0))
            elif is_action(node):
                ops.append((OP_ACT, len(actions)))
                actions.append(node)  # type: ignore[arg-type]
            elif isinstance(node, Seq):
                ops.append((OP_SEQ, len(node.items)))
                stack.extend(reversed(node.items))
            elif isinstance(node, Par):
                ops.append((OP_PAR, len(node.branches)))
                stack.extend(reversed(node.branches))
            else:
                raise TypeError(f"not a trace: {node!r}")
        return cls(ops, actions)

    # -- flat -> tree -------------------------------------------------------
    def to_trace(self) -> Trace:
        """Exact raw reconstruction (requires every action still alive)."""
        if not all(self.alive):
            raise ValueError(
                "trace has deleted actions; use rebuild() for the "
                "smart-constructor reconstruction"
            )
        t, pos = self._build(0, exact=True)
        if pos != len(self.ops):
            raise ValueError("trailing structure ops — corrupt flat trace")
        return t

    def rebuild(self) -> Trace:
        """Smart-constructor reconstruction honouring the alive flags.

        Dead action slots become ``0`` and the ``seq``/``par`` identities
        collapse them away — exactly what the recursive R1/R2 engine does on
        every path of the tree.
        """
        t, pos = self._build(0, exact=False)
        if pos != len(self.ops):
            raise ValueError("trailing structure ops — corrupt flat trace")
        return t

    def _build(self, pos: int, *, exact: bool) -> tuple[Trace, int]:
        code, arg = self.ops[pos]
        pos += 1
        if code == OP_NIL:
            return NIL, pos
        if code == OP_ACT:
            if exact or self.alive[arg]:
                return self.actions[arg], pos
            return NIL, pos
        children: list[Trace] = []
        for _ in range(arg):
            child, pos = self._build(pos, exact=exact)
            children.append(child)
        if code == OP_SEQ:
            return (Seq(tuple(children)) if exact else seq(*children)), pos
        if code == OP_PAR:
            return (Par(tuple(children)) if exact else par(*children)), pos
        raise ValueError(f"unknown structure opcode {code}")

    def compact(self) -> "FlatTrace":
        """The op-array export: live actions + normalised flat skeleton.

        Drops every dead slot and applies the ``seq``/``par`` smart-
        constructor identities (units removed, single children inlined,
        same-kind nests flattened) *without leaving the flat form* — the
        flat analogue of :meth:`rebuild`.  This is what the execution
        lowering (:mod:`repro.exec`) consumes: a program-order action
        array plus the minimal control skeleton, with
        ``compact().rebuild() == rebuild()`` by construction.
        """
        kinds = {OP_SEQ, OP_PAR}

        def norm(pos: int) -> tuple[tuple | None, int]:
            code, arg = self.ops[pos]
            pos += 1
            if code == OP_NIL:
                return None, pos
            if code == OP_ACT:
                if self.alive[arg]:
                    return (OP_ACT, self.actions[arg]), pos
                return None, pos
            children: list[tuple] = []
            for _ in range(arg):
                child, pos = norm(pos)
                if child is None:
                    continue
                if child[0] == code:
                    children.extend(child[1])
                else:
                    children.append(child)
            if not children:
                return None, pos
            if len(children) == 1:
                return children[0], pos
            assert code in kinds
            return (code, children), pos

        root, end = norm(0)
        if end != len(self.ops):
            raise ValueError("trailing structure ops — corrupt flat trace")
        ops: list[tuple[int, int]] = []
        actions: list[Action] = []
        stack: list[tuple] = [] if root is None else [root]
        if root is None:
            ops.append((OP_NIL, 0))
        while stack:
            node = stack.pop()
            code, payload = node
            if code == OP_ACT:
                ops.append((OP_ACT, len(actions)))
                actions.append(payload)
            else:
                ops.append((code, len(payload)))
                stack.extend(reversed(payload))
        return FlatTrace(ops, actions)

    # -- views --------------------------------------------------------------
    def live_actions(self) -> Iterator[tuple[int, Action]]:
        """``(index, action)`` pairs still alive, in program order."""
        alive = self.alive
        for i, a in enumerate(self.actions):
            if alive[i]:
                yield i, a

    def __len__(self) -> int:
        return len(self.actions)


def flatten_trace(t: Trace) -> FlatTrace:
    """Convenience alias for :meth:`FlatTrace.from_trace`."""
    return FlatTrace.from_trace(t)


class FlatConfig:
    """``⟨l, D, e⟩`` with ``e`` in flat form."""

    __slots__ = ("location", "data", "trace")

    def __init__(
        self, location: str, data: frozenset[str], trace: FlatTrace
    ) -> None:
        self.location = location
        self.data = data
        self.trace = trace


class FlatSystem:
    """A :class:`WorkflowSystem` as per-location flat action arrays."""

    __slots__ = ("configs", "_by_location")

    def __init__(self, configs: list[FlatConfig]) -> None:
        self.configs = configs
        self._by_location = {c.location: c for c in configs}

    @classmethod
    def from_system(cls, w: WorkflowSystem) -> "FlatSystem":
        return cls(
            [
                FlatConfig(c.location, c.data, FlatTrace.from_trace(c.trace))
                for c in w.configs
            ]
        )

    def __getitem__(self, location: str) -> FlatConfig:
        return self._by_location[location]

    def to_system(self) -> WorkflowSystem:
        """Exact round-trip (only valid while nothing has been deleted)."""
        return WorkflowSystem(
            tuple(
                LocationConfig(c.location, c.data, c.trace.to_trace())
                for c in self.configs
            )
        )

    def rebuild_system(self) -> WorkflowSystem:
        """Smart-constructor reconstruction honouring deletions."""
        return WorkflowSystem(
            tuple(
                LocationConfig(c.location, c.data, c.trace.rebuild())
                for c in self.configs
            )
        )

    # -- indexes ------------------------------------------------------------
    def comm_indexes(
        self,
    ) -> tuple[
        dict[str, dict[tuple, deque[int]]],
        dict[str, dict[tuple, deque[int]]],
    ]:
        """Per-location FIFO indexes over *alive* communication keys.

        Returns ``(sends, recvs)``: ``sends[loc][(data, port, src, dst)]``
        and ``recvs[loc][(port, src, dst)]`` are deques of action indices
        into ``self[loc].trace.actions`` in program order — popping the left
        end is exactly "the first matching occurrence" the tree engine's
        ``_remove_one`` finds.
        """
        sends: dict[str, dict[tuple, deque[int]]] = {}
        recvs: dict[str, dict[tuple, deque[int]]] = {}
        for cfg in self.configs:
            s_idx: dict[tuple, deque[int]] = {}
            r_idx: dict[tuple, deque[int]] = {}
            for i, a in cfg.trace.live_actions():
                if isinstance(a, Send):
                    s_idx.setdefault(
                        (a.data, a.port, a.src, a.dst), deque()
                    ).append(i)
                elif isinstance(a, Recv):
                    r_idx.setdefault((a.port, a.src, a.dst), deque()).append(i)
            sends[cfg.location] = s_idx
            recvs[cfg.location] = r_idx
        return sends, recvs


# ---------------------------------------------------------------------------
# Flat rewriting engines (Def. 15 + R3) — single indexed passes
# ---------------------------------------------------------------------------


def _new_stats() -> "OptimizationStats":
    from .optimizer import OptimizationStats

    return OptimizationStats()


def rewrite_r1r2(fs: FlatSystem) -> "OptimizationStats":
    """R1+R2 (Def. 15) as one left-to-right scan per location, in place.

    Mirrors the reference engine exactly: the set ``A`` of seen
    communication prefixes is threaded through each location's actions in
    program order (``A = ∅`` per location), local comms (R1) and repeats of
    an already-seen key (R2) are deleted by index.
    """
    stats = _new_stats()
    by_loc = stats.by_location
    kept = removed_local = removed_duplicate = 0
    for cfg in fs.configs:
        seen: set[tuple] = set()
        loc = cfg.location
        alive = cfg.trace.alive
        removed_here = 0
        for i, a in enumerate(cfg.trace.actions):
            if not alive[i]:
                continue
            cls = a.__class__
            if cls is Exec:
                kept += 1
                continue
            if a.src == a.dst:  # R1: μ ∈ A_{l,l}
                alive[i] = False
                removed_local += 1
                removed_here += 1
                continue
            if cls is Send:
                key: tuple = ("send", a.data, a.port, a.src, a.dst)
            else:
                key = ("recv", a.port, a.src, a.dst)
            if key in seen:  # R2: μ ∈ A
                alive[i] = False
                removed_duplicate += 1
                removed_here += 1
            else:
                seen.add(key)
                kept += 1
        if removed_here:
            by_loc[loc] = by_loc.get(loc, 0) + removed_here
    stats.kept = kept
    stats.removed_local = removed_local
    stats.removed_duplicate = removed_duplicate
    return stats


def rewrite_r3(fs: FlatSystem) -> "OptimizationStats":
    """R3 (spatial-constraint dedup) as one indexed pass, in place.

    The reference engine re-walks and rebuilds the whole tree per removed
    action; here the ``port → data`` and ``location → produces`` tables are
    built once over the alive actions and each removal pops the per-key
    FIFO index — first alive send at the source, first alive matching recv
    at the destination — making the pass linear in the action count.

    Stats count each removed pair once at the send's source *and* once at
    the recv's destination in ``by_location`` (two predicates, one per
    side), matching the reference engine.
    """
    stats = _new_stats()
    by_loc = stats.by_location

    # One scan builds everything: port → data sent over it, location →
    # data its own (alive) execs produce, the snapshot of alive send
    # occurrences in system program order (the tree engine iterates
    # `actions(c.trace)` of the pre-R3 system), and per-location FIFO
    # indexes (index lists + head pointers) over the comm keys.
    port_data: dict[str, set[str]] = {}
    produces: dict[str, set[str]] = {c.location: set() for c in fs.configs}
    snapshot: list[Send] = []
    send_fifo: dict[tuple, list[int]] = {}  # (loc, data, port, src, dst)
    recv_fifo: dict[tuple, list[int]] = {}  # (loc, port, src, dst)
    for cfg in fs.configs:
        loc = cfg.location
        prod = produces[loc]
        alive = cfg.trace.alive
        for i, a in enumerate(cfg.trace.actions):
            if not alive[i]:
                continue
            cls = a.__class__
            if cls is Send:
                port_data.setdefault(a.port, set()).add(a.data)
                snapshot.append(a)
                send_fifo.setdefault(
                    (loc, a.data, a.port, a.src, a.dst), []
                ).append(i)
            elif cls is Recv:
                recv_fifo.setdefault(
                    (loc, a.port, a.src, a.dst), []
                ).append(i)
            elif loc in a.locations:  # Exec
                prod.update(a.outputs)

    heads: dict[tuple, int] = {}
    for a in snapshot:
        if a.src == a.dst:
            continue
        if len(port_data[a.port]) != 1:
            continue
        if a.data not in produces.get(a.dst, ()):
            continue
        skey = (a.src, a.data, a.port, a.src, a.dst)
        rkey = (a.dst, a.port, a.src, a.dst)
        sq = send_fifo.get(skey)
        rq = recv_fifo.get(rkey)
        if sq is None or rq is None:
            continue
        shead = heads.get(skey, 0)
        rhead = heads.get(rkey, 0)
        if shead >= len(sq) or rhead >= len(rq):
            continue  # one side already exhausted — keep the other intact
        heads[skey] = shead + 1
        heads[rkey] = rhead + 1
        fs[a.src].trace.alive[sq[shead]] = False
        fs[a.dst].trace.alive[rq[rhead]] = False
        stats.removed_duplicate += 2
        by_loc[a.src] = by_loc.get(a.src, 0) + 1
        by_loc[a.dst] = by_loc.get(a.dst, 0) + 1
    return stats


#: Flat in-place engines by rule name (same keys as
#: :data:`repro.core.optimizer.REWRITE_RULES`).
FLAT_RULES = {
    "R1R2": rewrite_r1r2,
    "R3": rewrite_r3,
}


def rewrite_flat_pipeline(
    w: WorkflowSystem, rules: tuple[str, ...]
) -> tuple[WorkflowSystem, list["OptimizationStats"]]:
    """Apply ``rules`` with ONE flatten and ONE rebuild around the passes.

    The fast path behind :meth:`repro.api.Plan.optimize`: flattening and
    tree reconstruction are paid once for the whole rule list instead of
    once per rule.
    """
    unknown = [r for r in rules if r not in FLAT_RULES]
    if unknown:
        raise KeyError(f"unknown flat rewrite rules {unknown}")
    fs = FlatSystem.from_system(w)
    stats = [FLAT_RULES[r](fs) for r in rules]
    return fs.rebuild_system(), stats
