"""Optimisation function ``⟦·⟧ : W_W → W_O`` — Definition 15 of the paper.

Two rewriting rules, applied by a single left-to-right scan of each
location's execution trace while threading a set ``A`` of already-seen
communication prefixes:

* **R1 (local communication)** — ``μ ∈ A_{l,l}``: a ``send``/``recv`` whose
  source and destination coincide is redundant (the data element is already
  in the location's scope after the producing ``exec``) and is replaced by
  ``0``.
* **R2 (duplicate communication)** — ``μ ∈ A``: the same data element was
  already sent to the same location through the same port (just to a
  different step); the later copy is replaced by ``0``.

Per Def. 15 the set ``A`` is threaded *within* one location's trace (both
through ``.`` and ``|`` compositions, in program order) and each location is
rewritten with the same inherited top-level ``A = ∅`` — the sender dedupes
its sends and, independently, the receiver dedupes the matching recvs, which
keeps the two sides consistent.

Correctness: ``W ≈ ⟦W⟧`` (weak barbed bisimulation, Thm. 1) — checked
mechanically by :mod:`repro.core.bisim` in the property tests.

Two engines implement each rule:

* the **flat engine** (:mod:`repro.core.flat`) — the default behind
  :func:`rewrite_system` / :func:`rewrite_spatial`: one indexed pass over
  per-location action arrays, linear in the action count, built for
  10k-step plans;
* the **tree engine** (:func:`rewrite_system_tree` /
  :func:`rewrite_spatial_tree`) — the original recursive walkers over the
  immutable trace trees, kept verbatim as the reference oracle the
  differential property suite (``tests/test_flat_ir.py``) checks the flat
  engine against.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .syntax import (
    NIL,
    Exec,
    LocationConfig,
    Nil,
    Par,
    Recv,
    Send,
    Seq,
    Trace,
    WorkflowSystem,
    actions,
    is_action,
    par,
    seq,
)


@dataclass
class OptimizationStats:
    """What the rewriting removed — reported by benchmarks and EXPERIMENTS."""

    removed_local: int = 0  # R1: same-location send/recv pairs' predicates
    removed_duplicate: int = 0  # R2: duplicate sends/recvs
    kept: int = 0
    by_location: dict[str, int] = field(default_factory=dict)

    @property
    def removed(self) -> int:
        return self.removed_local + self.removed_duplicate


def _comm_key(a) -> tuple | None:
    """The identity under which communications are deduplicated.

    ``send(d↣p,l,l')`` repeats iff (d,p,l,l') repeats; ``recv(p,l,l')``
    repeats iff (p,l,l') repeats (the receiving side never names the datum,
    cf. Def. 8).
    """
    if isinstance(a, Send):
        return ("send", a.data, a.port, a.src, a.dst)
    if isinstance(a, Recv):
        return ("recv", a.port, a.src, a.dst)
    return None


def _is_local(a) -> bool:
    return isinstance(a, (Send, Recv)) and a.src == a.dst


def _rewrite(t: Trace, seen: set, stats: OptimizationStats, loc: str) -> Trace:
    """One pass of the third auxiliary function of Def. 15 (``A`` = seen)."""
    if isinstance(t, Nil):
        return t
    if is_action(t):
        if isinstance(t, Exec):
            stats.kept += 1
            return t
        if _is_local(t):  # μ ∈ A_{l,l}
            stats.removed_local += 1
            stats.by_location[loc] = stats.by_location.get(loc, 0) + 1
            return NIL
        key = _comm_key(t)
        if key in seen:  # μ ∈ A
            stats.removed_duplicate += 1
            stats.by_location[loc] = stats.by_location.get(loc, 0) + 1
            return NIL
        seen.add(key)
        stats.kept += 1
        return t
    if isinstance(t, Seq):
        return seq(*(_rewrite(i, seen, stats, loc) for i in t.items))
    if isinstance(t, Par):
        return par(*(_rewrite(b, seen, stats, loc) for b in t.branches))
    raise TypeError(f"not a trace: {t!r}")


def rewrite_system_tree(
    w: WorkflowSystem,
) -> tuple[WorkflowSystem, OptimizationStats]:
    """R1+R2 via the recursive tree engine (reference oracle)."""
    stats = OptimizationStats()
    configs = []
    for c in w.configs:
        seen: set = set()  # A = ∅ per location (see module docstring)
        new_trace = _rewrite(c.trace, seen, stats, c.location)
        configs.append(LocationConfig(c.location, c.data, new_trace))
    return WorkflowSystem(tuple(configs)), stats


def rewrite_system(w: WorkflowSystem) -> tuple[WorkflowSystem, OptimizationStats]:
    """``⟦W⟧`` — rewrite every location configuration (Def. 15, rules R1+R2).

    Canonical entry point used by :meth:`repro.api.Plan.optimize`.  Runs the
    single-pass flat engine (:func:`repro.core.flat.rewrite_r1r2`);
    :func:`rewrite_system_tree` is the recursive reference implementation.
    """
    from .flat import FlatSystem, rewrite_r1r2

    fs = FlatSystem.from_system(w)
    stats = rewrite_r1r2(fs)
    return fs.rebuild_system(), stats


def optimize(w: WorkflowSystem) -> tuple[WorkflowSystem, OptimizationStats]:
    """Deprecated shim for :func:`rewrite_system` (legacy free function)."""
    from repro._compat import warn_legacy

    warn_legacy("repro.core.optimize()", "swirl.trace(...).optimize()")
    return rewrite_system(w)


# ---------------------------------------------------------------------------
# R3 — spatial-constraint deduplication (beyond the paper's Def. 15)
# ---------------------------------------------------------------------------
#
# When a step s is mapped onto several locations, rule (EXEC) already places
# Out^D(s) on EVERY location of M(s).  The encoding, however, still emits a
# send/recv for each consumer location — including consumers that
# *participate in the producing exec themselves*.  Those transfers are
# value-redundant: the (COMM) would only add a datum that the destination's
# own exec occurrence already added, and removing the pair cannot enable
# anything earlier because (EXEC) still guards on In^D ⊆ D.  The proof
# obligation is the same weak-barbed-bisimulation argument as for R1
# (checked mechanically in tests/test_optimizer_rules.py).  This rewrite is
# what collapses the multi-pod trainer's grad_sync re-broadcast.


def _remove_one(t: Trace, pred) -> tuple[Trace, bool]:
    """Remove the first action satisfying ``pred`` (left-to-right)."""
    if is_action(t):
        return (NIL, True) if pred(t) else (t, False)
    if isinstance(t, Nil):
        return t, False
    if isinstance(t, Seq):
        items = list(t.items)
        for i, item in enumerate(items):
            new, hit = _remove_one(item, pred)
            if hit:
                items[i] = new
                return seq(*items), True
        return t, False
    if isinstance(t, Par):
        branches = list(t.branches)
        for i, b in enumerate(branches):
            new, hit = _remove_one(b, pred)
            if hit:
                branches[i] = new
                return par(*branches), True
        return t, False
    raise TypeError(f"not a trace: {t!r}")


def rewrite_spatial_tree(
    w: WorkflowSystem,
) -> tuple[WorkflowSystem, OptimizationStats]:
    """R3 via the recursive tree engine (reference oracle).

    Quadratic: every removal re-walks and rebuilds the trace tree through
    :func:`_remove_one`.  Kept verbatim (modulo the ``by_location``
    accounting fix) so the differential suite can check the indexed flat
    engine against it; production callers go through
    :func:`rewrite_spatial`.
    """
    stats = OptimizationStats()

    # Port → data elements actually sent over it (from the send predicates).
    port_data: dict[str, set[str]] = {}
    for c in w.configs:
        for a in actions(c.trace):
            if isinstance(a, Send):
                port_data.setdefault(a.port, set()).add(a.data)

    # Location → data its own (spatial) execs produce.
    produces: dict[str, set[str]] = {c.location: set() for c in w.configs}
    for c in w.configs:
        for a in actions(c.trace):
            if isinstance(a, Exec) and c.location in a.locations:
                produces[c.location] |= set(a.outputs)

    new_cfg = {c.location: c for c in w.configs}
    for c in w.configs:
        for a in list(actions(c.trace)):
            if not isinstance(a, Send) or a.src == a.dst:
                continue
            if len(port_data.get(a.port, set())) != 1:
                continue
            if a.data not in produces.get(a.dst, set()):
                continue
            # remove this send at src and one matching recv at dst
            src_cfg, dst_cfg = new_cfg[a.src], new_cfg[a.dst]
            s_trace, s_hit = _remove_one(
                src_cfg.trace, lambda x, a=a: x == a
            )
            d_trace, d_hit = _remove_one(
                dst_cfg.trace,
                lambda x, a=a: isinstance(x, Recv)
                and (x.port, x.src, x.dst) == (a.port, a.src, a.dst),
            )
            if s_hit and d_hit:
                new_cfg[a.src] = LocationConfig(
                    src_cfg.location, src_cfg.data, s_trace
                )
                new_cfg[a.dst] = LocationConfig(
                    dst_cfg.location, dst_cfg.data, d_trace
                )
                # One predicate removed per side: the send at its source,
                # the recv at its destination.
                stats.removed_duplicate += 2
                stats.by_location[a.src] = stats.by_location.get(a.src, 0) + 1
                stats.by_location[a.dst] = stats.by_location.get(a.dst, 0) + 1
    return (
        WorkflowSystem(tuple(new_cfg[c.location] for c in w.configs)),
        stats,
    )


def rewrite_spatial(
    w: WorkflowSystem,
) -> tuple[WorkflowSystem, OptimizationStats]:
    """R3: drop send/recv pairs whose destination co-executes the producer.

    Only channels whose port carries a single data element are rewritten
    (recv predicates name the port, not the datum — with one datum per port
    the matching is unambiguous; multi-data ports are left untouched).

    Runs the indexed flat engine (:func:`repro.core.flat.rewrite_r3`):
    port→data and location→produces tables are built once and each pair is
    deleted by index instead of rebuilding the trace tree per removal
    (:func:`rewrite_spatial_tree`, the reference, is quadratic in plan
    size).
    """
    from .flat import FlatSystem, rewrite_r3

    fs = FlatSystem.from_system(w)
    stats = rewrite_r3(fs)
    return fs.rebuild_system(), stats


def optimize_spatial(
    w: WorkflowSystem,
) -> tuple[WorkflowSystem, OptimizationStats]:
    """Deprecated shim for :func:`rewrite_spatial` (legacy free function)."""
    from repro._compat import warn_legacy

    warn_legacy(
        "repro.core.optimize_spatial()",
        'swirl.trace(...).optimize(rules=("R1R2", "R3"))',
    )
    return rewrite_spatial(w)


#: The rule sets :meth:`repro.api.Plan.optimize` can apply, in canonical
#: application order.  "R1R2" is the paper's Def.-15 scan (local + duplicate
#: communication removal); "R3" is the spatial-constraint deduplication.
#: Backed by the flat engines; :data:`REWRITE_RULES_TREE` holds the
#: recursive reference implementations under the same keys.
REWRITE_RULES = {
    "R1R2": rewrite_system,
    "R3": rewrite_spatial,
}

REWRITE_RULES_TREE = {
    "R1R2": rewrite_system_tree,
    "R3": rewrite_spatial_tree,
}
