"""Bundle compiler — the ``SWIRLCompiler`` layer of the toolchain.

A *bundle* is a self-contained, per-location executable: the location's
execution trace plus the metadata the semantics does not model (step
callables / commands, data payload specs, channel endpoints).

Since the execution-IR refactor the canonical per-location executable is
the :class:`~repro.exec.program.LocationProgram` of :mod:`repro.exec`
(program-order op arrays, interpreted by every backend); what remains here
is the **step metadata model** (:class:`StepMeta`, shared by the whole
toolchain) and the legacy bundle layer:

* :class:`LocationBundle` / :func:`build_bundles` — a *view shim* over the
  canonical lowering, feeding the deprecated tree runtimes that are kept
  as differential-test oracles;
* :func:`emit_python_source` / :func:`emit_all` — deprecation shims over
  :mod:`repro.exec.emit` (standalone per-location Python source, now
  generated from the program IR).
"""

from __future__ import annotations

import textwrap
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence

from .syntax import (
    Exec,
    LocationConfig,
    Recv,
    Send,
    Trace,
    WorkflowSystem,
    actions,
)

# A step function: mapping of input data name -> payload  ->  mapping of
# output data name -> payload.  Pure by contract (same assumption the paper
# inherits from dataflow semantics; it is what makes re-execution-based fault
# tolerance sound).
StepFn = Callable[[Mapping[str, Any]], Mapping[str, Any]]


@dataclass(frozen=True)
class StepMeta:
    """Declarative metadata for one step (the paper's metadata file entry)."""

    fn: StepFn
    inputs: frozenset[str] = frozenset()
    outputs: frozenset[str] = frozenset()
    # Scheduling hints used by the runtime's straggler mitigation:
    expected_seconds: float | None = None
    # Declared byte-size per output datum, consumed by the placement
    # scheduler's payload-size estimator (repro.sched.SizeModel):
    output_bytes: Mapping[str, int] | None = None


@dataclass(frozen=True)
class Channel:
    """A directed (src, dst, port) communication endpoint pair."""

    src: str
    dst: str
    port: str


@dataclass
class LocationBundle:
    """Self-contained executable for one location."""

    location: str
    initial_data: frozenset[str]
    trace: Trace
    steps: dict[str, StepMeta] = field(default_factory=dict)

    def channels(self) -> list[Channel]:
        """Every channel endpoint this bundle communicates over."""
        chans: set[Channel] = set()
        for a in actions(self.trace):
            if isinstance(a, Send):
                chans.add(Channel(a.src, a.dst, a.port))
            elif isinstance(a, Recv):
                chans.add(Channel(a.src, a.dst, a.port))
        return sorted(chans, key=lambda c: (c.src, c.dst, c.port))

    def exec_steps(self) -> list[str]:
        return [a.step for a in actions(self.trace) if isinstance(a, Exec)]


def build_bundles(
    w: WorkflowSystem,
    step_fns: Mapping[str, StepFn],
    *,
    step_meta: Mapping[str, StepMeta] | None = None,
) -> dict[str, LocationBundle]:
    """Compile a workflow system into one bundle per location.

    ``step_fns`` must cover every step executed anywhere in ``w``; a step
    mapped onto several locations (spatial constraint) receives the same
    callable everywhere — the runtime synchronises the exec like the (EXEC)
    rule does.

    Since the execution-IR refactor this is a *view shim* over the
    canonical lowering (:func:`repro.exec.lower_system`): bundles are
    projected from the per-location programs, and no backend consumes them
    anymore — they feed the legacy tree runtimes kept as reference oracles.
    The legacy name :func:`compile_bundles` additionally warns.
    """
    from repro.exec.program import lower_system

    program = lower_system(w)
    bundles: dict[str, LocationBundle] = {}
    for lp in program.programs:
        local_steps: dict[str, StepMeta] = {}
        for op in lp.exec_ops():
            if op.step not in step_fns:
                raise KeyError(
                    f"no step function registered for {op.step!r}"
                )
            meta = (step_meta or {}).get(op.step)
            local_steps[op.step] = meta or StepMeta(
                fn=step_fns[op.step],
                inputs=frozenset(op.inputs),
                outputs=frozenset(op.outputs),
            )
        bundles[lp.location] = LocationBundle(
            location=lp.location,
            initial_data=lp.data,
            trace=w[lp.location].trace,
            steps=local_steps,
        )
    return bundles


def compile_bundles(
    w: WorkflowSystem,
    step_fns: Mapping[str, StepFn],
    *,
    step_meta: Mapping[str, StepMeta] | None = None,
) -> dict[str, LocationBundle]:
    """Deprecated shim for :func:`build_bundles` (legacy free function)."""
    from repro._compat import warn_legacy

    warn_legacy(
        "repro.core.compile_bundles()",
        'swirl.trace(...).lower("threaded").compile(step_fns)',
    )
    return build_bundles(w, step_fns, step_meta=step_meta)


# ---------------------------------------------------------------------------
# Standalone Python source emission (paper §5's generated bundles)
#
# The generators moved to repro.exec.emit, driven by the per-location
# program IR instead of the trace trees; the two entry points below are
# deprecation shims kept for the legacy bundle workflow.
# ---------------------------------------------------------------------------


def emit_python_source(bundle: LocationBundle) -> str:
    """Deprecated: emit a standalone Python program for one bundle.

    Shim over :func:`repro.exec.emit.emit_location_source` — the bundle's
    trace is lowered to a :class:`~repro.exec.program.LocationProgram` and
    emitted from its op arrays.
    """
    from repro._compat import warn_legacy
    from repro.exec.emit import emit_location_source
    from repro.exec.program import lower_system

    warn_legacy(
        "repro.core.compile.emit_python_source(bundle)",
        "repro.exec.emit_location_source(plan.exec_program()[location])",
    )
    system = WorkflowSystem(
        (
            LocationConfig(
                bundle.location, bundle.initial_data, bundle.trace
            ),
        )
    )
    return emit_location_source(lower_system(system)[bundle.location])


def emit_all(w: WorkflowSystem) -> dict[str, str]:
    """Deprecated: per-location sources for a whole system.

    Shim over :func:`repro.exec.emit.emit_program_sources`.
    """
    from repro._compat import warn_legacy
    from repro.exec.emit import emit_program_sources
    from repro.exec.program import lower_system

    warn_legacy(
        "repro.core.compile.emit_all(system)",
        "repro.exec.emit_program_sources(plan.exec_program())",
    )
    return emit_program_sources(lower_system(w))
