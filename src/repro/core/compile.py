"""Bundle compiler — the ``SWIRLCompiler`` layer of the toolchain.

A *bundle* is a self-contained, per-location executable: the location's
execution trace plus the metadata the semantics does not model (step
callables / commands, data payload specs, channel endpoints).  The paper's
reference compiler emits one multithreaded Python program per location over
TCP sockets; here the same separation is kept with two back-ends:

* :class:`LocationBundle` — the in-memory program handed to the
  :mod:`repro.workflow` runtime (threads + in-process channels).  This is the
  faithful decentralised runtime: every location interprets *only its own
  trace*; there is no central orchestrator.
* :func:`emit_python_source` — generates standalone Python source per
  location (the paper's "self-contained workflow execution bundle",
  Research-Object ready), used by the toolchain example and golden tests.

The JAX back-end (lowering location traces onto mesh slices with
``ppermute``-based send/recv) lives in :mod:`repro.launch.bundle_jax` since it
depends on mesh construction.
"""

from __future__ import annotations

import textwrap
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence

from .syntax import (
    Exec,
    Nil,
    Par,
    Recv,
    Send,
    Seq,
    Trace,
    WorkflowSystem,
    actions,
)

# A step function: mapping of input data name -> payload  ->  mapping of
# output data name -> payload.  Pure by contract (same assumption the paper
# inherits from dataflow semantics; it is what makes re-execution-based fault
# tolerance sound).
StepFn = Callable[[Mapping[str, Any]], Mapping[str, Any]]


@dataclass(frozen=True)
class StepMeta:
    """Declarative metadata for one step (the paper's metadata file entry)."""

    fn: StepFn
    inputs: frozenset[str] = frozenset()
    outputs: frozenset[str] = frozenset()
    # Scheduling hints used by the runtime's straggler mitigation:
    expected_seconds: float | None = None
    # Declared byte-size per output datum, consumed by the placement
    # scheduler's payload-size estimator (repro.sched.SizeModel):
    output_bytes: Mapping[str, int] | None = None


@dataclass(frozen=True)
class Channel:
    """A directed (src, dst, port) communication endpoint pair."""

    src: str
    dst: str
    port: str


@dataclass
class LocationBundle:
    """Self-contained executable for one location."""

    location: str
    initial_data: frozenset[str]
    trace: Trace
    steps: dict[str, StepMeta] = field(default_factory=dict)

    def channels(self) -> list[Channel]:
        """Every channel endpoint this bundle communicates over."""
        chans: set[Channel] = set()
        for a in actions(self.trace):
            if isinstance(a, Send):
                chans.add(Channel(a.src, a.dst, a.port))
            elif isinstance(a, Recv):
                chans.add(Channel(a.src, a.dst, a.port))
        return sorted(chans, key=lambda c: (c.src, c.dst, c.port))

    def exec_steps(self) -> list[str]:
        return [a.step for a in actions(self.trace) if isinstance(a, Exec)]


def build_bundles(
    w: WorkflowSystem,
    step_fns: Mapping[str, StepFn],
    *,
    step_meta: Mapping[str, StepMeta] | None = None,
) -> dict[str, LocationBundle]:
    """Compile a workflow system into one bundle per location.

    ``step_fns`` must cover every step executed anywhere in ``w``; a step
    mapped onto several locations (spatial constraint) receives the same
    callable everywhere — the runtime synchronises the exec like the (EXEC)
    rule does.  Canonical entry point used by the backends; the legacy name
    :func:`compile_bundles` is a deprecation shim over it.
    """
    bundles: dict[str, LocationBundle] = {}
    for cfg in w.configs:
        local_steps: dict[str, StepMeta] = {}
        for a in actions(cfg.trace):
            if isinstance(a, Exec):
                if a.step not in step_fns:
                    raise KeyError(f"no step function registered for {a.step!r}")
                meta = (step_meta or {}).get(a.step)
                local_steps[a.step] = meta or StepMeta(
                    fn=step_fns[a.step], inputs=a.inputs, outputs=a.outputs
                )
        bundles[cfg.location] = LocationBundle(
            location=cfg.location,
            initial_data=cfg.data,
            trace=cfg.trace,
            steps=local_steps,
        )
    return bundles


def compile_bundles(
    w: WorkflowSystem,
    step_fns: Mapping[str, StepFn],
    *,
    step_meta: Mapping[str, StepMeta] | None = None,
) -> dict[str, LocationBundle]:
    """Deprecated shim for :func:`build_bundles` (legacy free function)."""
    from repro._compat import warn_legacy

    warn_legacy(
        "repro.core.compile_bundles()",
        'swirl.trace(...).lower("threaded").compile(step_fns)',
    )
    return build_bundles(w, step_fns, step_meta=step_meta)


# ---------------------------------------------------------------------------
# Standalone Python source emission (paper §5's generated bundles)
# ---------------------------------------------------------------------------

_PROGRAM_TEMPLATE = '''\
"""Auto-generated SWIRL bundle for location {location!r}.

Generated by repro.core.compile.emit_python_source — a self-contained,
decentralised executor for this location's trace.  Channels are injected by
the harness as `channels[(src, dst, port)]` queue-like objects with
``put(payload)`` / ``get()``; step commands as `steps[name](inputs) -> outputs`.
"""


def run(channels, steps, initial_data):
    data = dict(initial_data)

{body}
    return data
'''


def _emit_trace(t: Trace, indent: int, uid: list[int]) -> str:
    pad = "    " * indent

    if isinstance(t, Nil):
        return f"{pad}pass\n"
    if isinstance(t, Exec):
        ins = sorted(t.inputs)
        outs = sorted(t.outputs)
        return (
            f"{pad}_out = steps[{t.step!r}]({{k: data[k] for k in {ins!r}}})\n"
            f"{pad}data.update({{k: _out[k] for k in {outs!r}}})\n"
        )
    if isinstance(t, Send):
        return (
            f"{pad}channels[({t.src!r}, {t.dst!r}, {t.port!r})]"
            f".put(({t.data!r}, data[{t.data!r}]))\n"
        )
    if isinstance(t, Recv):
        return (
            f"{pad}_k, _v = channels[({t.src!r}, {t.dst!r}, {t.port!r})].get()\n"
            f"{pad}data[_k] = _v\n"
        )
    if isinstance(t, Seq):
        return "".join(_emit_trace(i, indent, uid) for i in t.items)
    if isinstance(t, Par):
        # Parallel branches become threads — the generated program is
        # multithreaded exactly like the reference implementation's output.
        uid[0] += 1
        gid = uid[0]
        lines = [f"{pad}import threading as _th_{gid}\n"]
        names = []
        for bi, b in enumerate(t.branches):
            fname = f"_branch_{gid}_{bi}"
            names.append(fname)
            lines.append(f"{pad}def {fname}():\n")
            lines.append(_emit_trace(b, indent + 1, uid))
        lines.append(
            f"{pad}_ts_{gid} = [_th_{gid}.Thread(target=f) for f in [{', '.join(names)}]]\n"
        )
        lines.append(f"{pad}[t.start() for t in _ts_{gid}]\n")
        lines.append(f"{pad}[t.join() for t in _ts_{gid}]\n")
        return "".join(lines)
    raise TypeError(f"not a trace: {t!r}")


def emit_python_source(bundle: LocationBundle) -> str:
    """Emit a standalone Python program for one location's trace."""
    body = _emit_trace(bundle.trace, indent=1, uid=[0])
    return _PROGRAM_TEMPLATE.format(location=bundle.location, body=body)


def emit_all(w: WorkflowSystem) -> dict[str, str]:
    """Emit per-location sources for a whole system (no step fns needed)."""
    out = {}
    for cfg in w.configs:
        b = LocationBundle(cfg.location, cfg.data, cfg.trace)
        out[cfg.location] = emit_python_source(b)
    return out
