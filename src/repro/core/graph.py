"""Workflow graph model — Definitions 1-7 of the SWIRL paper.

A workflow is a directed bipartite graph ``W = (S, P, D)`` of *steps* and
*ports* (Def. 1).  A *workflow instance* adds data elements and their port
placement (Def. 3).  A *distributed workflow* adds locations and a step ->
locations mapping (Def. 5); an *instance* of it carries both (Def. 7).

All containers are immutable once constructed (tuples / frozensets) so that
graphs can be hashed, compared and safely shared between the encoder, the
optimiser and the runtime scheduler.

Accessor complexity: ``In``/``Out`` projections and the data/port lookups
are served from lazily-built adjacency indexes (one linear pass over the
dependency relation, cached on the instance), so encoding and scheduling
stay linear in workflow size — the original per-call relation scans made
``⟦·⟧`` quadratic and 10k-step plans intractable.  Immutability makes the
caches safe: every ``dataclasses.replace`` produces a fresh instance with
fresh (empty) caches.
"""

from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass, field
from typing import Iterable, Mapping, Sequence


def _fset(xs: Iterable[str]) -> frozenset[str]:
    return frozenset(xs)


_EMPTY: frozenset[str] = frozenset()


@dataclass(frozen=True)
class Workflow:
    """Def. 1 — ``W = (S, P, D)`` with ``D ⊆ (S×P) ∪ (P×S)``."""

    steps: frozenset[str]
    ports: frozenset[str]
    deps: frozenset[tuple[str, str]]

    def __post_init__(self) -> None:
        if self.steps & self.ports:
            raise ValueError(
                f"steps and ports must be disjoint: {sorted(self.steps & self.ports)}"
            )
        for a, b in self.deps:
            s2p = a in self.steps and b in self.ports
            p2s = a in self.ports and b in self.steps
            if not (s2p or p2s):
                raise ValueError(f"dependency {(a, b)} is not (S×P) ∪ (P×S)")

    # -- adjacency indexes (lazy, cached; fields are immutable) -------------
    def _adjacency(self) -> dict[str, dict[str, frozenset[str]]]:
        idx = self.__dict__.get("_adj")
        if idx is None:
            in_ports: dict[str, set[str]] = {}
            out_ports: dict[str, set[str]] = {}
            in_steps: dict[str, set[str]] = {}
            out_steps: dict[str, set[str]] = {}
            steps, ports = self.steps, self.ports
            for a, b in self.deps:
                if a in steps:  # (s, p)
                    out_ports.setdefault(a, set()).add(b)
                    in_steps.setdefault(b, set()).add(a)
                else:  # (p, s)
                    in_ports.setdefault(b, set()).add(a)
                    out_steps.setdefault(a, set()).add(b)
            idx = {
                "in_ports": {k: _fset(v) for k, v in in_ports.items()},
                "out_ports": {k: _fset(v) for k, v in out_ports.items()},
                "in_steps": {k: _fset(v) for k, v in in_steps.items()},
                "out_steps": {k: _fset(v) for k, v in out_steps.items()},
            }
            object.__setattr__(self, "_adj", idx)
        return idx

    # -- Def. 2 ------------------------------------------------------------
    def in_ports(self, s: str) -> frozenset[str]:
        """``In(s) = {p | (p, s) ∈ D}``."""
        return self._adjacency()["in_ports"].get(s, _EMPTY)

    def out_ports(self, s: str) -> frozenset[str]:
        """``Out(s) = {p | (s, p) ∈ D}``."""
        return self._adjacency()["out_ports"].get(s, _EMPTY)

    def in_steps(self, p: str) -> frozenset[str]:
        """``In(p) = {s | (s, p) ∈ D}`` — the producers of port ``p``."""
        return self._adjacency()["in_steps"].get(p, _EMPTY)

    def out_steps(self, p: str) -> frozenset[str]:
        """``Out(p) = {s | (p, s) ∈ D}`` — the consumers of port ``p``."""
        return self._adjacency()["out_steps"].get(p, _EMPTY)

    # -- helpers ------------------------------------------------------------
    def initial_ports(self) -> frozenset[str]:
        """Ports with no producing step (workflow inputs, cf. App. B ``s_0``)."""
        return _fset(p for p in self.ports if not self.in_steps(p))

    def topological_steps(self) -> tuple[str, ...]:
        """Steps in a deterministic topological order (raises on cycles).

        Cached: every ``work_queue`` projection reuses one traversal.
        """
        cached = self.__dict__.get("_topo")
        if cached is not None:
            return cached
        # In-degree counts *distinct* upstream steps (a producer feeding a
        # consumer through several ports is still one completion event) —
        # counting per (port, producer) pair would leave the consumer's
        # counter positive forever and misreport an acyclic DAG as cyclic.
        indeg = {s: 0 for s in self.steps}
        for s in self.steps:
            ups: set[str] = set()
            for p in self.in_ports(s):
                ups |= self.in_steps(p)
            indeg[s] = len(ups)
        order: list[str] = []
        ready = [s for s, d in indeg.items() if d == 0]
        heapq.heapify(ready)
        seen: set[str] = set()
        while ready:
            s = heapq.heappop(ready)
            order.append(s)
            seen.add(s)
            nxt: set[str] = set()
            for p in self.out_ports(s):
                nxt |= self.out_steps(p)
            for t in nxt:
                indeg[t] -= 1
                if indeg[t] == 0 and t not in seen:
                    heapq.heappush(ready, t)
        if len(order) != len(self.steps):
            raise ValueError("workflow graph contains a cycle")
        out = tuple(order)
        object.__setattr__(self, "_topo", out)
        return out


def make_workflow(
    steps: Iterable[str],
    ports: Iterable[str],
    deps: Iterable[tuple[str, str]],
) -> Workflow:
    return Workflow(_fset(steps), _fset(ports), frozenset(tuple(d) for d in deps))


@dataclass(frozen=True)
class WorkflowInstance:
    """Def. 3 — ``(W, D, I)`` with ``I ⊆ D×P`` mapping data to its port.

    ``placement`` maps each data element to the single port containing it
    (the paper treats ``I`` as a relation; every example places each data
    element on exactly one port, which is what we enforce).
    """

    workflow: Workflow
    data: frozenset[str]
    placement: Mapping[str, str]  # d -> p

    def __post_init__(self) -> None:
        object.__setattr__(self, "placement", dict(self.placement))
        for d, p in self.placement.items():
            if d not in self.data:
                raise ValueError(f"placement references unknown data {d!r}")
            if p not in self.workflow.ports:
                raise ValueError(f"placement references unknown port {p!r}")
        missing = self.data - set(self.placement)
        if missing:
            raise ValueError(f"data without a port: {sorted(missing)}")

    def _port_index(self) -> dict[str, frozenset[str]]:
        idx = self.__dict__.get("_by_port")
        if idx is None:
            by_port: dict[str, set[str]] = {}
            for d, p in self.placement.items():
                by_port.setdefault(p, set()).add(d)
            idx = {p: _fset(ds) for p, ds in by_port.items()}
            object.__setattr__(self, "_by_port", idx)
        return idx

    def port_of(self, d: str) -> str:
        """``I(d)`` — the port holding data element ``d``."""
        return self.placement[d]

    def data_on(self, p: str) -> frozenset[str]:
        return self._port_index().get(p, _EMPTY)

    # -- Def. 4 ------------------------------------------------------------
    def in_data(self, s: str) -> frozenset[str]:
        """``In^D(s) = {d | (d, p) ∈ I ∧ p ∈ In(s)}``."""
        by_port = self._port_index()
        out: frozenset[str] = _EMPTY
        for p in self.workflow.in_ports(s):
            out = out | by_port.get(p, _EMPTY)
        return out

    def out_data(self, s: str) -> frozenset[str]:
        """``Out^D(s) = {d | (d, p) ∈ I ∧ p ∈ Out(s)}``."""
        by_port = self._port_index()
        out: frozenset[str] = _EMPTY
        for p in self.workflow.out_ports(s):
            out = out | by_port.get(p, _EMPTY)
        return out


@dataclass(frozen=True)
class DistributedWorkflow:
    """Def. 5 — ``(W, L, M)`` with ``M ⊆ S×L``."""

    workflow: Workflow
    locations: frozenset[str]
    mapping: Mapping[str, tuple[str, ...]]  # s -> locations (deterministic order)

    def __post_init__(self) -> None:
        norm = {s: tuple(ls) for s, ls in dict(self.mapping).items()}
        object.__setattr__(self, "mapping", norm)
        for s, ls in norm.items():
            if s not in self.workflow.steps:
                raise ValueError(f"mapping references unknown step {s!r}")
            if not ls:
                raise ValueError(f"step {s!r} mapped to no location")
            for l in ls:
                if l not in self.locations:
                    raise ValueError(f"mapping references unknown location {l!r}")
        unmapped = self.workflow.steps - set(norm)
        if unmapped:
            raise ValueError(f"steps without a location: {sorted(unmapped)}")

    def locs_of(self, s: str) -> tuple[str, ...]:
        """``M(s)``."""
        return self.mapping[s]

    # -- Def. 6 ------------------------------------------------------------
    def work_queue(self, l: str) -> tuple[str, ...]:
        """``Q(l) = {s | l ∈ M(s)}`` in deterministic (topological) order."""
        queues = self.__dict__.get("_queues")
        if queues is None:
            queues = {loc: [] for loc in self.locations}
            for s in self.workflow.topological_steps():
                for loc in self.mapping[s]:
                    queues[loc].append(s)
            queues = {loc: tuple(q) for loc, q in queues.items()}
            object.__setattr__(self, "_queues", queues)
        return queues[l]


@dataclass(frozen=True)
class DistributedWorkflowInstance:
    """Def. 7 — ``I = (W, L, M, D, I)``.

    ``initial_data`` optionally records the instance data distribution
    ``G(l)`` (Sec. 3.2): which data elements are already resident on each
    location before execution starts (e.g. the driver's inputs).
    """

    workflow: Workflow
    locations: frozenset[str]
    mapping: Mapping[str, tuple[str, ...]]
    data: frozenset[str]
    placement: Mapping[str, str]
    initial_data: Mapping[str, frozenset[str]] = field(default_factory=dict)

    def __post_init__(self) -> None:
        # Delegate validation to the component models.
        object.__setattr__(self, "mapping", dict(self.mapping))
        object.__setattr__(self, "placement", dict(self.placement))
        object.__setattr__(
            self,
            "initial_data",
            {l: frozenset(ds) for l, ds in dict(self.initial_data).items()},
        )
        # Validate through the component models and keep them: the
        # ``distributed``/``instance`` projections (and everything routed
        # through them — work queues, In^D/Out^D) are served from these
        # cached views instead of re-validating per call.
        object.__setattr__(
            self,
            "_distributed",
            DistributedWorkflow(self.workflow, self.locations, self.mapping),
        )
        object.__setattr__(
            self,
            "_instance",
            WorkflowInstance(self.workflow, self.data, self.placement),
        )
        for l, ds in self.initial_data.items():
            if l not in self.locations:
                raise ValueError(f"initial data on unknown location {l!r}")
            if not ds <= self.data:
                raise ValueError(f"unknown initial data on {l!r}: {sorted(ds - self.data)}")

    # Convenience projections -------------------------------------------------
    @property
    def distributed(self) -> DistributedWorkflow:
        return self._distributed  # type: ignore[attr-defined]

    @property
    def instance(self) -> WorkflowInstance:
        return self._instance  # type: ignore[attr-defined]

    def locs_of(self, s: str) -> tuple[str, ...]:
        return self.mapping[s]

    def work_queue(self, l: str) -> tuple[str, ...]:
        return self.distributed.work_queue(l)

    def port_of(self, d: str) -> str:
        return self.placement[d]

    def _memo(self, name: str, key: str, compute) -> frozenset[str]:
        cache = self.__dict__.get(name)
        if cache is None:
            cache = {}
            object.__setattr__(self, name, cache)
        hit = cache.get(key)
        if hit is None:
            hit = cache[key] = compute(key)
        return hit

    def in_data(self, s: str) -> frozenset[str]:
        return self._memo("_in_data", s, self.instance.in_data)

    def out_data(self, s: str) -> frozenset[str]:
        return self._memo("_out_data", s, self.instance.out_data)

    def producers_of_data(self, d: str) -> frozenset[str]:
        """``In(I(d))`` — steps producing the port that holds ``d``."""
        return self.workflow.in_steps(self.placement[d])

    def consumers_of_data(self, d: str) -> frozenset[str]:
        """``Out(I(d))`` — steps consuming the port that holds ``d``."""
        return self.workflow.out_steps(self.placement[d])

    def g(self, l: str) -> frozenset[str]:
        """``G(l)`` — instance data initially resident on ``l``."""
        return self.initial_data.get(l, frozenset())

    def with_initial_data(
        self, initial: Mapping[str, Iterable[str]]
    ) -> "DistributedWorkflowInstance":
        return dataclasses.replace(
            self, initial_data={l: frozenset(ds) for l, ds in initial.items()}
        )
