"""Workflow graph model — Definitions 1-7 of the SWIRL paper.

A workflow is a directed bipartite graph ``W = (S, P, D)`` of *steps* and
*ports* (Def. 1).  A *workflow instance* adds data elements and their port
placement (Def. 3).  A *distributed workflow* adds locations and a step ->
locations mapping (Def. 5); an *instance* of it carries both (Def. 7).

All containers are immutable once constructed (tuples / frozensets) so that
graphs can be hashed, compared and safely shared between the encoder, the
optimiser and the runtime scheduler.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Iterable, Mapping, Sequence


def _fset(xs: Iterable[str]) -> frozenset[str]:
    return frozenset(xs)


@dataclass(frozen=True)
class Workflow:
    """Def. 1 — ``W = (S, P, D)`` with ``D ⊆ (S×P) ∪ (P×S)``."""

    steps: frozenset[str]
    ports: frozenset[str]
    deps: frozenset[tuple[str, str]]

    def __post_init__(self) -> None:
        if self.steps & self.ports:
            raise ValueError(
                f"steps and ports must be disjoint: {sorted(self.steps & self.ports)}"
            )
        for a, b in self.deps:
            s2p = a in self.steps and b in self.ports
            p2s = a in self.ports and b in self.steps
            if not (s2p or p2s):
                raise ValueError(f"dependency {(a, b)} is not (S×P) ∪ (P×S)")

    # -- Def. 2 ------------------------------------------------------------
    def in_ports(self, s: str) -> frozenset[str]:
        """``In(s) = {p | (p, s) ∈ D}``."""
        return _fset(p for (p, s2) in self.deps if s2 == s and p in self.ports)

    def out_ports(self, s: str) -> frozenset[str]:
        """``Out(s) = {p | (s, p) ∈ D}``."""
        return _fset(p for (s2, p) in self.deps if s2 == s and p in self.ports)

    def in_steps(self, p: str) -> frozenset[str]:
        """``In(p) = {s | (s, p) ∈ D}`` — the producers of port ``p``."""
        return _fset(s for (s, p2) in self.deps if p2 == p and s in self.steps)

    def out_steps(self, p: str) -> frozenset[str]:
        """``Out(p) = {s | (p, s) ∈ D}`` — the consumers of port ``p``."""
        return _fset(s for (p2, s) in self.deps if p2 == p and s in self.steps)

    # -- helpers ------------------------------------------------------------
    def initial_ports(self) -> frozenset[str]:
        """Ports with no producing step (workflow inputs, cf. App. B ``s_0``)."""
        return _fset(p for p in self.ports if not self.in_steps(p))

    def topological_steps(self) -> tuple[str, ...]:
        """Steps in a deterministic topological order (raises on cycles)."""
        indeg = {s: 0 for s in self.steps}
        for s in self.steps:
            for p in self.in_ports(s):
                indeg[s] += len(self.in_steps(p))
        order: list[str] = []
        ready = sorted(s for s, d in indeg.items() if d == 0)
        seen: set[str] = set()
        while ready:
            s = ready.pop(0)
            order.append(s)
            seen.add(s)
            nxt: set[str] = set()
            for p in self.out_ports(s):
                nxt |= self.out_steps(p)
            for t in sorted(nxt):
                indeg[t] -= 1
                if indeg[t] == 0 and t not in seen:
                    ready.append(t)
            ready.sort()
        if len(order) != len(self.steps):
            raise ValueError("workflow graph contains a cycle")
        return tuple(order)


def make_workflow(
    steps: Iterable[str],
    ports: Iterable[str],
    deps: Iterable[tuple[str, str]],
) -> Workflow:
    return Workflow(_fset(steps), _fset(ports), frozenset(tuple(d) for d in deps))


@dataclass(frozen=True)
class WorkflowInstance:
    """Def. 3 — ``(W, D, I)`` with ``I ⊆ D×P`` mapping data to its port.

    ``placement`` maps each data element to the single port containing it
    (the paper treats ``I`` as a relation; every example places each data
    element on exactly one port, which is what we enforce).
    """

    workflow: Workflow
    data: frozenset[str]
    placement: Mapping[str, str]  # d -> p

    def __post_init__(self) -> None:
        object.__setattr__(self, "placement", dict(self.placement))
        for d, p in self.placement.items():
            if d not in self.data:
                raise ValueError(f"placement references unknown data {d!r}")
            if p not in self.workflow.ports:
                raise ValueError(f"placement references unknown port {p!r}")
        missing = self.data - set(self.placement)
        if missing:
            raise ValueError(f"data without a port: {sorted(missing)}")

    def port_of(self, d: str) -> str:
        """``I(d)`` — the port holding data element ``d``."""
        return self.placement[d]

    def data_on(self, p: str) -> frozenset[str]:
        return _fset(d for d, p2 in self.placement.items() if p2 == p)

    # -- Def. 4 ------------------------------------------------------------
    def in_data(self, s: str) -> frozenset[str]:
        """``In^D(s) = {d | (d, p) ∈ I ∧ p ∈ In(s)}``."""
        ins = self.workflow.in_ports(s)
        return _fset(d for d, p in self.placement.items() if p in ins)

    def out_data(self, s: str) -> frozenset[str]:
        """``Out^D(s) = {d | (d, p) ∈ I ∧ p ∈ Out(s)}``."""
        outs = self.workflow.out_ports(s)
        return _fset(d for d, p in self.placement.items() if p in outs)


@dataclass(frozen=True)
class DistributedWorkflow:
    """Def. 5 — ``(W, L, M)`` with ``M ⊆ S×L``."""

    workflow: Workflow
    locations: frozenset[str]
    mapping: Mapping[str, tuple[str, ...]]  # s -> locations (deterministic order)

    def __post_init__(self) -> None:
        norm = {s: tuple(ls) for s, ls in dict(self.mapping).items()}
        object.__setattr__(self, "mapping", norm)
        for s, ls in norm.items():
            if s not in self.workflow.steps:
                raise ValueError(f"mapping references unknown step {s!r}")
            if not ls:
                raise ValueError(f"step {s!r} mapped to no location")
            for l in ls:
                if l not in self.locations:
                    raise ValueError(f"mapping references unknown location {l!r}")
        unmapped = self.workflow.steps - set(norm)
        if unmapped:
            raise ValueError(f"steps without a location: {sorted(unmapped)}")

    def locs_of(self, s: str) -> tuple[str, ...]:
        """``M(s)``."""
        return self.mapping[s]

    # -- Def. 6 ------------------------------------------------------------
    def work_queue(self, l: str) -> tuple[str, ...]:
        """``Q(l) = {s | l ∈ M(s)}`` in deterministic (topological) order."""
        topo = self.workflow.topological_steps()
        return tuple(s for s in topo if l in self.mapping[s])


@dataclass(frozen=True)
class DistributedWorkflowInstance:
    """Def. 7 — ``I = (W, L, M, D, I)``.

    ``initial_data`` optionally records the instance data distribution
    ``G(l)`` (Sec. 3.2): which data elements are already resident on each
    location before execution starts (e.g. the driver's inputs).
    """

    workflow: Workflow
    locations: frozenset[str]
    mapping: Mapping[str, tuple[str, ...]]
    data: frozenset[str]
    placement: Mapping[str, str]
    initial_data: Mapping[str, frozenset[str]] = field(default_factory=dict)

    def __post_init__(self) -> None:
        # Delegate validation to the component models.
        object.__setattr__(self, "mapping", dict(self.mapping))
        object.__setattr__(self, "placement", dict(self.placement))
        object.__setattr__(
            self,
            "initial_data",
            {l: frozenset(ds) for l, ds in dict(self.initial_data).items()},
        )
        DistributedWorkflow(self.workflow, self.locations, self.mapping)
        WorkflowInstance(self.workflow, self.data, self.placement)
        for l, ds in self.initial_data.items():
            if l not in self.locations:
                raise ValueError(f"initial data on unknown location {l!r}")
            if not ds <= self.data:
                raise ValueError(f"unknown initial data on {l!r}: {sorted(ds - self.data)}")

    # Convenience projections -------------------------------------------------
    @property
    def distributed(self) -> DistributedWorkflow:
        return DistributedWorkflow(self.workflow, self.locations, self.mapping)

    @property
    def instance(self) -> WorkflowInstance:
        return WorkflowInstance(self.workflow, self.data, self.placement)

    def locs_of(self, s: str) -> tuple[str, ...]:
        return self.mapping[s]

    def work_queue(self, l: str) -> tuple[str, ...]:
        return self.distributed.work_queue(l)

    def port_of(self, d: str) -> str:
        return self.placement[d]

    def in_data(self, s: str) -> frozenset[str]:
        return self.instance.in_data(s)

    def out_data(self, s: str) -> frozenset[str]:
        return self.instance.out_data(s)

    def producers_of_data(self, d: str) -> frozenset[str]:
        """``In(I(d))`` — steps producing the port that holds ``d``."""
        return self.workflow.in_steps(self.placement[d])

    def consumers_of_data(self, d: str) -> frozenset[str]:
        """``Out(I(d))`` — steps consuming the port that holds ``d``."""
        return self.workflow.out_steps(self.placement[d])

    def g(self, l: str) -> frozenset[str]:
        """``G(l)`` — instance data initially resident on ``l``."""
        return self.initial_data.get(l, frozenset())

    def with_initial_data(
        self, initial: Mapping[str, Iterable[str]]
    ) -> "DistributedWorkflowInstance":
        return dataclasses.replace(
            self, initial_data={l: frozenset(ds) for l, ds in initial.items()}
        )
