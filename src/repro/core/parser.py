"""``.swirl`` surface syntax — tokenizer + recursive-descent parser.

The paper's reference toolchain uses ANTLR-generated Python3 parsers; ANTLR is
unavailable offline, so the same surface grammar is implemented by hand.  The
grammar below round-trips exactly the ``pretty()`` form of
:mod:`repro.core.syntax`::

    system  := config ("|" config)*
    config  := "<" NAME "," dataset "," trace ">"
    dataset := "{" [NAME ("," NAME)*] "}"
    trace   := par
    par     := seqe ("|" seqe)*
    seqe    := term ("." term)*
    term    := "0" | action | "(" trace ")"
    action  := "exec" "(" NAME "," dataset "->" dataset "," "{" names "}" ")"
             | "send" "(" NAME "->" NAME "," NAME "," NAME ")"
             | "recv" "(" NAME "," NAME "," NAME ")"

Identifiers are ``[A-Za-z0-9_^$]+`` (no dots — ``.`` is sequential
composition).  ``#`` starts a line comment.  Whitespace is insignificant.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

from .syntax import (
    NIL,
    Exec,
    LocationConfig,
    Recv,
    Send,
    Trace,
    WorkflowSystem,
    par,
    seq,
)

_TOKEN_RE = re.compile(
    r"""
    (?P<ws>\s+|\#[^\n]*)
  | (?P<arrow>->)
  | (?P<punct>[<>(){},.|])
  | (?P<name>[A-Za-z0-9_^$]+)
    """,
    re.VERBOSE,
)


class SwirlSyntaxError(ValueError):
    """Raised on malformed ``.swirl`` input, with position info.

    Carries structured location attributes so front ends (the HTTP gateway,
    editors) can point at the offending character without re-parsing the
    message: ``offset`` is the 0-based character offset into the source,
    ``line``/``column`` are 1-based when the source is known (``None``
    otherwise).
    """

    def __init__(
        self,
        message: str,
        *,
        offset: int | None = None,
        line: int | None = None,
        column: int | None = None,
    ):
        super().__init__(message)
        self.offset = offset
        self.line = line
        self.column = column


def _line_col(src: str, offset: int) -> tuple[int, int]:
    """1-based (line, column) of character ``offset`` in ``src``."""
    offset = min(max(offset, 0), len(src))
    line = src.count("\n", 0, offset) + 1
    column = offset - (src.rfind("\n", 0, offset) + 1) + 1
    return line, column


def _syntax_error(src: str, message: str, offset: int) -> SwirlSyntaxError:
    line, column = _line_col(src, offset)
    return SwirlSyntaxError(
        f"{message} at line {line}, column {column}",
        offset=offset,
        line=line,
        column=column,
    )


@dataclass
class _Tok:
    kind: str  # 'arrow' | 'punct' | 'name' | 'eof'
    text: str
    pos: int


def tokenize(src: str) -> list[_Tok]:
    toks: list[_Tok] = []
    i = 0
    while i < len(src):
        m = _TOKEN_RE.match(src, i)
        if not m:
            raise _syntax_error(src, f"unexpected character {src[i]!r}", i)
        i = m.end()
        kind = m.lastgroup or ""
        if kind == "ws":
            continue
        toks.append(_Tok(kind, m.group(), m.start()))
    toks.append(_Tok("eof", "", len(src)))
    return toks


class _Parser:
    def __init__(self, src: str):
        self.src = src
        self.toks = tokenize(src)
        self.i = 0

    # -- token helpers -------------------------------------------------------
    def peek(self) -> _Tok:
        return self.toks[self.i]

    def next(self) -> _Tok:
        t = self.toks[self.i]
        self.i += 1
        return t

    def error(self, message: str, pos: int) -> SwirlSyntaxError:
        return _syntax_error(self.src, message, pos)

    def expect(self, text: str) -> _Tok:
        t = self.next()
        if t.text != text:
            raise self.error(
                f"expected {text!r} but found {t.text or 'EOF'!r}", t.pos
            )
        return t

    def name(self) -> str:
        t = self.next()
        if t.kind != "name":
            raise self.error(
                f"expected identifier but found {t.text or 'EOF'!r}", t.pos
            )
        return t.text

    # -- grammar -------------------------------------------------------------
    def system(self) -> WorkflowSystem:
        configs = [self.config()]
        while self.peek().text == "|":
            self.next()
            configs.append(self.config())
        if self.peek().kind != "eof":
            t = self.peek()
            raise self.error(f"trailing input {t.text!r}", t.pos)
        return WorkflowSystem(tuple(configs))

    def config(self) -> LocationConfig:
        self.expect("<")
        loc = self.name()
        self.expect(",")
        data = self.dataset()
        self.expect(",")
        trace = self.par()
        self.expect(">")
        return LocationConfig(loc, data, trace)

    def dataset(self) -> frozenset[str]:
        self.expect("{")
        items: list[str] = []
        if self.peek().text != "}":
            items.append(self.name())
            while self.peek().text == ",":
                self.next()
                items.append(self.name())
        self.expect("}")
        return frozenset(items)

    def par(self) -> Trace:
        branches = [self.seqe()]
        while self.peek().text == "|":
            self.next()
            branches.append(self.seqe())
        return par(*branches)

    def seqe(self) -> Trace:
        items = [self.term()]
        while self.peek().text == ".":
            self.next()
            items.append(self.term())
        return seq(*items)

    def term(self) -> Trace:
        t = self.peek()
        if t.text == "(":
            self.next()
            inner = self.par()
            self.expect(")")
            return inner
        if t.text == "0":
            self.next()
            return NIL
        if t.text in ("exec", "send", "recv"):
            return self.action()
        raise self.error(
            f"expected a trace term but found {t.text or 'EOF'!r}", t.pos
        )

    def action(self) -> Trace:
        kw_pos = self.peek().pos
        kw = self.name()
        self.expect("(")
        if kw == "exec":
            step = self.name()
            self.expect(",")
            ins = self.dataset()
            self.expect("->")
            outs = self.dataset()
            self.expect(",")
            self.expect("{")
            locs: list[str] = []
            if self.peek().text != "}":
                locs.append(self.name())
                while self.peek().text == ",":
                    self.next()
                    locs.append(self.name())
            self.expect("}")
            self.expect(")")
            return Exec(step, ins, outs, tuple(locs))
        if kw == "send":
            d = self.name()
            self.expect("->")
            p = self.name()
            self.expect(",")
            src = self.name()
            self.expect(",")
            dst = self.name()
            self.expect(")")
            return Send(d, p, src, dst)
        if kw == "recv":
            p = self.name()
            self.expect(",")
            src = self.name()
            self.expect(",")
            dst = self.name()
            self.expect(")")
            return Recv(p, src, dst)
        raise self.error(f"unknown action {kw!r}", kw_pos)


def parse_system(src: str) -> WorkflowSystem:
    """Parse a full ``.swirl`` workflow system."""
    return _Parser(src).system()


def parse_trace(src: str) -> Trace:
    """Parse a bare execution trace (used in tests and the REPL)."""
    p = _Parser(src)
    t = p.par()
    if p.peek().kind != "eof":
        tok = p.peek()
        raise p.error(f"trailing input {tok.text!r}", tok.pos)
    return t


def dumps(w: WorkflowSystem) -> str:
    """Emit the canonical ``.swirl`` text (inverse of :func:`parse_system`)."""
    return " |\n".join(c.pretty() for c in w.configs)


def loads(src: str) -> WorkflowSystem:
    return parse_system(src)
