"""SWIRL reduction semantics — Figs. 2 and 3 of the paper.

The semantics is implemented as an explicit labelled transition system over
:class:`~repro.core.syntax.WorkflowSystem` states:

* ``(EXEC)``   — synchronised execution of a step across all of ``M(s)``;
  enabled when every involved location has an *active* ``exec(s, ...)``
  occurrence and ``In^D(s) ⊆ D_i`` on each.  Adds ``Out^D(s)`` everywhere.
* ``(COMM)``   — matching active ``send(d↣p,l,l')`` / ``recv(p,l,l')`` with
  ``d ∈ D_l``; *copies* ``d`` into ``D_{l'}`` (data is never consumed).
* ``(L-COMM)`` — the ``l = l'`` case of the above.
* ``(L-PAR) / (SEQ) / (PAR) / (CONGR)`` — realised structurally by the notion
  of *active occurrence*: an action is active iff it is not guarded by an
  unfinished sequential prefix.  This is exactly the closure of the four
  context rules over the congruence of Fig. 2.

Transitions carry labels used by the bisimulation checker: ``exec`` labels
are observable barbs ``ν``; communications are silent ``τ`` actions (Sec. 4).
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Callable, Iterator, Optional

from .syntax import (
    NIL,
    Action,
    Exec,
    LocationConfig,
    Nil,
    Par,
    Recv,
    Send,
    Seq,
    Trace,
    WorkflowSystem,
    is_action,
    normalize,
    par,
    seq,
)

# An *occurrence* of an active action inside a trace: the action itself plus
# a rebuild function that returns the whole trace with this occurrence
# replaced by an arbitrary sub-trace (``NIL`` to consume it).
Occurrence = tuple[Action, Callable[[Trace], Trace]]


def active_occurrences(t: Trace) -> list[Occurrence]:
    """All action occurrences executable *now* (not sequentially guarded)."""
    if is_action(t):
        act: Action = t  # type: ignore[assignment]
        return [(act, lambda new: new)]
    if isinstance(t, Nil):
        return []
    if isinstance(t, Seq):
        if not t.items:
            return []
        head, rest = t.items[0], t.items[1:]
        out: list[Occurrence] = []
        for act, rebuild in active_occurrences(head):
            out.append(
                (act, lambda new, rb=rebuild: seq(rb(new), *rest))
            )
        return out
    if isinstance(t, Par):
        out = []
        for i, b in enumerate(t.branches):
            others_before = t.branches[:i]
            others_after = t.branches[i + 1 :]
            for act, rebuild in active_occurrences(b):
                out.append(
                    (
                        act,
                        lambda new, rb=rebuild, ob=others_before, oa=others_after: par(
                            *ob, rb(new), *oa
                        ),
                    )
                )
        return out
    raise TypeError(f"not a trace: {t!r}")


# ---------------------------------------------------------------------------
# Transitions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ExecTransition:
    """(EXEC): one active ``exec(s,...)`` occurrence per involved location."""

    step: str
    action: Exec
    # location -> index into active_occurrences of that location's trace
    picks: tuple[tuple[str, int], ...]

    @property
    def label(self) -> tuple:
        return ("exec", self.action.step, self.action.inputs, self.action.outputs,
                self.action.locations)

    @property
    def is_tau(self) -> bool:
        return False


@dataclass(frozen=True)
class CommTransition:
    """(COMM)/(L-COMM): matching send/recv occurrence pair."""

    send: Send
    src_pick: int  # occurrence index in the source location's trace
    dst_pick: int  # occurrence index in the destination location's trace

    @property
    def label(self) -> tuple:
        return ("tau", self.send.data, self.send.port, self.send.src, self.send.dst)

    @property
    def is_tau(self) -> bool:
        return True


Transition = ExecTransition | CommTransition


def enabled_transitions(w: WorkflowSystem) -> list[Transition]:
    """Enumerate every transition enabled in ``w`` (Fig. 3 premises)."""
    occs = {c.location: active_occurrences(c.trace) for c in w.configs}
    data = {c.location: c.data for c in w.configs}
    out: list[Transition] = []

    # (EXEC) — for each step with an active exec occurrence somewhere, check
    # every location of M(s) has one and the input data is resident.
    exec_sites: dict[tuple[str, Exec], dict[str, list[int]]] = {}
    for l, lst in occs.items():
        for i, (act, _) in enumerate(lst):
            if isinstance(act, Exec):
                exec_sites.setdefault((act.step, act), {}).setdefault(l, []).append(i)
    for (step, act), sites in exec_sites.items():
        locs = act.locations
        if not all(l in sites for l in locs):
            continue  # some involved location is not ready to synchronise
        if not all(act.inputs <= data[l] for l in locs):
            continue  # In^D(s) ⊄ D_i
        # Pick the first active occurrence on each location (other picks lead
        # to congruent states because occurrences of the same exec predicate
        # are interchangeable).
        picks = tuple((l, sites[l][0]) for l in locs)
        out.append(ExecTransition(step, act, picks))

    # (COMM) / (L-COMM) — match send with a recv on (port, src, dst).
    for l, lst in occs.items():
        for i, (act, _) in enumerate(lst):
            if not isinstance(act, Send):
                continue
            if act.data not in data[l] or act.src != l:
                continue
            dst_list = occs.get(act.dst, [])
            for j, (ract, _) in enumerate(dst_list):
                if (
                    isinstance(ract, Recv)
                    and ract.port == act.port
                    and ract.src == act.src
                    and ract.dst == act.dst
                ):
                    out.append(CommTransition(act, i, j))
                    break  # matching any one recv occurrence is enough
    return out


def apply_transition(w: WorkflowSystem, t: Transition) -> WorkflowSystem:
    """Apply one reduction ``W → W'``."""
    occs = {c.location: active_occurrences(c.trace) for c in w.configs}
    if isinstance(t, ExecTransition):
        new = w
        for l, idx in t.picks:
            act, rebuild = occs[l][idx]
            assert isinstance(act, Exec) and act == t.action
            cfg = new[l]
            new = new.replace(
                l, data=cfg.data | t.action.outputs, trace=rebuild(NIL)
            )
        return new
    if isinstance(t, CommTransition):
        s = t.send
        if s.src == s.dst:
            # (L-COMM): consume both occurrences within the same location.
            lst = occs[s.src]
            sact, srebuild = lst[t.src_pick]
            # Rebuild send first, then locate the recv in the *new* trace.
            trace1 = srebuild(NIL)
            lst1 = active_occurrences(trace1)
            # find matching recv occurrence again
            for ract, rrebuild in lst1:
                if (
                    isinstance(ract, Recv)
                    and ract.port == s.port
                    and ract.src == s.src
                    and ract.dst == s.dst
                ):
                    cfg = w[s.src]
                    return w.replace(s.src, data=cfg.data | {s.data},
                                     trace=rrebuild(NIL))
            raise RuntimeError("L-COMM recv occurrence vanished")
        # (COMM)
        sact, srebuild = occs[s.src][t.src_pick]
        ract, rrebuild = occs[s.dst][t.dst_pick]
        new = w.replace(s.src, trace=srebuild(NIL))
        dst_cfg = w[s.dst]
        new = new.replace(
            s.dst, data=dst_cfg.data | {s.data}, trace=rrebuild(NIL)
        )
        return new
    raise TypeError(f"not a transition: {t!r}")


# ---------------------------------------------------------------------------
# Execution drivers
# ---------------------------------------------------------------------------


@dataclass
class RunResult:
    final: WorkflowSystem
    events: list[tuple]  # transition labels in firing order
    deadlocked: bool

    @property
    def exec_events(self) -> list[tuple]:
        return [e for e in self.events if e[0] == "exec"]

    @property
    def comm_events(self) -> list[tuple]:
        return [e for e in self.events if e[0] == "tau"]


def run(
    w: WorkflowSystem,
    *,
    rng: Optional[random.Random] = None,
    max_steps: int = 100_000,
    prefer_comm: bool = False,
) -> RunResult:
    """Reduce ``w`` to completion under a (possibly random) scheduler.

    Every schedule of an encoded system reaches the same final state up to
    congruence (Lemma 1, Church–Rosser) — the random scheduler is how the
    property tests exercise that claim.
    """
    events: list[tuple] = []
    for _ in range(max_steps):
        ts = enabled_transitions(w)
        if not ts:
            return RunResult(w, events, deadlocked=not w.is_terminated())
        if rng is None:
            t = ts[0]
        else:
            if prefer_comm:
                comms = [t for t in ts if t.is_tau]
                t = rng.choice(comms or ts)
            else:
                t = rng.choice(ts)
        events.append(t.label)
        w = apply_transition(w, t)
    raise RuntimeError(f"did not terminate within {max_steps} reductions")


def reachable_states(
    w: WorkflowSystem, *, max_states: int = 20_000
) -> dict[str, list[tuple[tuple, str]]]:
    """Explicit LTS: canonical state -> [(label, canonical successor)].

    Used by the bisimulation checker; raises if the state space exceeds
    ``max_states`` (keep the property-test instances small).
    """
    lts: dict[str, list[tuple[tuple, str]]] = {}
    index: dict[str, WorkflowSystem] = {w.canonical(): w}
    frontier = [w]
    while frontier:
        cur = frontier.pop()
        key = cur.canonical()
        if key in lts:
            continue
        succ: list[tuple[tuple, str]] = []
        for t in enabled_transitions(cur):
            nxt = apply_transition(cur, t)
            nkey = nxt.canonical()
            succ.append((t.label, nkey))
            if nkey not in index:
                index[nkey] = nxt
                frontier.append(nxt)
                if len(index) > max_states:
                    raise RuntimeError("state space too large for exploration")
        lts[key] = succ
    return lts


def barbs(w: WorkflowSystem) -> frozenset[tuple]:
    """Strong barbs ``W ↓_ν``: the observable exec predicates enabled now."""
    return frozenset(
        t.label for t in enabled_transitions(w) if isinstance(t, ExecTransition)
    )
