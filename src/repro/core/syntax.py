"""SWIRL syntax — Definition 8 of the paper.

::

    W ::= ⟨l, D, e⟩ | (W1 | W2)
    e ::= μ | e1.e2 | (e1 | e2) | 0
    μ ::= exec(s, F(s), M(s)) | send(d↣p, l, l') | recv(p, l, l')
    F(s) ::= In^D(s) ↦ Out^D(s)

Traces are immutable hashable trees.  ``Seq``/``Par`` are n-ary and kept in
*source order* (the order matters for readability and paper-exactness tests);
structural congruence (Fig. 2) is provided by :func:`normalize` /
:func:`congruent`, which flatten nested compositions, drop ``0`` units and
compare ``Par`` branches up to permutation.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Iterator, Mapping, Union


# ---------------------------------------------------------------------------
# Predicates μ
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Exec:
    """``exec(s, In^D(s) ↦ Out^D(s), M(s))``."""

    step: str
    inputs: frozenset[str]
    outputs: frozenset[str]
    locations: tuple[str, ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "inputs", frozenset(self.inputs))
        object.__setattr__(self, "outputs", frozenset(self.outputs))
        object.__setattr__(self, "locations", tuple(self.locations))

    def pretty(self) -> str:
        ins = "{" + ",".join(sorted(self.inputs)) + "}"
        outs = "{" + ",".join(sorted(self.outputs)) + "}"
        locs = "{" + ",".join(self.locations) + "}"
        return f"exec({self.step},{ins}->{outs},{locs})"


@dataclass(frozen=True)
class Send:
    """``send(d ↣ p, l, l')`` — transfer data ``d`` over port ``p``."""

    data: str
    port: str
    src: str
    dst: str

    def pretty(self) -> str:
        return f"send({self.data}->{self.port},{self.src},{self.dst})"


@dataclass(frozen=True)
class Recv:
    """``recv(p, l, l')`` — receive over port ``p`` from ``l`` at ``l'``."""

    port: str
    src: str
    dst: str

    def pretty(self) -> str:
        return f"recv({self.port},{self.src},{self.dst})"


Action = Union[Exec, Send, Recv]


# ---------------------------------------------------------------------------
# Traces e
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Nil:
    """The empty trace ``0``."""

    def pretty(self) -> str:
        return "0"


@dataclass(frozen=True)
class Seq:
    """``e1.e2...`` — n-ary sequential composition."""

    items: tuple["Trace", ...]

    def pretty(self) -> str:
        return ".".join(_paren(t, inside="seq") for t in self.items)


@dataclass(frozen=True)
class Par:
    """``e1 | e2 | ...`` — n-ary parallel composition."""

    branches: tuple["Trace", ...]

    def pretty(self) -> str:
        return " | ".join(_paren(t, inside="par") for t in self.branches)


Trace = Union[Nil, Seq, Par, Exec, Send, Recv]

NIL = Nil()


def _paren(t: Trace, inside: str) -> str:
    s = t.pretty()
    if inside == "seq" and isinstance(t, (Par, Seq)):
        return f"({s})"
    if inside == "par" and isinstance(t, Par):
        return f"({s})"
    return s


# ---------------------------------------------------------------------------
# Smart constructors (apply the Fig. 2 identities eagerly)
# ---------------------------------------------------------------------------


def seq(*items: Trace) -> Trace:
    """Sequential composition with ``0.e ≡ e ∧ e.0 ≡ e`` (Id.) and flattening."""
    flat: list[Trace] = []
    for it in items:
        if isinstance(it, Nil):
            continue
        if isinstance(it, Seq):
            flat.extend(it.items)
        else:
            flat.append(it)
    if not flat:
        return NIL
    if len(flat) == 1:
        return flat[0]
    return Seq(tuple(flat))


def par(*branches: Trace) -> Trace:
    """Parallel composition with ``e | 0 ≡ e`` (Id|) and flattening."""
    flat: list[Trace] = []
    for b in branches:
        if isinstance(b, Nil):
            continue
        if isinstance(b, Par):
            flat.extend(b.branches)
        else:
            flat.append(b)
    if not flat:
        return NIL
    if len(flat) == 1:
        return flat[0]
    return Par(tuple(flat))


def is_action(t: Trace) -> bool:
    return isinstance(t, (Exec, Send, Recv))


def actions(t: Trace) -> Iterator[Action]:
    """All predicate occurrences in ``t`` in left-to-right program order."""
    if is_action(t):
        yield t  # type: ignore[misc]
    elif isinstance(t, Seq):
        for it in t.items:
            yield from actions(it)
    elif isinstance(t, Par):
        for b in t.branches:
            yield from actions(b)


def size(t: Trace) -> int:
    return sum(1 for _ in actions(t))


# ---------------------------------------------------------------------------
# Structural congruence (Fig. 2)
# ---------------------------------------------------------------------------


def normalize(t: Trace) -> Trace:
    """Normal form: flatten, drop units, sort ``Par`` branches canonically.

    Two traces are structurally congruent iff their normal forms are equal
    (COMT_u commutes parallel branches; Id rules drop ``0``).
    """
    if is_action(t) or isinstance(t, Nil):
        return t
    if isinstance(t, Seq):
        return seq(*(normalize(i) for i in t.items))
    if isinstance(t, Par):
        norm = [normalize(b) for b in t.branches]
        norm = [b for b in norm if not isinstance(b, Nil)]
        norm.sort(key=_trace_key)
        return par(*norm)
    raise TypeError(f"not a trace: {t!r}")


def _trace_key(t: Trace) -> str:
    return normalize(t).pretty() if isinstance(t, (Seq, Par)) else t.pretty()


def congruent(a: Trace, b: Trace) -> bool:
    """``a ≡ b`` under the Fig. 2 structural congruence."""
    return normalize(a) == normalize(b)


# ---------------------------------------------------------------------------
# Workflow systems W (parallel composition of location configurations)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LocationConfig:
    """``⟨l, D, e⟩`` — location name, resident data, execution trace."""

    location: str
    data: frozenset[str]
    trace: Trace

    def __post_init__(self) -> None:
        object.__setattr__(self, "data", frozenset(self.data))

    def pretty(self) -> str:
        d = "{" + ",".join(sorted(self.data)) + "}"
        return f"<{self.location},{d},{self.trace.pretty()}>"


@dataclass(frozen=True)
class WorkflowSystem:
    """``W = Π_i ⟨l_i, D_i, e_i⟩`` with one configuration per location."""

    configs: tuple[LocationConfig, ...]

    def __post_init__(self) -> None:
        names = [c.location for c in self.configs]
        if len(names) != len(set(names)):
            raise ValueError(f"duplicate location configuration: {names}")

    # -- accessors ----------------------------------------------------------
    def locations(self) -> tuple[str, ...]:
        return tuple(c.location for c in self.configs)

    def __getitem__(self, location: str) -> LocationConfig:
        for c in self.configs:
            if c.location == location:
                return c
        raise KeyError(location)

    def replace(self, location: str, *, data=None, trace=None) -> "WorkflowSystem":
        new = []
        for c in self.configs:
            if c.location == location:
                c = LocationConfig(
                    location,
                    frozenset(data) if data is not None else c.data,
                    trace if trace is not None else c.trace,
                )
            new.append(c)
        return WorkflowSystem(tuple(new))

    def is_terminated(self) -> bool:
        """All traces are ``≡ 0`` — the plan ran to completion."""
        return all(isinstance(normalize(c.trace), Nil) for c in self.configs)

    def pretty(self) -> str:
        return " |\n".join(c.pretty() for c in self.configs)

    def canonical(self) -> str:
        """Canonical string up to structural congruence (state-space key)."""
        parts = []
        for c in sorted(self.configs, key=lambda c: c.location):
            d = ",".join(sorted(c.data))
            parts.append(f"<{c.location}|{d}|{normalize(c.trace).pretty()}>")
        return "||".join(parts)

    def total_actions(self) -> int:
        return sum(size(c.trace) for c in self.configs)

    def comm_count(self) -> int:
        """Number of ``send``/``recv`` predicates in the whole system."""
        n = 0
        for c in self.configs:
            for a in actions(c.trace):
                if isinstance(a, (Send, Recv)):
                    n += 1
        return n

    def send_count(self) -> int:
        return sum(
            1
            for c in self.configs
            for a in actions(c.trace)
            if isinstance(a, Send)
        )


def system(*configs: LocationConfig) -> WorkflowSystem:
    return WorkflowSystem(tuple(configs))


def config(location: str, data: Iterable[str], trace: Trace) -> LocationConfig:
    return LocationConfig(location, frozenset(data), trace)
