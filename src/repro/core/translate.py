"""Front-end translators — the ``SWIRLTranslator`` layer of the toolchain.

The paper's toolchain translates product-specific workflow languages (CWL,
DAX, GWF) into SWIRL.  Offline we implement the same abstract class with
concrete translators for:

* :class:`DagTranslator` — a generic step-adjacency description (the common
  denominator of DAX/CWL DAGs): ``{step: [dependent steps]}`` plus a
  step→locations mapping.  One port + one data element is materialised per
  producer step output edge group, exactly like DAX's file-based edges.
* :func:`genomes_1000` — the paper's §6/Appendix B evaluation workflow,
  parameterised by ``(n, m, a, b, c)``.
* :class:`TrainPipelineTranslator` — swirl-jax's own front-end: a multi-pod
  training iteration (data shards → per-pod train steps → gradient
  synchronisation → optimiser update → checkpoint) as a distributed workflow
  instance.  ``launch/train.py`` drives distribution through this path, making
  the paper's technique the framework's first-class scheduling layer.
"""

from __future__ import annotations

from abc import ABC, abstractmethod
from dataclasses import dataclass, field
from typing import Iterable, Mapping, Sequence

from .graph import DistributedWorkflowInstance, make_workflow
from .syntax import WorkflowSystem
from .encoding import encode


class SWIRLTranslator(ABC):
    """Abstract translator: front-end description → distributed instance."""

    @abstractmethod
    def instance(self) -> DistributedWorkflowInstance:
        ...

    def translate(self) -> WorkflowSystem:
        """Front-end → SWIRL system via the paper's encoding ``⟦·⟧``.

        Deprecated: the staged pipeline (``swirl.trace(translator)``) calls
        :func:`~repro.core.encoding.encode` on :meth:`instance` directly and
        keeps the instance around for placement/explain support.
        """
        from repro._compat import warn_legacy

        warn_legacy(
            f"{type(self).__name__}.translate()", "swirl.trace(translator)"
        )
        return encode(self.instance())


# ---------------------------------------------------------------------------
# Generic DAG front-end
# ---------------------------------------------------------------------------


@dataclass
class DagTranslator(SWIRLTranslator):
    """``edges[s] = [s', ...]`` step DAG + ``mapping[s] = (l, ...)``.

    For every producer step ``s`` with successors, one port ``p^s`` and one
    data element ``d^s`` are created (all successors read the same datum —
    multiple output edges from one port, as Def. 1 allows).  Source steps
    with no predecessors consume nothing; their inputs, if any, must be
    provided via ``initial_data``.
    """

    edges: Mapping[str, Sequence[str]]
    mapping: Mapping[str, Sequence[str]]
    initial_data: Mapping[str, Iterable[str]] = field(default_factory=dict)

    def instance(self) -> DistributedWorkflowInstance:
        steps = set(self.edges) | {t for ts in self.edges.values() for t in ts}
        ports, data, deps, placement = set(), set(), set(), {}
        for s, succs in self.edges.items():
            if not succs:
                continue
            p, d = f"p^{s}", f"d^{s}"
            ports.add(p)
            data.add(d)
            placement[d] = p
            deps.add((s, p))
            for t in succs:
                deps.add((p, t))
        wf = make_workflow(steps, ports, deps)
        locations = frozenset(l for ls in self.mapping.values() for l in ls)
        return DistributedWorkflowInstance(
            workflow=wf,
            locations=locations,
            mapping={s: tuple(ls) for s, ls in self.mapping.items()},
            data=frozenset(data),
            placement=placement,
            initial_data={l: frozenset(ds) for l, ds in self.initial_data.items()},
        )


# ---------------------------------------------------------------------------
# 1000 Genomes (paper §6 / Appendix B)
# ---------------------------------------------------------------------------


def genomes_1000(
    n: int = 4, m: int = 3, a: int = 2, b: int = 2, c: int = 2
) -> DistributedWorkflowInstance:
    """The 1000 Genomes workflow instance of Table 1 / Fig. 5-6.

    ``n`` individuals steps over ``a`` locations, one individuals_merge, one
    sifting, ``m`` mutations_overlap steps over ``b`` locations and ``m``
    frequency steps over ``c`` locations, plus the auxiliary driver step
    ``s_0`` on ``l^d`` distributing the initial data.
    """
    steps = {"s0", "sIM", "sSF"}
    ports: set[str] = set()
    deps: set[tuple[str, str]] = set()
    data: set[str] = set()
    placement: dict[str, str] = {}
    mapping: dict[str, tuple[str, ...]] = {
        "s0": ("l^d",),
        "sIM": ("l^IM",),
        "sSF": ("l^SF",),
    }

    def port(name: str, datum: str, producer: str, consumers: Iterable[str]):
        ports.add(name)
        data.add(datum)
        placement[datum] = name
        deps.add((producer, name))
        for cstep in consumers:
            deps.add((name, cstep))

    # individuals: s^I_i on l^I_{(i-1) % a + 1}, fed by d0_i from s0.
    for i in range(1, n + 1):
        s = f"sI_{i}"
        steps.add(s)
        mapping[s] = (f"l^I_{(i - 1) % a + 1}",)
        port(f"p0_{i}", f"d0_{i}", "s0", [s])
        port(f"pI_{i}", f"dI_{i}", s, ["sIM"])

    # sifting input from the driver; its output feeds every MO and F step.
    port("p0_SF", "d0_SF", "s0", ["sSF"])

    # individuals_merge output d^IM and sifting output d^SF feed all MO/F.
    mo_steps, f_steps = [], []
    for h in range(1, m + 1):
        smo, sf = f"sMO_{h}", f"sF_{h}"
        steps |= {smo, sf}
        mo_steps.append(smo)
        f_steps.append(sf)
        mapping[smo] = (f"l^MO_{(h - 1) % b + 1}",)
        mapping[sf] = (f"l^F_{(h - 1) % c + 1}",)
        port(f"pP_{h}", f"dP_{h}", "s0", [smo, sf])
    port("p^IM", "d^IM", "sIM", mo_steps + f_steps)
    port("p^SF", "d^SF", "sSF", mo_steps + f_steps)

    locations = frozenset(
        {"l^d", "l^IM", "l^SF"}
        | {f"l^I_{j}" for j in range(1, a + 1)}
        | {f"l^MO_{t}" for t in range(1, b + 1)}
        | {f"l^F_{k}" for k in range(1, c + 1)}
    )
    wf = make_workflow(steps, ports, deps)
    # The driver initially owns every d0/dP input (G(l^d)).
    initial = {
        "l^d": frozenset(
            {f"d0_{i}" for i in range(1, n + 1)}
            | {f"dP_{h}" for h in range(1, m + 1)}
            | {"d0_SF"}
        )
    }
    return DistributedWorkflowInstance(
        workflow=wf,
        locations=locations,
        mapping=mapping,
        data=frozenset(data),
        placement=placement,
        initial_data=initial,
    )


# ---------------------------------------------------------------------------
# swirl-jax training-pipeline front-end
# ---------------------------------------------------------------------------


@dataclass
class TrainPipelineTranslator(SWIRLTranslator):
    """One training iteration over ``n_pods`` pods as a workflow instance.

    Steps (per iteration):
      * ``shard_<i>``    — produce pod-``i``'s input batch shard (on ``pod<i>``)
      * ``fwdbwd_<i>``   — forward+backward on pod ``i`` → local gradients
      * ``gradsync``     — hierarchical gradient synchronisation (mapped onto
        *all* pods: the spatial constraint models the collective — every pod
        participates and each ends up with the synchronised gradient copy)
      * ``update_<i>``   — optimiser update per pod (ZeRO-local)
      * ``ckpt``         — checkpoint step on pod 0 (optional)

    Per-pod replica state (``params_<i>``, ``opt_<i>``) and the iteration
    number enter as *initial ports* (no producing step — the same device as
    the paper's App. B driver data), resident in ``G(pod<i>)``.

    Encoding + the paper's optimisation then produce exactly the minimal
    communication plan: R1 removes same-pod transfers (data/grad stay local),
    R2 coalesces the duplicate broadcast of the synchronised gradients.
    """

    n_pods: int = 2
    with_checkpoint: bool = True

    def instance(self) -> DistributedWorkflowInstance:
        pods = [f"pod{i}" for i in range(self.n_pods)]
        steps, ports, deps = set(), set(), set()
        data, placement = set(), {}
        mapping: dict[str, tuple[str, ...]] = {}
        initial: dict[str, set[str]] = {p: set() for p in pods}

        def port(name, datum, producer, consumers):
            ports.add(name)
            data.add(datum)
            placement[datum] = name
            if producer is not None:
                deps.add((producer, name))
            for cstep in consumers:
                deps.add((name, cstep))

        for i, pod in enumerate(pods):
            sh, fb, up = f"shard_{i}", f"fwdbwd_{i}", f"update_{i}"
            steps |= {sh, fb, up}
            mapping[sh] = (pod,)
            mapping[fb] = (pod,)
            mapping[up] = (pod,)
            # initial (driver-resident) state for this pod
            port(f"p_iter_{i}", f"iter_{i}", None, [sh])
            port(f"p_params_{i}", f"params_{i}", None, [fb, up])
            port(f"p_opt_{i}", f"opt_{i}", None, [up])
            initial[pod] |= {f"iter_{i}", f"params_{i}", f"opt_{i}"}
            port(f"p_batch_{i}", f"batch_{i}", sh, [fb])
            port(f"p_grad_{i}", f"grad_{i}", fb, ["gradsync"])
            # updated replica state: consumed by ckpt on pod0 (if enabled),
            # read back by the driver between iterations either way
            port(
                f"p_state_{i}", f"state_{i}", up,
                ["ckpt"] if (self.with_checkpoint and i == 0) else [],
            )
        steps.add("gradsync")
        mapping["gradsync"] = tuple(pods)
        port("p_gsync", "grad_sync", "gradsync", [f"update_{i}" for i in range(self.n_pods)])
        if self.with_checkpoint:
            steps.add("ckpt")
            mapping["ckpt"] = (pods[0],)

        wf = make_workflow(steps, ports, deps)
        return DistributedWorkflowInstance(
            workflow=wf,
            locations=frozenset(pods),
            mapping=mapping,
            data=frozenset(data),
            placement=placement,
            initial_data={l: frozenset(ds) for l, ds in initial.items()},
        )


# ---------------------------------------------------------------------------
# Pipeline-parallel front-end (stages as locations)
# ---------------------------------------------------------------------------


@dataclass
class PipelineTranslator(SWIRLTranslator):
    """``n_stages`` pipeline stages × ``n_microbatches`` as a workflow.

    Stage ``j`` of microbatch ``k`` depends on stage ``j-1`` of the same
    microbatch; each stage is pinned to its own location.  The SWIRL send/recv
    pairs between consecutive stages are what ``launch``'s bundle compiler
    lowers to ``ppermute`` on the stage mesh axis.
    """

    n_stages: int = 4
    n_microbatches: int = 2

    def instance(self) -> DistributedWorkflowInstance:
        steps, ports, deps = set(), set(), set()
        data, placement, mapping = set(), {}, {}
        for k in range(self.n_microbatches):
            for j in range(self.n_stages):
                s = f"stage{j}_mb{k}"
                steps.add(s)
                mapping[s] = (f"stage{j}",)
                if j > 0:
                    p, d = f"p_{j - 1}to{j}_mb{k}", f"act_{j - 1}to{j}_mb{k}"
                    ports.add(p)
                    data.add(d)
                    placement[d] = p
                    deps.add((f"stage{j - 1}_mb{k}", p))
                    deps.add((p, s))
        wf = make_workflow(steps, ports, deps)
        return DistributedWorkflowInstance(
            workflow=wf,
            locations=frozenset(f"stage{j}" for j in range(self.n_stages)),
            mapping=mapping,
            data=frozenset(data),
            placement=placement,
        )
