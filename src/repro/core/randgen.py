"""Deterministic random layered workflow instances, at any scale.

One generator shared by the compile-time benchmarks
(``benchmarks/run.py compile``), the large-DAG smoke tests and the
flat-vs-tree differential property suite, so they all agree on what "an
N-step workflow" means: a layered DAG (always acyclic) with bounded
fan-in, a tunable fraction of spatially-constrained (multi-location)
steps, and a fixed seed → identical instance on every machine.
"""

from __future__ import annotations

import random

from .graph import DistributedWorkflowInstance, make_workflow

__all__ = ["random_layered_instance"]


def random_layered_instance(
    n_steps: int,
    *,
    n_locations: int = 4,
    seed: int = 0,
    max_width: int = 4,
    max_fan_in: int = 3,
    p_spatial: float = 0.1,
    p_sink_port: float = 0.5,
) -> DistributedWorkflowInstance:
    """A random layered DAG instance with exactly ``n_steps`` steps.

    Steps are laid out in layers of 1..``max_width``; each step consumes up
    to ``max_fan_in`` ports of the previous layer and (except some sinks)
    produces one port holding one data element.  With probability
    ``p_spatial`` a step is mapped onto two locations (a spatial
    constraint — the pattern rule R3 optimises); otherwise onto one.
    """
    if n_steps < 1:
        raise ValueError(f"n_steps must be >= 1: {n_steps}")
    rng = random.Random(seed)
    locations = [f"l{i}" for i in range(n_locations)]

    widths: list[int] = []
    remaining = n_steps
    while remaining:
        w = min(remaining, rng.randint(1, max_width))
        widths.append(w)
        remaining -= w

    steps: list[str] = []
    ports: list[str] = []
    deps: list[tuple[str, str]] = []
    data: list[str] = []
    placement: dict[str, str] = {}
    mapping: dict[str, tuple[str, ...]] = {}
    prev_ports: list[str] = []
    sid = 0
    for layer, width in enumerate(widths):
        new_ports: list[str] = []
        for _ in range(width):
            s = f"s{sid}"
            sid += 1
            steps.append(s)
            if n_locations > 1 and rng.random() < p_spatial:
                mapping[s] = tuple(sorted(rng.sample(locations, 2)))
            else:
                mapping[s] = (rng.choice(locations),)
            if prev_ports:
                n_in = rng.randint(0, min(max_fan_in, len(prev_ports)))
                for p in rng.sample(prev_ports, n_in):
                    deps.append((p, s))
            if layer < len(widths) - 1 or rng.random() < p_sink_port:
                p, d = f"p{s}", f"d{s}"
                ports.append(p)
                data.append(d)
                placement[d] = p
                deps.append((s, p))
                new_ports.append(p)
        prev_ports = new_ports
    wf = make_workflow(steps, ports, deps)
    return DistributedWorkflowInstance(
        workflow=wf,
        locations=frozenset(locations),
        mapping=mapping,
        data=frozenset(data),
        placement=placement,
        initial_data={},
    )
