"""SWIRL core — the paper's contribution as a composable library.

Layers (paper section in brackets):

* :mod:`~repro.core.graph`     — workflow / distributed-workflow models (§2)
* :mod:`~repro.core.syntax`    — the SWIRL calculus terms (§3, Def. 8)
* :mod:`~repro.core.semantics` — reduction semantics + LTS (§3.1, Figs. 2-3)
* :mod:`~repro.core.encoding`  — ``⟦·⟧ : W_I → W_W`` (§3.2, Defs. 10-12)
* :mod:`~repro.core.optimizer` — rewriting rules ``⟦·⟧ : W_W → W_O`` (§4, Def. 15)
* :mod:`~repro.core.bisim`     — weak barbed bisimulation checker (§4, Thm. 1)
* :mod:`~repro.core.parser`    — ``.swirl`` surface syntax (§5)
* :mod:`~repro.core.translate` — front-end translators incl. 1000 Genomes (§5-6)
* :mod:`~repro.core.compile`   — per-location executable bundles (§5)
"""

from .graph import (
    DistributedWorkflow,
    DistributedWorkflowInstance,
    Workflow,
    WorkflowInstance,
    make_workflow,
)
from .syntax import (
    NIL,
    Exec,
    LocationConfig,
    Nil,
    Par,
    Recv,
    Send,
    Seq,
    Trace,
    WorkflowSystem,
    config,
    congruent,
    normalize,
    par,
    seq,
    system,
)
from .semantics import (
    CommTransition,
    ExecTransition,
    RunResult,
    apply_transition,
    barbs,
    enabled_transitions,
    reachable_states,
    run,
)
from .encoding import building_block, encode, encode_flat
from .flat import (
    FlatConfig,
    FlatSystem,
    FlatTrace,
    flatten_trace,
    rewrite_flat_pipeline,
)
from .optimizer import (
    REWRITE_RULES,
    REWRITE_RULES_TREE,
    OptimizationStats,
    optimize,
    optimize_spatial,
    rewrite_spatial,
    rewrite_spatial_tree,
    rewrite_system,
    rewrite_system_tree,
)
from .bisim import weak_barbed_bisimilar
from .parser import dumps, loads, parse_system, parse_trace
from .translate import (
    DagTranslator,
    PipelineTranslator,
    SWIRLTranslator,
    TrainPipelineTranslator,
    genomes_1000,
)
from .compile import (
    Channel,
    LocationBundle,
    StepMeta,
    build_bundles,
    compile_bundles,
    emit_all,
    emit_python_source,
)

__all__ = [
    "Workflow",
    "WorkflowInstance",
    "DistributedWorkflow",
    "DistributedWorkflowInstance",
    "make_workflow",
    "NIL",
    "Nil",
    "Exec",
    "Send",
    "Recv",
    "Seq",
    "Par",
    "Trace",
    "LocationConfig",
    "WorkflowSystem",
    "config",
    "system",
    "seq",
    "par",
    "normalize",
    "congruent",
    "run",
    "RunResult",
    "barbs",
    "enabled_transitions",
    "apply_transition",
    "reachable_states",
    "ExecTransition",
    "CommTransition",
    "encode",
    "encode_flat",
    "building_block",
    "FlatTrace",
    "FlatConfig",
    "FlatSystem",
    "flatten_trace",
    "rewrite_flat_pipeline",
    "optimize",
    "optimize_spatial",
    "rewrite_system",
    "rewrite_system_tree",
    "rewrite_spatial",
    "rewrite_spatial_tree",
    "REWRITE_RULES",
    "REWRITE_RULES_TREE",
    "OptimizationStats",
    "weak_barbed_bisimilar",
    "parse_system",
    "parse_trace",
    "dumps",
    "loads",
    "SWIRLTranslator",
    "DagTranslator",
    "TrainPipelineTranslator",
    "PipelineTranslator",
    "genomes_1000",
    "StepMeta",
    "Channel",
    "LocationBundle",
    "build_bundles",
    "compile_bundles",
    "emit_python_source",
    "emit_all",
]
