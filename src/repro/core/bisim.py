"""Weak barbed bisimulation checker — Definition 16 / Theorem 1.

The observables (barbs) are the ``exec(s, F(s), M(s))`` predicates; all
communications are silent ``τ`` actions.  For the finite-state systems
produced by the encoder we can check ``W ≈ ⟦W⟧`` *exactly* by a greatest-
fixpoint computation over the product of the two reachable state spaces:

    R₀ = S_W × S_O
    drop (w, o) whenever a transition of one side cannot be weakly matched
    by the other (exec labels matched as τ* ν τ*, τ matched as τ*), or the
    weak barbs disagree;
    iterate to the fixpoint, then test (init_W, init_O) ∈ R.

This is the mechanical counterpart of the paper's Lemmas A.2/A.3 and
Theorem A.1, used as an executable proof on randomised instances.
"""

from __future__ import annotations

from collections import defaultdict

from .semantics import reachable_states
from .syntax import WorkflowSystem

Label = tuple
LTS = dict[str, list[tuple[Label, str]]]


def _tau_closure(lts: LTS) -> dict[str, frozenset[str]]:
    """τ* reachability per state."""
    closure: dict[str, set[str]] = {s: {s} for s in lts}
    changed = True
    while changed:
        changed = False
        for s in lts:
            for lbl, nxt in lts[s]:
                if lbl[0] != "tau":
                    continue
                add = closure[nxt] - closure[s]
                if add:
                    closure[s] |= add
                    changed = True
    return {s: frozenset(v) for s, v in closure.items()}


def _weak_obs_succ(
    lts: LTS, closure: dict[str, frozenset[str]]
) -> dict[str, dict[Label, frozenset[str]]]:
    """``o ⇒ --ν--> ⇒ o''`` successors per state and observable label."""
    out: dict[str, dict[Label, set[str]]] = {s: defaultdict(set) for s in lts}
    for s in lts:
        for mid in closure[s]:
            for lbl, nxt in lts[mid]:
                if lbl[0] == "tau":
                    continue
                out[s][lbl] |= closure[nxt]
    return {s: {l: frozenset(v) for l, v in d.items()} for s, d in out.items()}


def _weak_barbs(
    lts: LTS, closure: dict[str, frozenset[str]]
) -> dict[str, frozenset[Label]]:
    """``W ⇓_ν`` — barbs reachable via τ*."""
    strong: dict[str, set[Label]] = {
        s: {lbl for lbl, _ in lts[s] if lbl[0] != "tau"} for s in lts
    }
    return {
        s: frozenset(b for t in closure[s] for b in strong[t]) for s in lts
    }


def weak_barbed_bisimilar(
    w: WorkflowSystem,
    o: WorkflowSystem,
    *,
    max_states: int = 20_000,
) -> bool:
    """Decide ``w ≈ o`` (exact, for finite systems)."""
    lts_w = reachable_states(w, max_states=max_states)
    lts_o = reachable_states(o, max_states=max_states)
    cl_w, cl_o = _tau_closure(lts_w), _tau_closure(lts_o)
    obs_w, obs_o = _weak_obs_succ(lts_w, cl_w), _weak_obs_succ(lts_o, cl_o)
    barbs_w, barbs_o = _weak_barbs(lts_w, cl_w), _weak_barbs(lts_o, cl_o)

    # Candidate relation: states agreeing on weak barbs.
    rel: set[tuple[str, str]] = {
        (a, b)
        for a in lts_w
        for b in lts_o
        if barbs_w[a] == barbs_o[b]
    }

    def ok_one_way(a: str, b: str, lts_a, obs_b, cl_b, flip: bool) -> bool:
        for lbl, a2 in lts_a[a]:
            if lbl[0] == "tau":
                cand = cl_b[b]
                if not any(((a2, b2) if not flip else (b2, a2)) in rel for b2 in cand):
                    return False
            else:
                cand = obs_b[b].get(lbl, frozenset())
                if not any(((a2, b2) if not flip else (b2, a2)) in rel for b2 in cand):
                    return False
        return True

    changed = True
    while changed:
        changed = False
        for pair in list(rel):
            a, b = pair
            if not ok_one_way(a, b, lts_w, obs_o, cl_o, flip=False):
                rel.discard(pair)
                changed = True
                continue
            if not ok_one_way(b, a, lts_o, obs_w, cl_w, flip=True):
                rel.discard(pair)
                changed = True
    return (w.canonical(), o.canonical()) in rel
