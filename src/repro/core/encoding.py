"""Encoding function ``⟦·⟧ : W_I → W_W`` — Definitions 10-12 of the paper.

Given a distributed workflow instance ``I = (W, L, M, D, I)``, the encoding
produces the workflow system

    W_Init = Π_{l ∈ L} ⟨l, G(l), Π_{s ∈ Q(l)} B_l(s)⟩

where each *building block* ``B_l(s)`` (Def. 10) is

    (Π recv(I(d_i), l_j, l))         for every input datum and every
                                      location of its producing step
    . exec(s, In^D(s) ↦ Out^D(s), M(s))
    . (Π send(d_i ↣ I(d_i), l, l_j)) for every output datum, every consumer
                                      step, and every location it maps to

Determinism: all the Π iterations follow a fixed (sorted / mapping) order so
that encoding the same instance twice yields the identical system — the
paper-exactness tests rely on this.
"""

from __future__ import annotations

from .graph import DistributedWorkflowInstance
from .syntax import (
    Exec,
    LocationConfig,
    Recv,
    Send,
    Trace,
    WorkflowSystem,
    par,
    seq,
)


def building_block(inst: DistributedWorkflowInstance, s: str, l: str) -> Trace:
    """``B_l(s)`` per Def. 10."""
    if l not in inst.locs_of(s):
        raise ValueError(f"step {s!r} is not mapped onto location {l!r}")

    # (i) receive every input data element from every location of its producer
    recvs: list[Trace] = []
    for d in sorted(inst.in_data(s)):
        port = inst.port_of(d)
        producers = sorted(inst.producers_of_data(d))
        if not producers:
            # Initial port with no producing step: the data must be part of
            # G(l) (cf. App. B, handled by the auxiliary step s_0 in the
            # translate front-end). Nothing to receive.
            continue
        for ps in producers:
            for lj in inst.locs_of(ps):
                recvs.append(Recv(port, lj, l))

    ex = Exec(s, inst.in_data(s), inst.out_data(s), inst.locs_of(s))

    # (iii) send every output datum to every consumer step's locations
    sends: list[Trace] = []
    for d in sorted(inst.out_data(s)):
        port = inst.port_of(d)
        for sk in sorted(inst.consumers_of_data(d)):
            for lj in inst.locs_of(sk):
                sends.append(Send(d, port, l, lj))

    return seq(par(*recvs), ex, par(*sends))


def encode(inst: DistributedWorkflowInstance) -> WorkflowSystem:
    """``⟦I⟧`` per Def. 11 / Def. 12 (initial state ``W_Init``)."""
    configs = []
    for l in sorted(inst.locations):
        blocks = [building_block(inst, s, l) for s in inst.work_queue(l)]
        configs.append(LocationConfig(l, inst.g(l), par(*blocks)))
    return WorkflowSystem(tuple(configs))
