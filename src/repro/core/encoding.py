"""Encoding function ``⟦·⟧ : W_I → W_W`` — Definitions 10-12 of the paper.

Given a distributed workflow instance ``I = (W, L, M, D, I)``, the encoding
produces the workflow system

    W_Init = Π_{l ∈ L} ⟨l, G(l), Π_{s ∈ Q(l)} B_l(s)⟩

where each *building block* ``B_l(s)`` (Def. 10) is

    (Π recv(I(d_i), l_j, l))         for every input datum and every
                                      location of its producing step
    . exec(s, In^D(s) ↦ Out^D(s), M(s))
    . (Π send(d_i ↣ I(d_i), l, l_j)) for every output datum, every consumer
                                      step, and every location it maps to

Determinism: all the Π iterations follow a fixed (sorted / mapping) order so
that encoding the same instance twice yields the identical system — the
paper-exactness tests rely on this.

Two output forms implement the same Def.-10 block enumeration:

* :func:`encode`      — the tree syntax (``W_Init`` as Seq/Par trees), via
  :func:`building_block`/:func:`_block_parts`;
* :func:`encode_flat` — the flat IR (:class:`~repro.core.flat.FlatSystem`),
  emitting the per-location action arrays and structure skeleton directly
  from precomputed per-step templates, without materialising any tree
  nodes.  The two enumerations are kept honest against each other by the
  exact-equality property ``encode_flat(I).to_system() == encode(I)``
  (tests/test_flat_ir.py), so the fast compilation paths can stay in the
  flat domain end to end.
"""

from __future__ import annotations

from .flat import OP_ACT, OP_NIL, OP_PAR, OP_SEQ, FlatConfig, FlatSystem, FlatTrace
from .graph import DistributedWorkflowInstance
from .syntax import (
    Action,
    Exec,
    LocationConfig,
    Recv,
    Send,
    Trace,
    WorkflowSystem,
    par,
    seq,
)


def _block_parts(
    inst: DistributedWorkflowInstance, s: str, l: str
) -> tuple[list[Recv], Exec, list[Send]]:
    """The three pieces of ``B_l(s)`` (Def. 10) as action lists."""
    if l not in inst.locs_of(s):
        raise ValueError(f"step {s!r} is not mapped onto location {l!r}")

    # (i) receive every input data element from every location of its producer
    recvs: list[Recv] = []
    for d in sorted(inst.in_data(s)):
        port = inst.port_of(d)
        producers = sorted(inst.producers_of_data(d))
        if not producers:
            # Initial port with no producing step: the data must be part of
            # G(l) (cf. App. B, handled by the auxiliary step s_0 in the
            # translate front-end). Nothing to receive.
            continue
        for ps in producers:
            for lj in inst.locs_of(ps):
                recvs.append(Recv(port, lj, l))

    ex = Exec(s, inst.in_data(s), inst.out_data(s), inst.locs_of(s))

    # (iii) send every output datum to every consumer step's locations
    sends: list[Send] = []
    for d in sorted(inst.out_data(s)):
        port = inst.port_of(d)
        for sk in sorted(inst.consumers_of_data(d)):
            for lj in inst.locs_of(sk):
                sends.append(Send(d, port, l, lj))

    return recvs, ex, sends


def building_block(inst: DistributedWorkflowInstance, s: str, l: str) -> Trace:
    """``B_l(s)`` per Def. 10."""
    recvs, ex, sends = _block_parts(inst, s, l)
    return seq(par(*recvs), ex, par(*sends))


def encode(inst: DistributedWorkflowInstance) -> WorkflowSystem:
    """``⟦I⟧`` per Def. 11 / Def. 12 (initial state ``W_Init``)."""
    configs = []
    for l in sorted(inst.locations):
        blocks = [building_block(inst, s, l) for s in inst.work_queue(l)]
        configs.append(LocationConfig(l, inst.g(l), par(*blocks)))
    return WorkflowSystem(tuple(configs))


# ---------------------------------------------------------------------------
# Flat-form encoding — same blocks, no tree nodes
# ---------------------------------------------------------------------------


def _emit_group(
    ops: list[tuple[int, int]],
    actions: list[Action],
    group: list[Action],
) -> int:
    """Emit ``par(*group)`` ops; returns 1 if anything was emitted, else 0."""
    if not group:
        return 0
    if len(group) > 1:
        ops.append((OP_PAR, len(group)))
    for a in group:
        ops.append((OP_ACT, len(actions)))
        actions.append(a)
    return 1


def encode_flat(inst: DistributedWorkflowInstance) -> FlatSystem:
    """``⟦I⟧`` emitted directly as a :class:`~repro.core.flat.FlatSystem`.

    Structurally identical to :func:`encode` — the emitted skeleton mirrors
    what the ``seq``/``par`` smart constructors build: empty recv/send
    groups vanish, singleton groups inline, a block with no comms is its
    bare exec, and a location with one block is that block itself.

    The per-step recv/send templates (everything in ``B_l(s)`` that does
    not depend on ``l``) are computed once and instantiated per location,
    so a 10k-step encode performs no repeated sorting or relation scans.
    """
    topo = inst.workflow.topological_steps()
    # Grab the adjacency/port indexes once — the per-call accessor wrappers
    # cost more than the lookups themselves at 10k-step scale.
    adj = inst.workflow._adjacency()
    in_ports, out_ports = adj["in_ports"], adj["out_ports"]
    in_steps = adj["in_steps"]
    by_port = inst.instance._port_index()
    port_of = inst.placement
    mapping = inst.mapping
    empty: frozenset[str] = frozenset()

    # Per-step templates: recv sources (port, producer-location) and send
    # targets (datum, port, consumer-location), in Def.-10 emission order.
    recv_tmpl: dict[str, list[tuple[str, str]]] = {}
    send_tmpl: dict[str, list[tuple[str, str, str]]] = {}
    execs: dict[str, Exec] = {}
    producers_sorted: dict[str, list[str]] = {}
    consumers_sorted: dict[str, list[str]] = {}
    for s in topo:
        in_data: frozenset[str] = empty
        for p in in_ports.get(s, ()):
            in_data = in_data | by_port.get(p, empty)
        out_data: frozenset[str] = empty
        for p in out_ports.get(s, ()):
            out_data = out_data | by_port.get(p, empty)
        rt: list[tuple[str, str]] = []
        for d in sorted(in_data):
            port = port_of[d]
            producers = producers_sorted.get(d)
            if producers is None:
                producers = producers_sorted[d] = sorted(
                    in_steps.get(port, ())
                )
            for ps in producers:
                for lj in mapping[ps]:
                    rt.append((port, lj))
        recv_tmpl[s] = rt
        st: list[tuple[str, str, str]] = []
        for d in sorted(out_data):
            port = port_of[d]
            consumers = consumers_sorted.get(d)
            if consumers is None:
                consumers = consumers_sorted[d] = sorted(
                    inst.consumers_of_data(d)
                )
            for sk in consumers:
                for lj in mapping[sk]:
                    st.append((d, port, lj))
        send_tmpl[s] = st
        execs[s] = Exec(s, in_data, out_data, mapping[s])

    configs: list[FlatConfig] = []
    for l in sorted(inst.locations):
        queue = inst.work_queue(l)
        ops: list[tuple[int, int]] = []
        actions: list[Action] = []
        if not queue:
            ops.append((OP_NIL, 0))
        else:
            if len(queue) > 1:
                ops.append((OP_PAR, len(queue)))
            for s in queue:
                recvs: list[Action] = [
                    Recv(port, lj, l) for port, lj in recv_tmpl[s]
                ]
                sends: list[Action] = [
                    Send(d, port, l, lj) for d, port, lj in send_tmpl[s]
                ]
                n_items = 1 + (1 if recvs else 0) + (1 if sends else 0)
                if n_items > 1:
                    ops.append((OP_SEQ, n_items))
                _emit_group(ops, actions, recvs)
                ops.append((OP_ACT, len(actions)))
                actions.append(execs[s])
                _emit_group(ops, actions, sends)
        configs.append(
            FlatConfig(l, inst.g(l), FlatTrace(ops, actions))
        )
    return FlatSystem(configs)
