"""``repro.swirl`` — the public name of the staged-compilation API.

Thin re-export of :mod:`repro.api` so user code reads as the paper's
toolchain does::

    from repro import swirl

    result = (
        swirl.trace(edges, mapping=mapping)
        .optimize()
        .lower("jax")
        .compile(step_fns)
        .run()
    )
"""

from .api import (  # noqa: F401
    AppliedRewrite,
    BisimCertificate,
    ConcurrentRunError,
    Executable,
    ExecutionResult,
    Lowered,
    Plan,
    clear_compile_cache,
    compile_cache_stats,
    trace,
)
from .backends import (  # noqa: F401
    available_backends,
    get_backend,
    register_backend,
)
from .sched import (  # noqa: F401
    CostModel,
    NetworkModel,
    ScheduleReport,
    SizeModel,
    simulate,
)

__all__ = [
    "trace",
    "Plan",
    "Lowered",
    "Executable",
    "ExecutionResult",
    "AppliedRewrite",
    "BisimCertificate",
    "ConcurrentRunError",
    "clear_compile_cache",
    "compile_cache_stats",
    "register_backend",
    "get_backend",
    "available_backends",
    "NetworkModel",
    "SizeModel",
    "CostModel",
    "ScheduleReport",
    "simulate",
]
