"""``repro.swirl`` — the public name of the staged-compilation API.

Thin re-export of :mod:`repro.api` so user code reads as the paper's
toolchain does::

    from repro import swirl

    result = (
        swirl.trace(edges, mapping=mapping)
        .optimize()
        .lower("jax")
        .compile(step_fns)
        .run()
    )
"""

from .api import (  # noqa: F401
    AppliedRewrite,
    BisimCertificate,
    Executable,
    ExecutionResult,
    Lowered,
    Plan,
    trace,
)
from .backends import (  # noqa: F401
    available_backends,
    get_backend,
    register_backend,
)

__all__ = [
    "trace",
    "Plan",
    "Lowered",
    "Executable",
    "ExecutionResult",
    "AppliedRewrite",
    "BisimCertificate",
    "register_backend",
    "get_backend",
    "available_backends",
]
