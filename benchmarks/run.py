"""Benchmark harness — one section per paper table/figure + system benches.

Prints ``name,value,unit,derived`` CSV rows.  Sections:

* ``encoding``  — ⟦·⟧ encoding time vs workflow size (§3.2);
* ``optimise``  — rewriting time + removed comms vs (m, b) — the Appendix-B
  broadcast-collapse numbers (the paper's only quantitative claim);
* ``runtime``   — 1000 Genomes end-to-end on the decentralised runtime,
  optimised vs unoptimised plan (§6 experiment analogue: 10 locations,
  one chromosome/instance);
* ``dist``      — 1000 Genomes wall-clock, threaded vs the multiprocess
  backend (real OS processes over the ack-based socket transport);
* ``dataplane`` — data-plane raw speed (hard-gated): a 3-consumer scatter
  pump of 64k-float payloads across the seed socket framing vs pickle-5
  out-of-band vs shared-memory vs hybrid (shm must be ≥5x the seed
  framing, zero checksum mismatches), plus fused jitted JAX location
  programs vs the op-by-op interpreter on a 12-step Pallas-rmsnorm
  pipeline (≥3x, allclose outputs, roofline fraction);
* ``sched``     — cost-model-driven placement (repro.sched) vs round-robin
  on the 1000 Genomes workflow under the two-rack network preset;
* ``compile``   — compilation pipeline at scale: encode+R1R2+R3 wall-clock
  on random layered DAGs at 100/1k/2k/10k steps, recursive tree engine vs
  the flat indexed IR, plus ``auto_placement`` on a 500-step DAG (the
  incremental placement scorer);
* ``serve``     — compile-once/run-many serving throughput: 100 workflow
  instances over one lowered program (``Executable.run_many``, shared
  transport) vs the naive per-instance trace→lower→compile→run loop;
* ``gateway``   — workflow-as-a-service over HTTP (repro.serve): sustained
  cache-hit throughput across mixed plan shapes from concurrent keep-alive
  clients (p50/p99 + hit rate), plus an overload run (429s counted, zero
  dropped in-flight executions);
* ``chaos``     — elastic recovery under chaos: run_many throughput and
  result-correctness on the multiprocess backend while every instance's
  worker is SIGKILLed mid-flight and recovered onto a spare (rename) or a
  survivor (fold / pool resize); plus straggler mitigation (a delayed
  worker declared dead by the FaultPolicy heartbeat, spare vs fold vs
  no-policy makespan) and a whole-run deadline abort;
* ``bisim``     — LTS sizes + exact bisimulation check time (Thm. 1);
* ``kernels``   — Pallas kernels (interpret mode) vs jnp references;
* ``train``     — SWIRL-planned trainer steps/s (smoke config);
* ``roofline``  — re-prints the dry-run roofline summary if present.

Usage: ``PYTHONPATH=src python -m benchmarks.run [section ...] [--json]``

``--json`` additionally writes one ``BENCH_<section>.json`` per section —
the CSV rows as a JSON list plus run metadata — so the perf trajectory is
machine-trackable across PRs (CI uploads them as workflow artifacts).
"""

from __future__ import annotations

import glob as _glob
import json
import platform
import sys
import time
from pathlib import Path

import numpy as np

#: Rows of the section currently running (for --json); see main().
_ROWS: list[dict[str, str]] = []


def _t(fn, *args, repeat=3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


def row(name: str, value, unit: str, derived: str = "") -> None:
    print(f"{name},{value},{unit},{derived}")
    _ROWS.append(
        {"name": name, "value": str(value), "unit": unit, "derived": derived}
    )


# ---------------------------------------------------------------------------


def bench_encoding() -> None:
    from repro.core import encode
    from repro.core.translate import genomes_1000

    for n, m in [(4, 3), (16, 8), (64, 32), (256, 128)]:
        inst = genomes_1000(n=n, m=m, a=4, b=4, c=4)
        dt, w = _t(encode, inst)
        row(
            f"encoding/genomes_n{n}_m{m}", f"{dt * 1e6:.0f}", "us",
            f"actions={w.total_actions()}",
        )


def bench_optimise() -> None:
    from repro.core import encode, rewrite_system
    from repro.core.translate import genomes_1000

    for m, b in [(2, 2), (8, 2), (32, 2), (32, 8)]:
        inst = genomes_1000(n=8, m=m, a=2, b=b, c=b)
        w = encode(inst)
        dt, (o, stats) = _t(rewrite_system, w)
        row(
            f"optimise/m{m}_b{b}", f"{dt * 1e6:.0f}", "us",
            f"comms {w.comm_count()}->{o.comm_count()} removed={stats.removed}",
        )


def bench_runtime() -> None:
    from repro import swirl
    from repro.core.translate import genomes_1000

    # 10 locations, single instance — the paper's experiment scale.
    inst = genomes_1000(n=4, m=3, a=2, b=2, c=2)
    rng = np.random.default_rng(0)
    init = {("l^d", d): rng.random(65536) for d in inst.g("l^d")}

    def fns():
        out = {}
        for s in inst.workflow.steps:
            outs = inst.out_data(s)
            if s == "s0":
                out[s] = lambda i, outs=outs: {o: init[("l^d", o)] for o in outs}
            else:
                out[s] = lambda i, outs=outs: {
                    o: sum(np.sum(np.asarray(v)) for v in i.values()) * np.ones(65536)
                    for o in outs
                }
        return out

    raw = swirl.trace(inst)
    for label, plan in [
        ("unoptimised", raw),
        ("optimised", raw.optimize()),
    ]:
        lowered = plan.lower("threaded", timeout_s=60)

        def drive(lowered=lowered):
            return lowered.compile(fns()).run(initial_payloads=dict(init))

        dt, result = _t(drive, repeat=2)
        sent = result.stats["sent"]
        row(
            f"runtime/genomes_{label}", f"{dt * 1e3:.1f}", "ms",
            f"messages={sent} comms_planned={plan.system.comm_count()}",
        )


def bench_dist() -> None:
    """Threaded (one process, queues) vs multiprocess (real OS processes,
    ack-based sockets) wall-clock on the 1000 Genomes workflow."""
    from repro import swirl
    from repro.core.translate import genomes_1000

    inst = genomes_1000(n=4, m=3, a=2, b=2, c=2)
    rng = np.random.default_rng(0)
    init = {("l^d", d): rng.random(65536) for d in inst.g("l^d")}

    def fns():
        out = {}
        for s in inst.workflow.steps:
            outs = inst.out_data(s)
            if s == "s0":
                out[s] = lambda i, outs=outs: {o: init[("l^d", o)] for o in outs}
            else:
                out[s] = lambda i, outs=outs: {
                    o: sum(np.sum(np.asarray(v)) for v in i.values())
                    * np.ones(65536)
                    for o in outs
                }
        return out

    plan = swirl.trace(inst).optimize()
    n_locs = len(inst.locations)
    cases = [
        ("threaded", {"timeout_s": 120}, "in-process threads"),
        ("multiprocess", {"timeout_s": 240}, f"{n_locs} worker processes"),
        (
            "multiprocess",
            {"timeout_s": 240, "workers": 2},
            "packed onto 2 worker processes",
        ),
    ]
    for backend, options, label in cases:
        lowered = plan.lower(backend, **options)

        def drive(lowered=lowered):
            return lowered.compile(fns()).run(initial_payloads=dict(init))

        dt, result = _t(drive, repeat=2)
        workers = (
            result.stats.get("workers", 1)
            if isinstance(result.stats, dict)
            else 1
        )
        name = backend
        if "workers" in options:
            name += f"_w{options['workers']}"
        row(
            f"dist/genomes_{name}", f"{dt * 1e3:.1f}", "ms",
            f"{label}; locations={n_locs} workers={workers}",
        )


def bench_dataplane() -> None:
    """Data-plane raw speed: zero-copy transports + fused JAX programs.

    Two experiments, both hard-gated (asserts, not just rows):

    * *pump* — a genomes-shaped scatter pump: one source process fans
      bursts of 64k-float payloads out to 3 consumer processes which
      checksum and release each message (streaming consumption, so the
      shm arenas recycle).  Four arms over identical payload streams:
      the seed-era socket framing (inline pickle, per-message acks), the
      current pickle-5 out-of-band socket framing, the shared-memory
      transport, and a hybrid route over shm.  Acceptance: shm ≥ 5x the
      seed framing per send, zero checksum mismatches across arms.
    * *fused* — a 12-step single-location pipeline on the JAX backend
      (Pallas rmsnorm every 4th step, tanh-mix elementwise between),
      op-by-op interpreter vs ``fuse=True`` (straight-line EXEC runs
      compiled into one donated-buffer jit per segment).
      Acceptance: fused ≥ 3x, outputs allclose (float32 jit-fusion
      reassociation drift is ~1 ULP), roofline fraction reported.
    """
    import multiprocessing as mp
    import tempfile

    from repro.workflow.transport import (
        HybridTransport,
        SharedMemoryTransport,
        SocketTransport,
        shm_namespace,
        socket_addresses,
    )

    class ClassicSocketTransport(SocketTransport):
        """The seed-era framing: inline pickle, one ack per message."""

        name = "classic"

        def _send_frame(self, conn, frame):
            conn.send(frame)

        @staticmethod
        def _recv_frame(conn):
            return conn.recv()

        def send_many(self, endpoint, items):
            for data_name, payload in items:
                self.send(endpoint, data_name, payload)

        def scatter(self, sends):
            for endpoint, items in sends:
                self.send_many(endpoint, items)

    NDEST, BURST, WARM, NBURST = 3, 8, 3, 30
    AUTHKEY = b"bench-dataplane"
    DESTS = [f"w{i}" for i in range(NDEST)]
    kw = dict(authkey=AUTHKEY, ack_timeout=5.0, connect_timeout=30.0)

    def make(kind, addrs, serve):
        if kind == "classic":
            return ClassicSocketTransport(addrs, serve=serve, **kw)
        if kind == "socket":
            return SocketTransport(addrs, serve=serve, **kw)
        remote = SharedMemoryTransport(addrs, serve=serve, **kw)
        if kind == "hybrid":
            return HybridTransport(remote, serve)
        return remote

    def child(kind, addrs, me, n_msgs, out_q):
        t = make(kind, addrs, (me,))
        ep = ("src", me, "p")
        checksum = 0.0
        for _ in range(n_msgs):
            arr = t.recv(ep, timeout=60.0).payload
            checksum += float(arr[0]) + float(arr[-1])
            del arr  # consume-and-release: lets the sender recycle arenas
        out_q.put((me, checksum))
        t.close()

    ctx = mp.get_context("fork")

    def pump(kind):
        tmp = tempfile.mkdtemp(prefix=f"swirl-dp-{kind}-")
        addrs = socket_addresses(["src"] + DESTS, base_dir=tmp)
        q = ctx.SimpleQueue()
        n_msgs = (WARM + NBURST) * BURST
        procs = [
            ctx.Process(
                target=child, args=(kind, addrs, d, n_msgs, q), daemon=True
            )
            for d in DESTS
        ]
        for p in procs:
            p.start()
        t = make(kind, addrs, ("src",))
        rng = np.random.default_rng(0)
        timed, expect = 0.0, 0.0
        try:
            for b in range(WARM + NBURST):
                arrs = [rng.random(65536) for _ in range(BURST)]
                sends = [
                    (
                        ("src", d, "p"),
                        [(f"b{b}x{i}", a) for i, a in enumerate(arrs)],
                    )
                    for d in DESTS
                ]
                t0 = time.perf_counter()
                t.scatter(sends)
                if b >= WARM:
                    timed += time.perf_counter() - t0
                expect += sum(float(a[0]) + float(a[-1]) for a in arrs)
            sums = dict(q.get() for _ in DESTS)
            for p in procs:
                p.join(30.0)
            stats = t.stats()
        finally:
            t.close()
        mismatches = sum(
            1
            for d in DESTS
            if abs(sums[d] - expect) > 1e-9 * max(abs(expect), 1.0)
        )
        per_send = timed / (NBURST * BURST * NDEST)
        return per_send, mismatches, stats

    per_send: dict[str, float] = {}
    mismatch_total = 0
    for kind in ("classic", "socket", "shm", "hybrid"):
        best, detail = float("inf"), ""
        for _ in range(3):
            dt, mis, stats = pump(kind)
            mismatch_total += mis
            if dt < best:
                best = dt
                inner = stats.get("remote", stats)
                if "segments_created" in inner:
                    detail = (
                        f"arenas created={inner['segments_created']} "
                        f"reused={inner['segments_reused']} "
                        f"dedup={inner['dedup_sends']}"
                    )
        per_send[kind] = best
        row(
            f"dataplane/pump_{kind}_per_send",
            f"{best * 1e6:.1f}", "us",
            detail
            or f"{NDEST} consumers x {NBURST} bursts x {BURST} x 512KB",
        )
    speedup = per_send["classic"] / per_send["shm"]
    row(
        "dataplane/pump_shm_speedup", f"{speedup:.2f}", "x",
        "shm vs seed socket framing — target >= 5x (acceptance)",
    )
    row(
        "dataplane/pump_mismatches", mismatch_total, "checksums",
        f"{NDEST} consumers x 4 transports x 3 runs (must be 0)",
    )
    assert mismatch_total == 0, "transport arms disagreed on payloads"
    assert speedup >= 5.0, f"shm speedup {speedup:.2f}x < 5x floor"
    leaked = _glob.glob(f"/dev/shm/{shm_namespace(AUTHKEY)}-*")
    row("dataplane/pump_shm_leaked", len(leaked), "segments", "(must be 0)")
    assert not leaked, f"leaked shm segments: {leaked}"

    # -- fused jitted location programs --------------------------------------
    import jax.numpy as jnp

    from repro import swirl
    from repro.core.graph import DistributedWorkflowInstance, make_workflow
    from repro.kernels.ops import rmsnorm

    n_steps, shape = 12, (64, 256)
    steps = [f"s{i}" for i in range(1, n_steps + 1)]
    ports = [f"p{i}" for i in range(n_steps + 1)]
    deps = []
    for i, s in enumerate(steps):
        deps += [(f"p{i}", s), (s, f"p{i + 1}")]
    inst = DistributedWorkflowInstance(
        workflow=make_workflow(steps, ports, deps),
        locations=frozenset({"l0"}),
        mapping={s: ("l0",) for s in steps},
        data=frozenset(f"d{i}" for i in range(n_steps + 1)),
        placement={f"d{i}": f"p{i}" for i in range(n_steps + 1)},
        initial_data={"l0": frozenset({"d0"})},
    )
    W = jnp.ones((shape[1],), jnp.float32)

    def norm(v):
        return rmsnorm(v, W)

    def mix(v):
        # Contraction (Lipschitz < 1): fused-vs-eager 1-ULP drift cannot
        # compound down the chain past the allclose gate.
        return 0.5 * v + 0.1 * jnp.tanh(v)

    fns = {
        s: (
            lambda i, a=f"d{k}", b=f"d{k + 1}",
            f=(norm if k % 4 == 0 else mix): {b: f(i[a])}
        )
        for k, s in enumerate(steps)
    }
    x = jnp.asarray(
        np.random.default_rng(7).standard_normal(shape), jnp.float32
    )
    init = {("l0", "d0"): x}
    plan = swirl.trace(inst).optimize()
    interp = plan.lower("jax").compile(fns)
    fused = plan.lower("jax", fuse=True).compile(fns)
    res_i = interp.run(initial_payloads=dict(init))  # warm (traces jits)
    res_f = fused.run(initial_payloads=dict(init))
    mism = sum(
        0
        if np.allclose(
            np.asarray(res_i.data[l][d]), np.asarray(res_f.data[l][d]),
            rtol=1e-5, atol=1e-6,
        )
        else 1
        for l in res_i.data
        for d in res_i.data[l]
    )
    dt_i, _ = _t(
        lambda: interp.run(initial_payloads=dict(init)), repeat=7
    )
    dt_f, res_f = _t(
        lambda: fused.run(initial_payloads=dict(init)), repeat=7
    )
    fstats = res_f.stats["fused"]
    row(
        "dataplane/fused_interp", f"{dt_i * 1e3:.2f}", "ms",
        f"{n_steps}-step pallas-rmsnorm+tanh pipeline {shape}, op-by-op",
    )
    row(
        "dataplane/fused_jit", f"{dt_f * 1e3:.2f}", "ms",
        f"segments={fstats['fused_calls']} "
        f"execs_fused={fstats['fused_execs']}/{n_steps}",
    )
    fspeed = dt_i / dt_f
    row(
        "dataplane/fused_speedup", f"{fspeed:.2f}", "x",
        "fused jit vs op-by-op interpreter — target >= 3x (acceptance)",
    )
    row(
        "dataplane/fused_mismatches", mism, "arrays",
        "allclose rtol=1e-5 atol=1e-6 (must be 0)",
    )
    rl = fstats["roofline"]["l0"]
    row(
        "dataplane/fused_roofline_frac",
        f"{rl['fraction_of_roof']:.4f}", "",
        f"achieved {rl['achieved_bytes_per_s'] / 1e9:.2f} GB/s of "
        f"{rl['theoretical_bytes_per_s'] / 1e9:.0f} GB/s HBM roof",
    )
    assert mism == 0, "fused and interpreted runs diverged"
    assert fspeed >= 3.0, f"fused speedup {fspeed:.2f}x < 3x floor"


def bench_sched() -> None:
    from repro import swirl
    from repro.core.translate import genomes_1000
    from repro.sched import CostModel, NetworkModel, SizeModel

    # Same payload scale as the runtime section (64k-float arrays).
    inst = genomes_1000(n=8, m=6, a=2, b=2, c=2)
    network = NetworkModel.preset("two-rack")
    sizes = SizeModel(default_bytes=8 * 65536)
    costs = CostModel(default_exec_s=2e-3)
    plan = swirl.trace(inst).optimize()

    for objective in ("makespan", "bytes"):
        dt, sched = _t(
            lambda: plan.schedule(
                network, objective=objective, sizes=sizes, costs=costs
            ),
            repeat=1,
        )
        r = sched.schedule_report
        row(
            f"sched/genomes_{objective}_search", f"{dt * 1e3:.0f}", "ms",
            f"steps={len(r.placement)} locations={len(inst.locations)}",
        )
        row(
            f"sched/genomes_{objective}_bytes",
            r.predicted.cross_bytes, "bytes",
            f"round_robin={r.baseline.cross_bytes} "
            f"saved={r.bytes_saved_frac * 100:.0f}%",
        )
        row(
            f"sched/genomes_{objective}_makespan",
            f"{r.predicted.makespan * 1e3:.2f}", "ms",
            f"round_robin={r.baseline.makespan * 1e3:.2f}ms "
            f"speedup={r.makespan_speedup:.2f}x",
        )


def bench_compile() -> None:
    """Compilation at 10k-step scale: tree engine vs flat indexed IR.

    The DAG family is collective-heavy (40% of steps are two-location
    spatial constraints, the multi-pod-trainer profile) so rule R3 — whose
    tree implementation rebuilds the trace per removed action — has real
    work to do.  The tree pipeline is ``encode`` + the recursive reference
    engines; the flat pipeline is ``encode_flat`` + the single-pass flat
    engines + one tree reconstruction.  Both must produce the identical
    system (asserted) before their times are compared.
    """
    from repro.core import encode, encode_flat
    from repro.core.flat import FLAT_RULES
    from repro.core.optimizer import rewrite_spatial_tree, rewrite_system_tree
    from repro.core.randgen import random_layered_instance
    from repro.sched import CostModel, NetworkModel, SizeModel, auto_placement

    def tree_pipeline(inst):
        w = encode(inst)
        o, _ = rewrite_system_tree(w)
        return rewrite_spatial_tree(o)[0]

    def flat_pipeline(inst):
        fs = encode_flat(inst)
        FLAT_RULES["R1R2"](fs)
        FLAT_RULES["R3"](fs)
        return fs.rebuild_system()

    cases = [(100, True, 3), (1000, True, 3), (2000, True, 2), (10000, False, 1)]
    for n, tree_too, repeat in cases:
        inst = random_layered_instance(
            n, n_locations=4, seed=0, p_spatial=0.4
        )
        # Warm the instance-level adjacency/topology caches once — both
        # pipelines share them, so neither arm pays the one-off build.
        encode(inst)
        dt_flat, flat_sys = _t(flat_pipeline, inst, repeat=repeat)
        row(
            f"compile/flat_{n}steps", f"{dt_flat * 1e3:.1f}", "ms",
            f"actions={flat_sys.total_actions()}",
        )
        if tree_too:
            dt_tree, tree_sys = _t(tree_pipeline, inst, repeat=repeat)
            assert tree_sys == flat_sys, "engines diverged — do not compare"
            row(
                f"compile/tree_{n}steps", f"{dt_tree * 1e3:.1f}", "ms",
                "recursive reference engines",
            )
            row(
                f"compile/speedup_{n}steps", f"{dt_tree / dt_flat:.1f}", "x",
                "flat vs tree, end-to-end encode+R1R2+R3",
            )
        else:
            row(
                f"compile/tree_{n}steps", "skipped", "",
                "quadratic R3 — minutes at this size",
            )

    # Placement search at scale: the incremental scorer patches rows and
    # re-schedules through the shared array core instead of re-encoding,
    # re-rewriting and re-simulating trees per candidate move.
    inst = random_layered_instance(500, n_locations=4, seed=1, p_spatial=0.1)
    dt, report = _t(
        lambda: auto_placement(
            inst,
            NetworkModel.preset("two-rack"),
            sizes=SizeModel(default_bytes=1 << 18),
            costs=CostModel(default_exec_s=2e-3),
        ),
        repeat=1,
    )
    row(
        "compile/auto_placement_500steps", f"{dt:.1f}", "s",
        f"target <30s; bytes saved {report.bytes_saved_frac * 100:.0f}% "
        f"makespan {report.makespan_speedup:.2f}x vs round-robin",
    )


def _serve_workload(n_instances: int):
    """The serving-shaped workload shared by the serve / obs sections."""
    from repro.core.graph import DistributedWorkflowInstance, make_workflow

    # A serving-shaped workflow: a source step consumes the per-request
    # seed datum, fans out to two parallel workers, and a sink aggregates.
    wf = make_workflow(
        ["ingest", "work_a", "work_b", "merge"],
        ["p_seed", "p_ingest", "p_a", "p_b"],
        [
            ("p_seed", "ingest"),
            ("ingest", "p_ingest"),
            ("p_ingest", "work_a"),
            ("p_ingest", "work_b"),
            ("work_a", "p_a"),
            ("work_b", "p_b"),
            ("p_a", "merge"),
            ("p_b", "merge"),
        ],
    )
    inst = DistributedWorkflowInstance(
        workflow=wf,
        locations=frozenset({"l0", "l1", "l2"}),
        mapping={
            "ingest": ("l0",),
            "work_a": ("l1",),
            "work_b": ("l2",),
            "merge": ("l0",),
        },
        data=frozenset({"d_seed", "d_ingest", "d_a", "d_b"}),
        placement={
            "d_seed": "p_seed",
            "d_ingest": "p_ingest",
            "d_a": "p_a",
            "d_b": "p_b",
        },
        initial_data={"l0": frozenset({"d_seed"})},
    )
    fns = {
        "ingest": lambda i: {"d_ingest": i["d_seed"] * 2},
        "work_a": lambda i: {"d_a": i["d_ingest"] + 1},
        "work_b": lambda i: {"d_b": i["d_ingest"] + 2},
        "merge": lambda i: {},
    }
    inputs = [{("l0", "d_seed"): i} for i in range(n_instances)]
    return inst, fns, inputs


def bench_serve() -> None:
    """Compile-once/run-many serving throughput (instances/sec).

    100 workflow instances through the threaded backend, two ways:

    * *per-instance* — the naive serving loop: every instance pays the full
      trace → optimize → lower → compile → run pipeline;
    * *run-many* — one ``trace → optimize → lower → compile`` then
      ``Executable.run_many`` over the same lowered program IR with a
      shared transport and a bounded instance pool.

    Acceptance: run-many ≥ 5× instances/sec vs per-instance.
    """
    from repro import swirl

    n_instances = 100
    inst, fns, inputs = _serve_workload(n_instances)

    def per_instance():
        results = []
        for payloads in inputs:
            results.append(
                swirl.trace(inst)
                .optimize()
                .lower("threaded", timeout_s=60)
                .compile(fns)
                .run(initial_payloads=payloads)
            )
        return results

    def run_many():
        exe = (
            swirl.trace(inst)
            .optimize()
            .lower("threaded", timeout_s=60)
            .compile(fns)
        )
        return exe.run_many(inputs, max_concurrent=8)

    dt_per, res_per = _t(per_instance, repeat=1)
    dt_many, res_many = _t(run_many, repeat=1)
    assert [r.data for r in res_many] == [r.data for r in res_per], (
        "run-many results diverged from per-instance runs — do not compare"
    )
    ips_per = n_instances / dt_per
    ips_many = n_instances / dt_many
    row(
        "serve/per_instance_ips", f"{ips_per:.1f}", "instances/s",
        f"{n_instances} x trace->optimize->lower->compile->run",
    )
    row(
        "serve/run_many_ips", f"{ips_many:.1f}", "instances/s",
        f"{n_instances} instances, compile-once, max_concurrent=8",
    )
    row(
        "serve/speedup", f"{ips_many / ips_per:.1f}", "x",
        "target >= 5x (acceptance)",
    )


def _spin(n: int = 1500) -> int:
    """~50µs of pure-Python arithmetic — a stand-in for real step work."""
    acc = 0
    for i in range(n):
        acc += i * i
    return acc


def bench_obs() -> None:
    """Tracing overhead on the serving hot path (target < 5%).

    The same serve-shaped run_many batch through one compiled Executable:
    untraced (the ``recorder is None`` fast path) vs traced (``trace=True``
    span capture on every exec/send/recv), on two workloads:

    * *work* — steps do ~50µs of real computation each, the smallest
      plausible production step; the < 5% acceptance applies here;
    * *empty* — steps return constants, so every op is pure framework
      and tracing cost has nothing to amortise against.  This is the
      stress ceiling, reported for honesty, not gated.

    Each number is the **median of paired per-round ratios**: the two
    arms alternate within each round, because on a loaded container the
    machine drifts more between separate timing blocks than the
    few-percent signal being measured.
    """
    import statistics

    from repro import swirl

    n_instances = 100
    inst, fns, inputs = _serve_workload(n_instances)
    work_fns = {
        "ingest": lambda i: {"d_ingest": i["d_seed"] * 2 + 0 * _spin()},
        "work_a": lambda i: {"d_a": i["d_ingest"] + 1 + 0 * _spin()},
        "work_b": lambda i: {"d_b": i["d_ingest"] + 2 + 0 * _spin()},
        "merge": lambda i: (_spin(), {})[1],
    }
    plan = swirl.trace(inst).optimize()

    def paired_overhead(step_fns, rounds: int = 9):
        plain = plan.lower("threaded", timeout_s=60).compile(step_fns)
        traced = plan.lower(
            "threaded", timeout_s=60, trace=True
        ).compile(step_fns)
        # Warm both paths (thread pools, lazy imports) before timing.
        plain.run_many(inputs, max_concurrent=8)
        res = traced.run_many(inputs, max_concurrent=8)
        ratios, best_plain, best_traced = [], float("inf"), float("inf")
        for _ in range(rounds):
            t0 = time.perf_counter()
            plain.run_many(inputs, max_concurrent=8)
            dt_p = time.perf_counter() - t0
            t0 = time.perf_counter()
            traced.run_many(inputs, max_concurrent=8)
            dt_t = time.perf_counter() - t0
            ratios.append(dt_t / dt_p)
            best_plain = min(best_plain, dt_p)
            best_traced = min(best_traced, dt_t)
        overhead = (statistics.median(ratios) - 1.0) * 100.0
        spans = sum(len(r.profile.spans) for r in res)
        return overhead, best_plain, best_traced, spans

    over_work, dt_p, dt_t, spans = paired_overhead(work_fns)
    row(
        "obs/untraced_ips", f"{n_instances / dt_p:.1f}", "instances/s",
        f"{n_instances} instances, ~50µs steps, trace off",
    )
    row(
        "obs/traced_ips", f"{n_instances / dt_t:.1f}", "instances/s",
        f"{n_instances} instances, ~50µs steps, trace on "
        f"({spans} spans/batch)",
    )
    row(
        "obs/overhead_pct", f"{over_work:.1f}", "%",
        "median paired ratio, ~50µs steps — target < 5% (acceptance)",
    )
    over_empty, _, _, _ = paired_overhead(fns)
    row(
        "obs/overhead_empty_pct", f"{over_empty:.1f}", "%",
        "empty steps: every op is pure framework (stress ceiling)",
    )


def bench_gateway() -> None:
    """Workflow-as-a-service over HTTP: cache-hit serving + overload.

    Phase 1 submits three differently-shaped workflows (1-location chain,
    3-location diamond, 3-location fan-out), then drives a mixed stream of
    ``run_many`` batches from several keep-alive HTTP clients against the
    cached fingerprints — every request is a content-address cache hit.
    Acceptance: sustained >= 1000 instances/s aggregate, p50/p99 request
    latency and cache hit rate reported.

    Phase 2 overloads a tight tenant quota (2 in flight + 2 queued) with
    30 concurrent runs: the shed requests 429, every admitted run
    completes, and graceful close drains with nothing dropped.
    """
    import threading

    from repro.serve import (
        Gateway,
        GatewayClient,
        GatewayError,
        TenantConfig,
        WorkflowService,
    )

    shapes = {
        "chain": {
            "dag": {
                "edges": {"c_a": ["c_b"], "c_b": []},
                "mapping": {"c_a": ["l0"], "c_b": ["l0"]},
            }
        },
        "diamond": {
            "dag": {
                "edges": {
                    "d_pre": ["d_x", "d_y"],
                    "d_x": ["d_merge"],
                    "d_y": ["d_merge"],
                    "d_merge": [],
                },
                "mapping": {
                    "d_pre": ["l0"],
                    "d_x": ["l1"],
                    "d_y": ["l2"],
                    "d_merge": ["l0"],
                },
            }
        },
        "fan": {
            "dag": {
                "edges": {
                    "f_src": ["f_w1", "f_w2", "f_w3", "f_w4"],
                    "f_w1": [],
                    "f_w2": [],
                    "f_w3": [],
                    "f_w4": [],
                },
                "mapping": {
                    "f_src": ["l0"],
                    "f_w1": ["l1"],
                    "f_w2": ["l1"],
                    "f_w3": ["l2"],
                    "f_w4": ["l2"],
                },
            }
        },
    }

    def _steps():
        registry = {}
        for body in shapes.values():
            for s, succs in body["dag"]["edges"].items():
                if succs:
                    registry[s] = (
                        lambda inp, _d=f"d^{s}": {_d: 1}
                    )
                else:
                    registry[s] = lambda inp: {}
        return registry

    svc = WorkflowService(
        _steps(),
        tenants=[
            TenantConfig(
                "bench", api_key="bench", max_concurrent=64, max_queue=256
            )
        ],
        batch_max_concurrent=8,
    )
    n_clients, batches_per_client, batch_size = 6, 4, 50
    n_instances = n_clients * batches_per_client * batch_size
    latencies: list[float] = []
    lock = threading.Lock()
    with Gateway(svc) as gw:
        with GatewayClient(gw.url, api_key="bench") as c:
            fps = [
                c.submit(body)["fingerprint"] for body in shapes.values()
            ]
            for body in shapes.values():  # resubmits: source-digest hits
                assert c.submit(body)["cached"]

        def worker(i: int) -> None:
            with GatewayClient(gw.url, api_key="bench") as c:
                for b in range(batches_per_client):
                    fp = fps[(i + b) % len(fps)]  # mixed plan shapes
                    t0 = time.perf_counter()
                    r = c.run_many(fp, [{}] * batch_size)
                    dt = time.perf_counter() - t0
                    assert len(r["results"]) == batch_size
                    with lock:
                        latencies.append(dt)

        t0 = time.perf_counter()
        threads = [
            threading.Thread(target=worker, args=(i,))
            for i in range(n_clients)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        stats = svc.stats()

    ips = n_instances / wall
    lat = np.array(sorted(latencies))
    hit_rate = stats["cache"]["hit_rate"]
    row(
        "gateway/cache_hit_ips", f"{ips:.0f}", "instances/s",
        f"{n_instances} instances, {n_clients} HTTP clients, "
        f"3 shapes, batch={batch_size} (target >= 1000)",
    )
    row(
        "gateway/request_p50", f"{np.percentile(lat, 50) * 1e3:.1f}", "ms",
        f"run_many batch of {batch_size}",
    )
    row(
        "gateway/request_p99", f"{np.percentile(lat, 99) * 1e3:.1f}", "ms",
        f"n={len(lat)} requests",
    )
    row(
        "gateway/cache_hit_rate", f"{hit_rate:.3f}", "",
        f"compiles={stats['counters']['compiles']} of "
        f"{stats['counters']['submissions']} submissions",
    )
    assert stats["counters"]["instances_failed"] == 0

    # -- overload: tight quota, concurrent burst -----------------------------
    slow = WorkflowService(
        {
            "s_a": lambda inp: (time.sleep(0.05), {"d^s_a": 1})[1],
            "s_b": lambda inp: {},
        },
        tenants=[
            TenantConfig(
                "tight", api_key="tight", max_concurrent=2, max_queue=2
            )
        ],
    )
    burst = 30
    outcome = {"ok": 0, "429": 0}
    gw2 = Gateway(slow).start()
    with GatewayClient(gw2.url, api_key="tight") as c:
        fp = c.submit(
            {
                "dag": {
                    "edges": {"s_a": ["s_b"], "s_b": []},
                    "mapping": {"s_a": ["l0"], "s_b": ["l0"]},
                }
            }
        )["fingerprint"]

    def overload_worker() -> None:
        with GatewayClient(gw2.url, api_key="tight") as c:
            try:
                c.run(fp)
                with lock:
                    outcome["ok"] += 1
            except GatewayError as e:
                assert e.status == 429 and e.retry_after >= 1
                with lock:
                    outcome["429"] += 1

    threads = [
        threading.Thread(target=overload_worker) for _ in range(burst)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    drained = gw2.close(drain_timeout_s=10)
    counters = slow.stats()["counters"]
    assert outcome["ok"] + outcome["429"] == burst
    assert counters["instances_completed"] == outcome["ok"]
    assert counters["instances_failed"] == 0 and drained
    row(
        "gateway/overload_429", outcome["429"], "requests",
        f"burst={burst}, quota 2+2, served={outcome['ok']}",
    )
    row(
        "gateway/overload_dropped", 0, "runs",
        f"drained={drained}; every admitted run completed",
    )


def bench_chaos() -> None:
    """Elastic recovery under chaos: sustained throughput while workers die.

    Drives ``run_many`` batches through the multiprocess backend with a
    SIGKILL injected into every instance mid-flight, in both recovery
    modes: ``spare`` (the dead location's program is renamed onto a spare
    and a fresh fleet respawned) and ``fold`` (the pool is resized — the
    dead location's op array is spliced onto a survivor).  Acceptance:
    every chaos-run instance produces the unperturbed run's data modulo
    the recovery renaming, no step body re-executes after checkpointed
    completion, and throughput under sustained kills stays a reasonable
    fraction of the fault-free baseline.
    """
    from repro import swirl

    edges = {
        "c_pre": ["c_a", "c_b"],
        "c_a": ["c_join"],
        "c_b": ["c_join"],
        "c_join": ["c_out"],
        "c_out": [],
    }
    mapping = {
        "c_pre": ("n0",),
        "c_a": ("n1",),
        "c_b": ("n2",),
        "c_join": ("n1",),
        "c_out": ("n0",),
    }

    def steps():
        return {
            "c_pre": lambda inp: {"d^c_pre": list(range(64))},
            "c_a": lambda inp: {"d^c_a": sum(inp["d^c_pre"])},
            "c_b": lambda inp: {"d^c_b": max(inp["d^c_pre"])},
            "c_join": lambda inp: {
                "d^c_join": inp["d^c_a"] * inp["d^c_b"]
            },
            "c_out": lambda inp: {},
        }

    plan = swirl.trace(edges, mapping=mapping).optimize()
    clean = (
        plan.lower("multiprocess", timeout_s=60)
        .compile(steps())
        .run()
        .data
    )
    n = 8

    def fold_expect(ren):
        out: dict = {}
        for l, d in clean.items():
            out.setdefault(ren.get(l, l), {}).update(d)
        return out

    # Fault-free baseline throughput.
    exe = plan.lower("multiprocess", timeout_s=60).compile(steps())
    dt, results = _t(lambda: exe.run_many([None] * n), repeat=1)
    assert all(r.data == clean for r in results)
    baseline_ips = n / dt
    row(
        "chaos/baseline_ips", f"{baseline_ips:.1f}", "instances/s",
        f"{n} instances, 3 worker processes, no faults",
    )

    # Sustained kills, spare replacement: every instance loses the
    # c_join worker to SIGKILL and is renamed onto a spare location.
    mismatches, recoveries = 0, 0
    for mode, lower_opts in [
        ("spare", dict(recover="spare", spares=["hot0"])),
        ("fold", dict(recover="fold")),
    ]:
        exe = plan.lower(
            "multiprocess",
            timeout_s=120,
            _kill_at_step="c_join",
            **lower_opts,
        ).compile(steps())
        dt, results = _t(lambda: exe.run_many([None] * n), repeat=1)
        for r in results:
            recs = r.stats["recoveries"]
            recoveries += len(recs)
            ren = recs[0]["renaming"] if recs else {}
            if r.data != fold_expect(ren):
                mismatches += 1
        ips = n / dt
        row(
            f"chaos/{mode}_ips", f"{ips:.1f}", "instances/s",
            f"{n} instances, 1 SIGKILL each, "
            f"{ips / baseline_ips * 100:.0f}% of fault-free",
        )
    row(
        "chaos/recoveries", recoveries, "events",
        f"expected {2 * n} (one per killed instance)",
    )
    row(
        "chaos/result_mismatches", mismatches, "instances",
        "recovered data vs clean run modulo renaming (must be 0)",
    )
    assert mismatches == 0
    assert recoveries == 2 * n

    # -- stragglers: a delayed (never killed) c_join worker -------------------
    # The FaultPolicy progress heartbeat declares the silent worker dead and
    # elastic recovery reruns its step on a spare (rename) or a survivor
    # (fold); without a policy the run simply waits out the whole delay.
    import tempfile

    from repro.exec import FaultPolicy, RunDeadlineExceeded
    from repro.workflow.fault import SlowOnceAcrossProcesses

    delay_s = 8.0
    policy = FaultPolicy(heartbeat_interval_s=0.2, heartbeat_timeout_s=1.0)
    straggler_s: dict[str, float] = {}
    corrupted = 0
    with tempfile.TemporaryDirectory() as tmp:
        for mode, opts in [
            ("spare", dict(policy=policy, recover="spare", spares=["hot0"])),
            ("fold", dict(policy=policy, recover="fold")),
            ("no_policy", {}),
        ]:
            fns = steps()
            fns["c_join"] = SlowOnceAcrossProcesses(
                fns["c_join"],
                flag_path=str(Path(tmp) / f"straggle-{mode}"),
                delay_s=delay_s,
            )
            exe = plan.lower(
                "multiprocess", timeout_s=120, **opts
            ).compile(fns)
            dt, res = _t(exe.run, repeat=1)
            recs = res.stats.get("recoveries") or []
            ren = recs[0]["renaming"] if recs else {}
            if res.data != fold_expect(ren):
                corrupted += 1
            if mode == "no_policy":
                detail = f"{delay_s:.0f}s straggler, no fault policy"
            else:
                assert len(recs) == 1
                assert recs[0]["declared_by"] == "heartbeat"
                detail = (
                    f"{delay_s:.0f}s straggler declared dead by heartbeat "
                    f"after {policy.heartbeat_timeout_s:.0f}s silence"
                )
            straggler_s[mode] = dt
            row(f"chaos/straggler_{mode}_s", f"{dt:.2f}", "s", detail)
    row(
        "chaos/straggler_corrupted", corrupted, "runs",
        "straggler-run data vs clean run modulo renaming (must be 0)",
    )
    assert corrupted == 0
    # Recovery must beat sitting out the delay, in both modes.
    assert straggler_s["spare"] < straggler_s["no_policy"]
    assert straggler_s["fold"] < straggler_s["no_policy"]

    # -- whole-run deadline: typed abort, promptly ----------------------------
    slow = steps()
    slow["c_join"] = lambda inp: (time.sleep(30), {"d^c_join": 0})[1]
    exe = plan.lower(
        "threaded", timeout_s=60, policy=FaultPolicy(deadline_s=0.5)
    ).compile(slow)
    t0 = time.perf_counter()
    try:
        exe.run()
        aborted = False
    except RunDeadlineExceeded:
        aborted = True
    abort_s = time.perf_counter() - t0
    row(
        "chaos/deadline_abort_s", f"{abort_s:.2f}", "s",
        "0.5s run deadline over a 30s straggling c_join (threaded)",
    )
    assert aborted and abort_s < 5.0


def bench_bisim() -> None:
    from repro.core import encode, rewrite_system, weak_barbed_bisimilar
    from repro.core.semantics import reachable_states
    from repro.core.translate import genomes_1000

    inst = genomes_1000(n=2, m=2, a=1, b=1, c=1)
    w = encode(inst)
    o, _ = rewrite_system(w)
    dt, states = _t(lambda: len(reachable_states(w, max_states=100_000)))
    row("bisim/states_W", states, "states", f"explore={dt * 1e3:.0f}ms")
    dt, ok = _t(lambda: weak_barbed_bisimilar(w, o, max_states=100_000), repeat=1)
    row("bisim/check", f"{dt * 1e3:.0f}", "ms", f"bisimilar={ok}")


def bench_kernels() -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention

    key = jax.random.key(0)
    b, hq, hkv, l, d = 1, 4, 2, 512, 64
    q = jax.random.normal(key, (b, hq, l, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, hkv, l, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, hkv, l, d))

    out = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(out - want)))
    row("kernels/flash_attn_maxerr", f"{err:.2e}", "abs", f"shape={q.shape}")

    fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True))
    fn(q, k, v).block_until_ready()
    dt, _ = _t(lambda: fn(q, k, v).block_until_ready())
    row("kernels/xla_ref_latency", f"{dt * 1e3:.2f}", "ms", "CPU jit reference")


def bench_train() -> None:
    from repro.launch.train import train

    t0 = time.perf_counter()
    out = train(
        "llama3.2-3b", smoke=True, steps=5, n_pods=2,
        global_batch=4, seq_len=32, ckpt_dir=None, log_every=100,
    )
    dt = time.perf_counter() - t0
    losses = [float(h["loss"]) for h in out["history"]]
    row(
        "train/swirl_2pod_smoke", f"{dt / 5:.2f}", "s/step",
        f"loss {losses[0]:.3f}->{losses[-1]:.3f}",
    )


def bench_roofline() -> None:
    d = Path("experiments/dryrun")
    if not d.exists():
        row("roofline/dryrun", "missing", "", "run repro.launch.dryrun --all")
        return
    recs = [json.loads(p.read_text()) for p in sorted(d.glob("*.json"))]
    ok = [r for r in recs if r.get("status") == "ok"]
    skips = [r for r in recs if r.get("status") == "skipped"]
    row("roofline/cells_ok", len(ok), "cells", f"skipped={len(skips)}")
    for r in ok:
        if r["mesh"] != "pod1":
            continue
        rl = r["roofline"]
        row(
            f"roofline/{r['arch']}/{r['shape']}",
            f"{rl['bound_s']:.4g}", "s",
            f"dom={rl['dominant']} mfu_bound={rl['mfu_bound'] * 100:.1f}%",
        )


SECTIONS = {
    "encoding": bench_encoding,
    "optimise": bench_optimise,
    "runtime": bench_runtime,
    "dist": bench_dist,
    "dataplane": bench_dataplane,
    "sched": bench_sched,
    "compile": bench_compile,
    "serve": bench_serve,
    "obs": bench_obs,
    "gateway": bench_gateway,
    "chaos": bench_chaos,
    "bisim": bench_bisim,
    "kernels": bench_kernels,
    "train": bench_train,
    "roofline": bench_roofline,
}


def main() -> None:
    args = sys.argv[1:]
    emit_json = "--json" in args
    which = [a for a in args if a != "--json"] or list(SECTIONS)
    unknown = [name for name in which if name not in SECTIONS]
    if unknown:
        raise SystemExit(
            f"unknown sections {unknown}; known: {list(SECTIONS)}"
        )
    print("name,value,unit,derived")
    for name in which:
        _ROWS.clear()
        SECTIONS[name]()
        if emit_json:
            out = Path(f"BENCH_{name}.json")
            out.write_text(
                json.dumps(
                    {
                        "section": name,
                        "generated_unix": time.time(),
                        "python": platform.python_version(),
                        "platform": platform.platform(),
                        "rows": list(_ROWS),
                    },
                    indent=2,
                )
                + "\n"
            )
            print(f"# wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
