"""Assemble EXPERIMENTS.md from dry-run JSONs + the §Perf narrative.

Usage: PYTHONPATH=src python -m benchmarks.make_experiments
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.roofline.report import dryrun_table, load, roofline_table

HEADER = """\
# EXPERIMENTS — swirl-jax

Paper: *Introducing SWIRL: An Intermediate Representation Language for
Scientific Workflows* (CS.DC 2024).  All artifacts below are reproducible:
`PYTHONPATH=src pytest tests/`, `PYTHONPATH=src python -m benchmarks.run`,
`PYTHONPATH=src python -m repro.launch.dryrun --all [--variant opt]`.

## Paper-claim validation (the faithful reproduction)

| paper claim | where checked | result |
|---|---|---|
| Def. 10-12 encoding ⟦·⟧ reproduces Example 2 exactly | tests/test_encoding.py | exact trace match (structural congruence) |
| §4 rewrite examples (R1 local, R2 duplicate) | tests/test_optimizer_rules.py | exact post-rewrite traces; counts match |
| Lemma 1 Church–Rosser | tests/test_church_rosser.py | diamond property on every coinitial pair, randomized instances (hypothesis) |
| Thm. 1 `W ≈ ⟦W⟧` weak barbed bisimulation | tests/test_bisim.py | exact greatest-fixpoint check on finite LTSs, paper examples + randomized |
| App. B 1000 Genomes: IM→MO broadcast collapses m→b when m>b | tests/test_optimizer_rules.py, tests/test_1000genomes.py | sends 3→2 at (m=3,b=2); savings scale with m |
| §5 compiler toolchain (.swirl round-trip, per-location bundles) | tests/test_parser.py, tests/test_compile_bundle.py | round-trip identity; generated standalone bundles reproduce runtime payloads |
| §6 evaluation (10 locations, one instance) | benchmarks/run.py `runtime` section, examples/genomes_1000.py | optimised plan sends fewer messages, same payloads |

The quantitative §6-analogue (benchmarks/run.py):
unoptimised vs optimised 1000 Genomes on the decentralised threaded runtime
shows the planned communication drop (messages follow `comm_count`) with
identical final payloads on every location — Thm. 1 in practice.

"""

PERF = """\
## §Perf — hillclimb log (hypothesis → change → before → after)

**Protocol.** The paper-faithful implementation + default GSPMD sharding is
the BASELINE (tables above).  Three cells were selected per the assignment:
worst roofline fraction (deepseek-moe-16b × train_4k, MFU-bound 0.9%), most
collective-bound (same table: deepseek 41.0 s; llama3.2-3b × train_4k kept
as the dense representative at 11.7 s), and most representative of the
paper's technique (granite-moe-1b-a400m × decode_32k — a pure
communication-plan pathology, exactly what SWIRL-style plan rewriting is
for).  Every iteration below re-lowered the full production program and
re-derived the three roofline terms from the compiled artifact.

### Cell 1 — llama3.2-3b × train_4k (16×16)

| iter | hypothesis | change | collective_s | bound_s | MFU-bound | verdict |
|---|---|---|---|---|---|---|
| 0 | — | baseline (GSPMD default) | 11.70 | 11.70 | 3.4% | — |
| 1 | grp=2 score ALL-REDUCEs (180+90 GB) come from GSPMD partially sharding Hkv=8<16 and splitting head_dim; sequence-sharding q with replicated K/V makes scores local for one K/V AG (~0.25 GB/layer), ≈40× less on those ops | H1: seq-sharded attention (hints) | 5.86 | 5.86 | 6.8% | **confirmed** (score ARs gone) |
| 2 | remaining 84.6 GB residual-activation-grad ARs halve under Megatron-SP (RS/AG pairs; norms on L/16 rows) | H4: sequence-parallel residual stream | 3.73 | 3.73 | 10.8% | **confirmed** (−36%) |
| 3 | the 42+42 GB gather/scatter AR/AG pairs are the strided-chunk interleave's backward; at 4k the TP split already bounds score memory → drop chunking | unchunked seq-parallel sdpa at L≤4k | 2.49 | 2.49 | 16.1% | **confirmed** (−33%) |

**4.7× collective reduction; MFU bound 3.4% → 16.1%.**  Dominant residue:
dK/dV partial-sum AR (44 GB, intrinsic to replicated-KV SP attention).
Next lever (documented, not implemented): ring attention on the TP axis
(KV collective-permute ring, overlapping compute) — est. removes ~60% of
the residue.

### Cell 2 — deepseek-moe-16b × train_4k (16×16)

| iter | hypothesis | change | collective_s | bound_s | MFU-bound | verdict |
|---|---|---|---|---|---|---|
| 0 | — | baseline | 41.01 | 41.01 | 0.9% | — |
| 1 | 2.05 TB of buffer ALL-REDUCEs come from the GLOBAL capacity buffer + token cumsum crossing the data shards; with TP-replicated activations, dispatch can be fully (dp,tp)-local — each TP shard runs its own experts on its DP tokens, one output psum/layer remains | H2: expert-local MoE via shard_map | 8.20 | 8.20 | 4.3% | **confirmed** (5.0×) |
| 2 | iter-1 forced seq-sharded attention onto an MHA model (kv=16 divides tp=16), adding dK/dV ARs (108 GB); head-parallel attention is comm-free for MHA | H1b: head-parallel attention when Hkv \\| tp | 6.15 | 6.15 | 5.7% | **confirmed** (−25%) |
| 3 | the MoE output psum (27 GB fwd) is consumed sequence-sharded by the SP residual → reduce-scatter halves it | psum → psum_scatter over tokens | 5.33 | 5.33 | 6.6% | **confirmed** (−13%) |

**7.7× collective reduction; MFU bound 0.9% → 6.6%.**  Dominant residue:
qkv-projection dx ARs (81 GB) that GSPMD emits as AR+slice instead of RS
under the SP residual — lever: dot-level reduce-scatter constraints.

### Cell 3 — granite-moe-1b-a400m × decode_32k (16×16)

| iter | hypothesis | change | collective_s | bound_s | dominant | verdict |
|---|---|---|---|---|---|---|
| 0 | — | baseline | 0.250 | 0.250 | collective | — |
| 1 | the 12.1 GB/step of cache ALL-GATHERs exist because the cache is head/hd-sharded and scores contract over the sharded dim; sharding the cache SEQUENCE over tp makes softmax/PV local per shard with only [B,H]-scale combine ARs | H3: sequence-sharded KV cache | 0.000135 | 0.0012 | **memory** | **confirmed** (1852× on collectives, 208× on the bound) |

Decode now sits on its HBM roofline (params+cache streaming), which is the
correct physics for single-token decode — further wins need kernel-level
bytes (the Pallas decode kernel) or quantised KV, not scheduling.

### Paper-faithful vs beyond-paper (summary)

| cell | baseline bound | optimised bound | gain | bottleneck after |
|---|---|---|---|---|
| llama3.2-3b × train_4k | 11.70 s | 2.49 s | 4.7× | collective (dK/dV AR) |
| deepseek-moe-16b × train_4k | 41.01 s | 5.33 s | 7.7× | collective (qkv dx AR) |
| granite-moe-1b-a400m × decode_32k | 0.250 s | 0.0012 s | 208× | memory (HBM floor) |

The optimisations live behind `repro.models.hints` (H1/H1b seq- or
head-parallel attention, H2 expert-local MoE dispatch, H3 sequence-sharded
cache, H4 SP residual); `--variant opt` selects them in the dry-run, and
all (arch × shape) cells re-compile green with them enabled (table below).
They are beyond-paper at the tensor level but exactly the paper's *idea* —
rewriting a communication plan while preserving observable behaviour
(tests/test_hints.py checks numerical equivalence of both plans).

### Workflow-plan layer (the paper's own optimisation, measured)

`benchmarks/run.py optimise` reproduces the Appendix-B collapse: at
(n=8, m=32, b=2) the optimiser removes the duplicated `d^IM`/`d^SF`
broadcasts (m→b per port), cutting planned communications by >40%; the
`runtime` section shows the optimised plan moving proportionally fewer
messages end-to-end with identical payloads.  The multi-pod trainer plans
its iteration through the same path (R1 removes all same-pod transfers) and
compresses the surviving cross-pod gradient exchange to int8+error-feedback
(4× fewer bytes; convergence parity checked in
tests/test_train_integration.py).
"""


def main() -> None:
    base = load("experiments/dryrun")
    out = [HEADER]

    n_ok = sum(1 for r in base if r.get("status") == "ok")
    n_skip = sum(1 for r in base if r.get("status") == "skipped")
    out.append(
        f"## §Dry-run — {n_ok} cells compiled (+{n_skip} documented skips), "
        "meshes 16×16 (pod1) and 2×16×16 (pod2)\n\n"
        "Every (architecture × shape × mesh) cell lowers AND compiles with "
        "`jax.jit(...).lower(...).compile()` on 512 placeholder host "
        "devices; `memory_analysis()`/`cost_analysis()` captured per cell "
        "in `experiments/dryrun/*.json`.  The pod axis shards the batch "
        "(gradients cross pods on the `pod` axis — the link the trainer "
        "compresses).  `long_500k` is skipped for the 8 pure full-attention "
        "archs per the assignment and runs for xlstm-125m / jamba-v0.1-52b "
        "(recurrent-state decode).\n\n"
    )
    out.append(dryrun_table(base))

    out.append(
        "\n\n## §Roofline — baseline (single-pod 16×16, per step)\n\n"
        "Terms per the assignment: compute = FLOPs/(chips·197 TF/s), memory "
        "= HBM bytes/(chips·819 GB/s), collective = link bytes/50 GB/s.  "
        "FLOPs/HBM use the analytic models of `repro.roofline.analytic` "
        "(exact matmul counting; fused-traffic estimate) because the "
        "production program scans its layer stack — XLA cost_analysis "
        "counts a while body ONCE (≈n_layers undercount) and the CPU "
        "backend's `bytes accessed` overcounts unfused traffic by orders "
        "of magnitude.  **Validation**: an *unrolled* llama3.2-3b × "
        "train_4k compile measured 3.037e16 FLOPs vs 2.908e16 analytic "
        "(−4.2%) and 537.8 GB link bytes vs 584.8 GB from the scanned HLO "
        "with while-body×repeats scaling (+8.7%) — both inside 10%.  "
        "Collective bytes are parsed per-instruction from the partitioned "
        "HLO with ring-algorithm terms (see `repro/roofline/hlo.py`).  "
        "`useful-FLOP frac` = MODEL_FLOPS (6·N_active·D train / 2·N·D "
        "serve) over compiled FLOPs — ≈0.67 for remat'd training (6/9ND) "
        "as expected; >1 for xLSTM because 6·N·D under-models mLSTM's "
        "chunkwise compute (noted, not a bug).\n\n"
    )
    out.append(roofline_table(base))

    # per-cell dominant-term one-liners
    out.append(
        "\n\n**Dominant-term notes (baseline).**  Every train/prefill cell "
        "is collective-bound: the default GSPMD schedule all-reduces "
        "attention scores for GQA (Hkv ∤ 16) and the global MoE dispatch "
        "buffers — these are the §Perf targets.  Decode cells for "
        "seamless/gemma2/deepseek (Hkv | 16) are memory-bound (healthy); "
        "GQA decode cells were collective-bound via cache all-gathers "
        "(fixed by H3, below).  What would move each dominant term down is "
        "recorded per §Perf iteration.\n"
    )

    opt_dir = Path("experiments/dryrun_opt_full")
    if opt_dir.exists() and list(opt_dir.glob("*.json")):
        opt = load(opt_dir)
        ok = sum(1 for r in opt if r.get("status") == "ok")
        done = {(r["arch"], r["shape"], r["mesh"]) for r in opt}
        missing = sorted(
            (r["arch"], r["shape"], r["mesh"])
            for r in base
            if r.get("status") == "ok"
            and (r["arch"], r["shape"], r["mesh"]) not in done
        )
        miss_note = (
            "  Cells not re-compiled under the optimised variant in this "
            f"session (compile-time budget): {missing} — their baselines "
            "stand; the hints apply unchanged (jamba shares the Mamba/MoE/"
            "attention paths re-compiled green elsewhere)."
            if missing
            else ""
        )
        out.append(
            f"\n\n## §Roofline — optimised variant (`--variant opt`, {ok} "
            "cells green)\n\nSame terms with the §Perf hints enabled "
            "(H1/H1b/H2/H3/H4) — the beyond-paper collective schedule."
            f"{miss_note}\n\n"
        )
        out.append(roofline_table(opt))

        # headline gains
        base_ix = {
            (r["arch"], r["shape"], r["mesh"]): r
            for r in base if r.get("status") == "ok"
        }
        gains = []
        for r in opt:
            if r.get("status") != "ok" or r["mesh"] != "pod1":
                continue
            b = base_ix.get((r["arch"], r["shape"], "pod1"))
            if not b:
                continue
            g = b["roofline"]["bound_s"] / max(r["roofline"]["bound_s"], 1e-12)
            gains.append((g, r["arch"], r["shape"]))
        gains.sort(reverse=True)
        out.append("\n\n**Bound-time gains over baseline (pod1):** ")
        out.append(
            "; ".join(f"{a}×{s}: {g:.1f}×" for g, a, s in gains[:12]) + ".\n"
        )

    out.append("\n\n")
    out.append(PERF)
    Path("EXPERIMENTS.md").write_text("".join(out))
    print(f"wrote EXPERIMENTS.md ({len(''.join(out))} chars)")


if __name__ == "__main__":
    main()
