"""Paper §6 / Appendix B: the 1000 Genomes workflow, end to end.

Ten locations, one chromosome (one instance), numeric step bodies; runs on
BOTH runtimes and reports what the paper's optimisation saved.

Run: ``PYTHONPATH=src python examples/genomes_1000.py``
"""

import time

import numpy as np

from repro import swirl
from repro.exec import emit_location_source
from repro.core.translate import genomes_1000

# n individuals over a locations; m mutation_overlap / frequency steps over
# b / c locations — Table 1's shape, with m > b so R2 has work to do.
inst = genomes_1000(n=4, m=4, a=2, b=2, c=2)
print(f"locations: {sorted(inst.locations)}")

raw = swirl.trace(inst)
plan = raw.optimize()
stats = plan.stats
print(
    f"plan: {raw.system.total_actions()} actions, "
    f"{raw.system.comm_count()} comms; optimiser removed {stats.removed} "
    f"(local {stats.removed_local}, duplicate {stats.removed_duplicate})"
)

# Step bodies: individuals sort their chunk, individuals_merge averages,
# sifting filters, mutation_overlap / frequency reduce to statistics.
rng = np.random.default_rng(0)
init = {("l^d", d): rng.random(4096) for d in inst.g("l^d")}


def make_fns():
    fns = {}
    for s in inst.workflow.steps:
        outs = inst.out_data(s)
        if s == "s0":
            fns[s] = lambda i, outs=outs: {o: init[("l^d", o)] for o in outs}
        elif s.startswith("sI_"):
            fns[s] = lambda i, outs=outs: {
                o: np.sort(list(i.values())[0]) for o in outs
            }
        elif s == "sIM":
            fns[s] = lambda i, outs=outs: {
                o: np.mean(np.stack([i[k] for k in sorted(i)]), axis=0)
                for o in outs
            }
        elif s == "sSF":
            fns[s] = lambda i, outs=outs: {
                o: (lambda d: d[d > 0.5])(list(i.values())[0]) for o in outs
            }
        else:
            fns[s] = lambda i, outs=outs: {
                o: float(sum(np.sum(v) for v in i.values())) for o in outs
            }
    return fns


for label, staged in (("unoptimised", raw), ("optimised", plan)):
    t0 = time.perf_counter()
    result = (
        staged.lower("threaded", timeout_s=60)
        .compile(make_fns())
        .run(initial_payloads=dict(init))
    )
    dt = time.perf_counter() - t0
    print(
        f"{label:12s}: {dt * 1e3:6.1f} ms, "
        f"{result.stats['sent']} messages"
    )

# Cross-check against the reduction-semantics (inprocess) backend.
result2 = (
    plan.lower("inprocess")
    .compile(make_fns())
    .run(initial_payloads=dict(init))
)
print(
    "sMO_1 statistic:",
    result2.location_data("l^MO_1").get("d^MO_1", "<reduced>"),
)

# Peek at one generated self-contained bundle (paper §5's compiler output),
# emitted straight from the per-location program IR.
program = plan.exec_program()["l^IM"]
print("\n--- generated bundle for l^IM (first 400 chars) ---")
print(emit_location_source(program)[:400])
