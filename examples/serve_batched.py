"""Compile-once / run-many workflow serving (``Executable.run_many``).

A serving-shaped workflow — ingest fans out to two workers whose results
merge — is traced, optimised and lowered **once**; then a batch of request
instances streams through the same per-location program IR over one shared
transport.  The naive serving loop pays trace → optimize → lower → compile
for every request; ``run_many`` amortises all of it and pipelines the
instances through persistent location threads.

Run: ``PYTHONPATH=src python examples/serve_batched.py``
"""

import time

from repro import swirl
from repro.core.graph import DistributedWorkflowInstance, make_workflow

N = 40

workflow = make_workflow(
    ["ingest", "work_a", "work_b", "merge"],
    ["p_seed", "p_ingest", "p_a", "p_b"],
    [
        ("p_seed", "ingest"),
        ("ingest", "p_ingest"),
        ("p_ingest", "work_a"),
        ("p_ingest", "work_b"),
        ("work_a", "p_a"),
        ("work_b", "p_b"),
        ("p_a", "merge"),
        ("p_b", "merge"),
    ],
)
inst = DistributedWorkflowInstance(
    workflow=workflow,
    locations=frozenset({"gateway", "pool_a", "pool_b"}),
    mapping={
        "ingest": ("gateway",),
        "work_a": ("pool_a",),
        "work_b": ("pool_b",),
        "merge": ("gateway",),
    },
    data=frozenset({"d_seed", "d_ingest", "d_a", "d_b"}),
    placement={
        "d_seed": "p_seed",
        "d_ingest": "p_ingest",
        "d_a": "p_a",
        "d_b": "p_b",
    },
    initial_data={"gateway": frozenset({"d_seed"})},
)
steps = {
    "ingest": lambda i: {"d_ingest": i["d_seed"] * 2},
    "work_a": lambda i: {"d_a": i["d_ingest"] + 1},
    "work_b": lambda i: {"d_b": i["d_ingest"] + 2},
    "merge": lambda i: {},
}
requests = [{("gateway", "d_seed"): i} for i in range(N)]

# Naive serving: the full pipeline per request.
t0 = time.perf_counter()
naive = [
    swirl.trace(inst)
    .optimize()
    .lower("threaded")
    .compile(steps)
    .run(initial_payloads=r)
    for r in requests
]
dt_naive = time.perf_counter() - t0

# Compile-once serving: one Executable, one run_many batch.
executable = swirl.trace(inst).optimize().lower("threaded").compile(steps)
t0 = time.perf_counter()
batch = executable.run_many(requests, max_concurrent=8)
dt_batch = time.perf_counter() - t0

assert [r.data for r in batch] == [r.data for r in naive]
for i, result in enumerate(batch):
    assert result.payload("pool_a", "d_a") == 2 * i + 1
    assert result.payload("pool_b", "d_b") == 2 * i + 2

print(
    f"per-request pipeline : {N / dt_naive:7.1f} instances/s"
    f"  ({dt_naive * 1e3 / N:.2f} ms/request)"
)
print(
    f"compile-once run_many: {N / dt_batch:7.1f} instances/s"
    f"  ({dt_batch * 1e3 / N:.2f} ms/request)"
)
print(f"speedup: {dt_naive / dt_batch:.1f}x")

# The same compile-once idea at the model level: prefill + KV-cache greedy
# decode on the xLSTM smoke config (O(1)-state decode).
from repro.launch.serve import serve  # noqa: E402

out = serve("xlstm-125m", smoke=True, batch=2, prompt_len=16, gen=8)
assert out["tokens"].shape == (2, 8)
print("OK")
