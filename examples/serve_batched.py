"""Batched serving example: prefill + KV-cache greedy decode.

Serves the xLSTM smoke model (O(1)-state decode — the ``long_500k`` path)
and a GQA transformer side by side.

Run: ``PYTHONPATH=src python examples/serve_batched.py``
"""

from repro.launch.serve import serve

for arch in ("xlstm-125m", "llama3.2-3b", "granite-moe-1b-a400m"):
    out = serve(arch, smoke=True, batch=4, prompt_len=32, gen=16)
    assert out["tokens"].shape == (4, 16)
print("OK")
