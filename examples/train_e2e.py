"""End-to-end driver: a few hundred training steps through the SWIRL plan.

Trains a reduced llama-family model (CPU-sized; the same driver trains the
full configs on a real mesh) for 200 steps across 2 emulated pods with int8
error-feedback gradient compression on the cross-pod sync, checkpointing
every iteration-boundary, and prints the loss curve.

Run: ``PYTHONPATH=src python examples/train_e2e.py [--steps 200]``
"""

import argparse
import tempfile

from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--pods", type=int, default=2)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        out = train(
            "llama3.2-3b",  # smoke variant: same family, CPU-sized
            smoke=True,
            steps=args.steps,
            n_pods=args.pods,
            global_batch=8,
            seq_len=64,
            ckpt_dir=ckpt_dir,
            log_every=20,
        )
    losses = [float(h["loss"]) for h in out["history"]]
    drop = losses[0] - min(losses[len(losses) // 2 :])
    print(f"loss: {losses[0]:.4f} → {losses[-1]:.4f} (best-half Δ {drop:.4f})")
    assert drop > 0.05, "training did not make progress"
    print("OK")


if __name__ == "__main__":
    main()
