"""Cost-model-driven placement on the 1000 Genomes workflow.

Demonstrates the ``repro.sched`` layer: a two-rack network cost model, the
makespan simulator, and ``Plan.schedule`` / ``placement="auto"`` lowering —
the scheduler co-locates producers with consumers, the R1/R2 rewrite then
deletes the now-local communications, and the threaded backend moves
measurably fewer messages.

Run: ``PYTHONPATH=src python examples/schedule_placement.py``
"""

import time

import numpy as np

from repro import swirl
from repro.core.translate import genomes_1000
from repro.sched import CostModel, NetworkModel, SizeModel, simulate

inst = genomes_1000(n=4, m=4, a=2, b=2, c=2)
network = NetworkModel.preset("two-rack")
sizes = SizeModel(default_bytes=8 * 65536)  # 64k-float arrays
costs = CostModel(default_exec_s=2e-3)

plan = swirl.trace(inst).optimize()
print("== original placement ==")
sim = simulate(plan.system, network=network, sizes=sizes, costs=costs,
               exec_slots=1)
print(sim.summary())

print("\n== scheduled (two-rack, makespan objective) ==")
sched = plan.schedule(network, sizes=sizes, costs=costs)
print(sched.schedule_report.summary())

# Run both on the threaded backend and compare real message counts.
rng = np.random.default_rng(0)
init = {("l^d", d): rng.random(65536) for d in inst.g("l^d")}


def make_fns():
    fns = {}
    for s in inst.workflow.steps:
        outs = inst.out_data(s)
        if s == "s0":
            fns[s] = lambda i, outs=outs: {o: init[("l^d", o)] for o in outs}
        else:
            fns[s] = lambda i, outs=outs: {
                o: float(sum(np.sum(np.asarray(v)) for v in i.values()))
                for o in outs
            }
    return fns


for label, p in (("original", plan), ("scheduled", sched)):
    t0 = time.perf_counter()
    result = (
        p.lower("threaded", timeout_s=60)
        .compile(make_fns())
        .run(initial_payloads=dict(init))
    )
    dt = time.perf_counter() - t0
    print(
        f"{label:10s}: {dt * 1e3:6.1f} ms wall, "
        f"{result.stats['sent']} messages, "
        f"{p.system.comm_count()} comms planned"
    )
