"""Workflow-as-a-service over HTTP: the gateway quickstart.

Starts an in-process gateway (``repro.serve``) whose step registry holds
the 1000 Genomes step bodies, then drives it the way a remote client
would — over plain HTTP/1.1 with keep-alive:

1. ``POST /v1/workflows`` with the workflow's ``.swirl`` text — the
   service compiles it through trace → optimize → lower → compile once
   and returns its content-address fingerprint;
2. resubmit — a cache hit, nothing recompiles;
3. ``POST /v1/workflows/{fp}/run`` and ``.../run_many`` — instances
   execute on the shared threaded Executable;
4. ``GET /v1/stats`` — cache hit rate + per-tenant admission counters;
5. graceful shutdown — in-flight work drains before the socket closes.

Run: ``PYTHONPATH=src python examples/gateway_client.py``
"""

from repro import swirl
from repro.core.parser import dumps
from repro.core.translate import genomes_1000
from repro.serve import Gateway, GatewayClient, GatewayError, WorkflowService

# -- the server side ---------------------------------------------------------
# The operator deploys the service with a step registry; submissions may
# only reference registered steps.  Bodies are plain Python working on
# JSON-able values (lists/floats) so results travel over the wire.
inst = genomes_1000(n=2, m=2, a=1, b=1, c=1)
SEED = {d: [float(i + 1), float(i + 2)] for i, d in enumerate(sorted(inst.g("l^d")))}


def make_registry():
    fns = {}
    for s in inst.workflow.steps:
        outs = inst.out_data(s)
        if s == "s0":  # the driver step: emits the chromosome chunks
            fns[s] = lambda i, outs=outs: {o: SEED[o] for o in outs}
        elif s.startswith("sI_"):  # individuals: sort the chunk
            fns[s] = lambda i, outs=outs: {
                o: sorted(next(iter(i.values()))) for o in outs
            }
        elif s == "sIM":  # individuals_merge: element-wise mean
            fns[s] = lambda i, outs=outs: {
                o: [
                    sum(vals) / len(vals)
                    for vals in zip(*(i[k] for k in sorted(i)))
                ]
                for o in outs
            }
        elif s == "sSF":  # sifting: keep values above threshold
            fns[s] = lambda i, outs=outs: {
                o: [v for v in next(iter(i.values())) if v > 2.0]
                for o in outs
            }
        else:  # mutation_overlap / frequency: reduce to a statistic
            fns[s] = lambda i, outs=outs: {
                o: float(sum(sum(v) for v in i.values())) for o in outs
            }
    return fns


service = WorkflowService(make_registry())
text = dumps(swirl.trace(inst).system)

with Gateway(service) as gateway:
    print(f"gateway listening on {gateway.url}")

    # -- the client side -----------------------------------------------------
    with GatewayClient(gateway.url) as client:
        receipt = client.submit({"swirl": text})
        fp = receipt["fingerprint"]
        print(
            f"submitted: fingerprint {fp[:16]}…  cached={receipt['cached']} "
            f"({receipt['actions']} actions, "
            f"{receipt['communications']} comms)"
        )
        assert receipt["cached"] is False

        again = client.submit({"swirl": text})
        assert again["fingerprint"] == fp and again["cached"] is True
        print("resubmitted: cache hit, no recompile")

        result = client.run(fp)
        final = result["data"]["l^IM"]["d^IM"]
        expect = [
            sum(vals) / len(vals)
            for vals in zip(sorted(SEED["d0_1"]), sorted(SEED["d0_2"]))
        ]
        assert final == expect, (final, expect)
        print(f"ran one instance: individuals_merge -> {final}")

        batch = client.run_many(fp, [{}] * 8, max_concurrent=4)
        assert len(batch["results"]) == 8
        assert all(
            r["data"]["l^IM"]["d^IM"] == expect for r in batch["results"]
        )
        print("ran a batch of 8 through the shared Executable")

        # Malformed submissions are structured 400s, never tracebacks.
        try:
            client.submit({"swirl": "<l, {d},\n  frobnicate(s)>"})
        except GatewayError as e:
            assert e.status == 400 and e.error["kind"] == "swirl-syntax"
            print(
                "malformed submission -> HTTP 400 "
                f"(line {e.error['line']}, column {e.error['column']})"
            )

        stats = client.stats()
        cache = stats["cache"]
        print(
            f"stats: {stats['counters']['instances_completed']} instances, "
            f"cache hit rate {cache['hit_rate']:.0%}, "
            f"{stats['counters']['compiles']} compile(s)"
        )
        assert stats["counters"]["compiles"] == 1
        assert stats["counters"]["instances_failed"] == 0

# Leaving the ``with`` block drained admitted work, then closed the socket.
print("gateway drained and closed. OK")
