"""Distributed execution: the multiprocess backend over real OS processes.

The ``multiprocess`` backend takes SWIRL's "distributed by design" claim
literally on one machine: every location (group) becomes its own worker
process and COMM messages cross the ack-based socket transport
(``multiprocessing.connection`` with pickle framing and resend on ack
timeout) — no shared memory, exactly like the paper's generated TCP
bundles.  This example shows:

1. the default one-process-per-location lowering (distinct PIDs);
2. cost-model scheduling pinning each network rack to one worker process;
3. a worker crash surfacing as a typed ``WorkerFailedError`` and the
   coordinator's checkpoint resuming the run without re-executing the
   steps that already finished.

Run: ``PYTHONPATH=src python examples/distributed_multiprocess.py``
"""

import os

from repro import swirl
from repro.backends import WorkerFailedError
from repro.core.translate import genomes_1000
from repro.sched import NetworkModel

# -- 1. one OS process per location ----------------------------------------

inst = genomes_1000(n=2, m=2, a=1, b=1, c=1)
plan = swirl.trace(inst).optimize()

step_fns = {}
for s in inst.workflow.steps:
    outs = inst.out_data(s)
    step_fns[s] = lambda i, s=s, outs=outs: {
        o: f"{s}({','.join(sorted(map(str, i)))})" for o in outs
    }
init = {("l^d", d): f"chr:{d}" for d in inst.g("l^d")}

exe = plan.lower("multiprocess", timeout_s=60).compile(step_fns)
result = exe.run(initial_payloads=dict(init))
pids = result.stats["pids"]
print(f"coordinator pid {os.getpid()}; {result.stats['workers']} workers:")
for wid, group in result.stats["groups"].items():
    print(f"  worker {wid} (pid {pids[wid]}): {', '.join(group)}")
assert len(set(pids.values())) == result.stats["workers"]

threaded = (
    plan.lower("threaded", timeout_s=60)
    .compile(step_fns)
    .run(initial_payloads=dict(init))
)
assert result.data == threaded.data
print("multiprocess == threaded: identical final stores\n")

# -- 2. schedule placement → process pinning --------------------------------

net = NetworkModel.preset("two-rack").bind(sorted(inst.locations))
sched = plan.schedule(net)
pinned = (
    sched.lower("multiprocess", timeout_s=60)
    .compile(step_fns)
    .run(initial_payloads=dict(init))
)
print(f"two-rack schedule → {pinned.stats['workers']} pinned workers:")
for wid, group in pinned.stats["groups"].items():
    print(f"  worker {wid}: {', '.join(group)}")

# -- 3. worker failure, checkpoint, resume ----------------------------------

victim = sorted(inst.workflow.steps)[-1]
crashing = plan.lower(
    "multiprocess", _kill_at_step=victim, timeout_s=60
).compile(step_fns)
try:
    crashing.run(initial_payloads=dict(init))
except WorkerFailedError as e:
    print(f"\ninjected crash: {e}")
    ckpt = crashing.checkpoint()
    print(
        f"checkpoint holds {len(ckpt.completed_execs)} completed steps; "
        "resuming..."
    )
    resumed = (
        plan.lower("multiprocess", timeout_s=60)
        .compile(step_fns)
        .restore(ckpt)
        .run(initial_payloads=dict(init))
    )
    assert resumed.data == result.data
    print("resumed run matches the clean run")

print("OK")
