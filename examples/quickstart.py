"""Quickstart: a 5-step DAG through the staged-compilation pipeline.

``swirl.trace`` encodes the DAG into a SWIRL plan, ``.optimize()`` applies
the paper's rewriting rules (with a machine-checked bisimulation
certificate), ``.lower(backend)`` picks an execution target by name, and
``.compile(steps).run()`` executes it.  The same plan runs on all four
in-tree backends with identical results — including ``multiprocess``,
which gives every location its own OS process.

Run: ``PYTHONPATH=src python examples/quickstart.py``
"""

from repro import swirl

# 1. Describe the workflow: preprocess fans out to two trainers, whose
#    outputs meet in an evaluation step; a report consumes the evaluation.
edges = {
    "preprocess": ["train_a", "train_b"],
    "train_a": ["evaluate"],
    "train_b": ["evaluate"],
    "evaluate": ["report"],
    "report": [],
}
mapping = {
    "preprocess": ("cpu0",),
    "train_a": ("gpu0",),
    "train_b": ("gpu1",),
    "evaluate": ("gpu0",),  # co-located with train_a → R1 kicks in
    "report": ("cpu0",),
}

# 2. trace → Plan, then optimise with the paper's ⟦·⟧ rewriting.  The
#    certificate is Thm. 1 checked mechanically: plan ≈ optimised plan.
plan = swirl.trace(edges, mapping=mapping).optimize(certify=True)
print(plan.explain())

# 3. Attach step bodies, lower to a backend by name, and run.
step_fns = {
    "preprocess": lambda inp: {"d^preprocess": list(range(10))},
    "train_a": lambda inp: {"d^train_a": sum(inp["d^preprocess"])},
    "train_b": lambda inp: {"d^train_b": max(inp["d^preprocess"])},
    "evaluate": lambda inp: {
        "d^evaluate": inp["d^train_a"] + inp["d^train_b"]
    },
    # sink step: no output ports — the score stays in cpu0's data scope
    "report": lambda inp: {},
}

for backend in ("inprocess", "threaded", "multiprocess", "jax"):
    result = plan.lower(backend).compile(step_fns).run()
    score = result.payload("cpu0", "d^evaluate")
    print(f"{backend:>10}: score = {score}")
    assert score == 54  # identical on every backend (bisimulation!)

print("OK")
