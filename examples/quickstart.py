"""Quickstart: a 5-step DAG → SWIRL plan → optimised → executed.

Run: ``PYTHONPATH=src python examples/quickstart.py``
"""

from repro.core import DagTranslator, optimize
from repro.workflow import Runtime

# 1. Describe the workflow: preprocess fans out to two trainers, whose
#    outputs meet in an evaluation step; a report consumes the evaluation.
translator = DagTranslator(
    edges={
        "preprocess": ["train_a", "train_b"],
        "train_a": ["evaluate"],
        "train_b": ["evaluate"],
        "evaluate": ["report"],
        "report": [],
    },
    mapping={
        "preprocess": ("cpu0",),
        "train_a": ("gpu0",),
        "train_b": ("gpu1",),
        "evaluate": ("gpu0",),  # co-located with train_a → R1 kicks in
        "report": ("cpu0",),
    },
)

# 2. Encode with the paper's ⟦·⟧ and apply the rewriting optimiser.
plan = translator.translate()
optimised, stats = optimize(plan)
print("=== SWIRL plan (optimised) ===")
print(optimised.pretty())
print(f"\ncommunications: {plan.comm_count()} -> {optimised.comm_count()} "
      f"(R1/R2 removed {stats.removed})\n")

# 3. Attach step bodies and execute on the fault-tolerant runtime.
reports: list[str] = []
step_fns = {
    "preprocess": lambda inp: {"d^preprocess": list(range(10))},
    "train_a": lambda inp: {"d^train_a": sum(inp["d^preprocess"])},
    "train_b": lambda inp: {"d^train_b": max(inp["d^preprocess"])},
    "evaluate": lambda inp: {
        "d^evaluate": inp["d^train_a"] + inp["d^train_b"]
    },
    # sink step: no output ports — it delivers the result out of band
    "report": lambda inp: reports.append(f"score = {inp['d^evaluate']}") or {},
}
rt = Runtime(optimised, step_fns)
rt.run()
print("report:", reports[0])
assert reports == ["score = 54"]
assert rt.payload("cpu0", "d^evaluate") == 54  # shipped to cpu0 for report
print("OK")
