"""Observability: trace a scheduled run, diff it against the simulator.

Lowers the 1000 Genomes workflow with ``trace=True``, so the threaded
backend records an exec/send/recv span for everything it does.  The
resulting :class:`repro.obs.RunProfile` rides on the execution result:

* ``Plan.profile(result)`` aligns the recorded spans against the sched
  simulator's predicted timeline — per-step start drift, duration ratio,
  and achieved-vs-predicted cross-location bytes;
* ``CostModel.from_profile`` calibrates the simulator with the measured
  step durations, closing the predict → run → re-predict loop;
* ``profile.save_chrome_trace`` writes Chrome trace-event JSON — open it
  at https://ui.perfetto.dev (or ``chrome://tracing``) for a per-location
  timeline with send→recv flow arrows.

Run: ``PYTHONPATH=src python examples/profile_run.py``
"""

import json

import numpy as np

from repro import swirl
from repro.core.translate import genomes_1000
from repro.obs import validate_chrome_trace
from repro.sched import CostModel, NetworkModel

inst = genomes_1000(n=4, m=4, a=2, b=2, c=2)
rng = np.random.default_rng(0)
init = {("l^d", d): rng.random(4096) for d in inst.g("l^d")}


def make_fns():
    fns = {}
    for s in inst.workflow.steps:
        outs = inst.out_data(s)
        if s == "s0":
            fns[s] = lambda i, outs=outs: {o: init[("l^d", o)] for o in outs}
        else:
            fns[s] = lambda i, outs=outs: {
                o: float(sum(np.sum(np.atleast_1d(v)) for v in i.values()))
                for o in outs
            }
    return fns


# 1. Schedule against a two-rack cost model, then lower with trace=True.
network = NetworkModel.preset("two-rack")
plan = swirl.trace(inst).optimize().schedule(network)
exe = plan.lower("threaded", trace=True, timeout_s=60).compile(make_fns())
result = exe.run(initial_payloads=dict(init))

# 2. The profile is attached to the result: spans + pipeline phases.
profile = result.profile
print(profile.summary())
print()

# 3. Predicted vs actual: align the spans against the simulator.
report = plan.profile(result, network=network)
print(report.summary())
print()

# 4. Calibrate the cost model from the measured run and re-predict.
calibrated = CostModel.from_profile(profile)
recal = plan.profile(result, network=network, costs=calibrated)
print(
    f"makespan predicted with default costs:    "
    f"{report.predicted_makespan * 1e3:8.2f} ms"
)
print(
    f"makespan predicted with measured costs:   "
    f"{recal.predicted_makespan * 1e3:8.2f} ms"
)
print(f"makespan actually measured:               "
      f"{report.actual_makespan * 1e3:8.2f} ms")

# 5. Export a Perfetto-loadable Chrome trace and check it validates.
path = "genomes_trace.json"
profile.save_chrome_trace(path)
with open(path) as f:
    validate_chrome_trace(json.load(f))
print(f"\nwrote {path} ({len(profile.spans)} spans) — "
      "open at https://ui.perfetto.dev")
