"""Pipeline parallelism as a SWIRL plan.

The pipeline-stage graph (stages × microbatches) is a distributed workflow
instance; the encoding derives each stage's trace, and the stage-to-stage
send/recv pairs are exactly what lowers to ``ppermute`` on a stage mesh
axis.  This example runs the plan on the workflow runtime with jitted stage
functions (CPU), and prints the 1F1B-like schedule that falls out of SWIRL
reduction order — no scheduler was written, the dataflow IS the schedule.

Run: ``PYTHONPATH=src python examples/pipeline_parallel.py``
"""

import jax
import jax.numpy as jnp

from repro import swirl
from repro.core.translate import PipelineTranslator

N_STAGES, N_MICRO = 4, 3
D = 64

translator = PipelineTranslator(n_stages=N_STAGES, n_microbatches=N_MICRO)
plan = swirl.trace(translator).optimize()
inst = plan.instance
print(f"pipeline plan: {plan.system.total_actions()} actions, "
      f"{plan.system.comm_count()} comms (removed {plan.stats.removed})")
print(plan.system["stage1"].pretty()[:200], "…\n")

# Stage bodies: each stage applies its own jitted MLP block.
key = jax.random.key(0)
weights = [
    jax.random.normal(jax.random.fold_in(key, j), (D, D)) / jnp.sqrt(D)
    for j in range(N_STAGES)
]


@jax.jit
def stage_fn(w, x):
    return jax.nn.relu(x @ w)


final_outputs: dict[int, jax.Array] = {}


def make_fns():
    fns = {}
    for j in range(N_STAGES):
        for k in range(N_MICRO):
            def f(inputs, j=j, k=k):
                if j == 0:
                    x = jax.random.normal(jax.random.key(100 + k), (8, D))
                else:
                    x = inputs[f"act_{j - 1}to{j}_mb{k}"]
                y = stage_fn(weights[j], x)
                if j == N_STAGES - 1:
                    final_outputs[k] = y  # sink stage: deliver the result
                return {o: y for o in inst.out_data(f"stage{j}_mb{k}")}
            fns[f"stage{j}_mb{k}"] = f
    return fns


st = plan.lower("inprocess").compile(make_fns()).run().stats
print(f"executed {st.execs} stage-steps, {st.comms} stage transfers")
print("execution order:", " ".join(s for s, _, _ in st.exec_log))

# Reference: run the microbatches straight through one process.
import numpy as np

for k in range(N_MICRO):
    x = jax.random.normal(jax.random.key(100 + k), (8, D))
    for j in range(N_STAGES):
        x = stage_fn(weights[j], x)
    np.testing.assert_allclose(
        np.asarray(final_outputs[k]), np.asarray(x), atol=1e-6
    )
print("pipeline outputs match sequential execution ✓")
