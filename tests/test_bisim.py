"""Theorem 1: ``W ≈ ⟦W⟧`` — weak barbed bisimulation, checked exactly on
finite LTSs (paper examples + randomised instances)."""


from repro.core import encode, optimize, weak_barbed_bisimilar
from repro.core.parser import parse_system

from conftest import given, instances, settings
from test_graph import fig1_instance


def test_fig1_bisimilar():
    w = encode(fig1_instance())
    o, _ = optimize(w)
    assert weak_barbed_bisimilar(w, o)


def test_paper_example_r1_bisimilar():
    w = parse_system(
        "<l,{d},"
        "exec(s,{d}->{d1},{l}).send(d1->p1,l,l)"
        " | recv(p1,l,l).exec(s1,{d1}->{},{l})>"
    )
    o, stats = optimize(w)
    assert stats.removed == 2
    assert weak_barbed_bisimilar(w, o)


def test_paper_example_r2_bisimilar():
    w = parse_system(
        "<l,{d},exec(s,{d}->{d1},{l})."
        "(send(d1->p1,l,lp) | send(d1->p1,l,lp))>"
        " | <lp,{},"
        "recv(p1,l,lp).exec(s1,{d1}->{},{lp})"
        " | recv(p1,l,lp).exec(s2,{d1}->{},{lp})>"
    )
    o, stats = optimize(w)
    assert stats.removed == 2
    assert weak_barbed_bisimilar(w, o)


def test_non_bisimilar_detected():
    """Sanity: dropping an exec is observable — checker must say no."""
    w = parse_system("<l,{d},exec(s,{d}->{},{l}).exec(t,{d}->{},{l})>")
    o = parse_system("<l,{d},exec(s,{d}->{},{l})>")
    assert not weak_barbed_bisimilar(w, o)


@settings(max_examples=12, deadline=None)
@given(inst=instances(max_layers=2, max_width=2, max_locations=3))
def test_random_instances_bisimilar(inst):
    w = encode(inst)
    o, _ = optimize(w)
    assert weak_barbed_bisimilar(w, o, max_states=30_000)
