"""Front-end translators: DAG, train-pipeline, pipeline-parallel."""

import random

from repro.core import encode, optimize, run
from repro.core.translate import (
    DagTranslator,
    PipelineTranslator,
    TrainPipelineTranslator,
)
from repro.core.syntax import Exec, Send, actions


class TestDagTranslator:
    def test_diamond(self):
        t = DagTranslator(
            edges={"a": ["b", "c"], "b": ["d"], "c": ["d"], "d": []},
            mapping={"a": ("l0",), "b": ("l1",), "c": ("l2",), "d": ("l0",)},
        )
        inst = t.instance()
        assert inst.in_data("d") == {"d^b", "d^c"}
        w = t.translate()
        r = run(w, rng=random.Random(0))
        assert not r.deadlocked
        assert len(r.exec_events) == 4

    def test_colocation_optimises_away(self):
        t = DagTranslator(
            edges={"a": ["b"], "b": []},
            mapping={"a": ("l0",), "b": ("l0",)},
        )
        w = t.translate()
        o, stats = optimize(w)
        assert stats.removed_local == 2
        assert o.comm_count() == 0


class TestTrainPipeline:
    def test_plan_shape(self):
        inst = TrainPipelineTranslator(n_pods=3, with_checkpoint=True).instance()
        w, stats = optimize(encode(inst))
        # gradsync is a spatial-constraint step on all pods
        execs = [
            a for c in w.configs for a in actions(c.trace)
            if isinstance(a, Exec) and a.step == "gradsync"
        ]
        assert all(len(e.locations) == 3 for e in execs)
        assert len(execs) == 3  # one occurrence per pod trace
        # same-pod batch/grad transfers were removed by R1
        for c in w.configs:
            for a in actions(c.trace):
                if isinstance(a, Send) and a.data.startswith("batch_"):
                    raise AssertionError("batch should stay pod-local")

    def test_cross_pod_sends_are_gradients(self):
        inst = TrainPipelineTranslator(n_pods=2, with_checkpoint=False).instance()
        w, _ = optimize(encode(inst))
        cross = [
            a for c in w.configs for a in actions(c.trace)
            if isinstance(a, Send) and a.src != a.dst
        ]
        assert cross, "expected cross-pod communication"
        assert all(
            a.data.startswith("grad_") or a.data == "grad_sync" for a in cross
        )

    def test_runs_for_many_pods(self):
        inst = TrainPipelineTranslator(n_pods=4, with_checkpoint=True).instance()
        w, _ = optimize(encode(inst))
        r = run(w, rng=random.Random(1))
        assert not r.deadlocked


class TestPipelineTranslator:
    def test_stage_dependencies(self):
        inst = PipelineTranslator(n_stages=3, n_microbatches=2).instance()
        w = encode(inst)
        r = run(w, rng=random.Random(2))
        assert not r.deadlocked
        # stage j of mb k must execute after stage j-1 of mb k
        order = [e[1] for e in r.exec_events]
        for k in range(2):
            for j in range(1, 3):
                assert order.index(f"stage{j}_mb{k}") > order.index(
                    f"stage{j - 1}_mb{k}"
                )

    def test_transfers_match_stage_edges(self):
        inst = PipelineTranslator(n_stages=4, n_microbatches=1).instance()
        w, _ = optimize(encode(inst))
        sends = [
            a for c in w.configs for a in actions(c.trace)
            if isinstance(a, Send)
        ]
        assert len(sends) == 3  # one activation transfer per stage edge
