"""Shared fixtures and hypothesis strategies for the SWIRL test suite.

NOTE: no XLA_FLAGS here — smoke tests must see the real single CPU device;
only launch/dryrun.py forces the 512-device host platform.
"""

from __future__ import annotations

import os
import random

import pytest

from repro.core.graph import DistributedWorkflowInstance, make_workflow

# ---------------------------------------------------------------------------
# Optional hypothesis: the property tests use it, but the suite must collect
# and run without it.  Test modules import ``given``/``settings``/``st`` from
# here; when hypothesis is missing those become no-op shims whose ``given``
# marks the test as skipped, so every non-property test still runs.
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True

    # Profiles: "ci" is fully deterministic (derandomize) so CI never flakes
    # on a fresh example; "dev" keeps random exploration locally.  Both
    # disable deadlines — the differential tests spawn real OS processes,
    # whose wall-clock is environment noise, not a property violation.
    _relaxed = dict(
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.register_profile("ci", derandomize=True, **_relaxed)
    settings.register_profile("dev", **_relaxed)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):  # type: ignore[no-redef]
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis is not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):  # type: ignore[no-redef]
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Placeholder ``strategies`` namespace: any call returns None."""

        def __getattr__(self, name):
            def strategy(*_args, **_kwargs):
                return None

            return strategy

    st = _StrategyStub()  # type: ignore[assignment]

    class HealthCheck:  # type: ignore[no-redef]
        """Placeholder members (settings is a no-op without hypothesis)."""

        too_slow = None
        data_too_large = None
        filter_too_much = None
        function_scoped_fixture = None


# ---------------------------------------------------------------------------
# Random distributed workflow instances (layered DAGs)
# ---------------------------------------------------------------------------


def _instances_impl(
    draw,
    max_layers: int = 3,
    max_width: int = 3,
    max_locations: int = 4,
    multi_location_steps: bool = True,
):
    """A random layered DAG workflow instance (always acyclic, connected
    enough to be interesting, small enough for LTS exploration)."""
    n_layers = draw(st.integers(1, max_layers))
    widths = [draw(st.integers(1, max_width)) for _ in range(n_layers)]
    n_locs = draw(st.integers(1, max_locations))
    locations = [f"l{i}" for i in range(n_locs)]

    steps, ports, deps = [], [], []
    data, placement = [], {}
    mapping = {}
    prev_ports: list[str] = []
    initial: dict[str, set] = {}

    sid = 0
    for layer, width in enumerate(widths):
        new_ports = []
        for w in range(width):
            s = f"s{sid}"
            sid += 1
            steps.append(s)
            if multi_location_steps and draw(st.booleans()) and n_locs > 1:
                k = draw(st.integers(1, min(2, n_locs)))
                locs = draw(
                    st.lists(
                        st.sampled_from(locations), min_size=k, max_size=k,
                        unique=True,
                    )
                )
                mapping[s] = tuple(locs)
            else:
                mapping[s] = (draw(st.sampled_from(locations)),)
            # consume a subset of previous layer's ports
            if prev_ports:
                n_in = draw(st.integers(0, min(2, len(prev_ports))))
                ins = draw(
                    st.lists(
                        st.sampled_from(prev_ports),
                        min_size=n_in, max_size=n_in, unique=True,
                    )
                )
                for p in ins:
                    deps.append((p, s))
            # produce one port (except sometimes sinks)
            if layer < n_layers - 1 or draw(st.booleans()):
                p = f"p{s}"
                d = f"d{s}"
                ports.append(p)
                data.append(d)
                placement[d] = p
                deps.append((s, p))
                new_ports.append(p)
        prev_ports = new_ports

    # Drop ports nobody consumes? keep them (legal).  Ensure every consumed
    # port has a producer (by construction it does).
    wf = make_workflow(steps, ports, deps)
    inst = DistributedWorkflowInstance(
        workflow=wf,
        locations=frozenset(locations),
        mapping=mapping,
        data=frozenset(data),
        placement=placement,
        initial_data={l: frozenset(ds) for l, ds in initial.items()},
    )
    return inst


if HAVE_HYPOTHESIS:
    instances = st.composite(_instances_impl)
else:

    def instances(**_kwargs):
        return None


@pytest.fixture
def rng():
    return random.Random(0)


def identity_step_fns(inst: DistributedWorkflowInstance):
    """Step fns producing deterministic string payloads."""

    def mk(step, outs):
        def fn(inputs):
            sig = ",".join(f"{k}={inputs[k]}" for k in sorted(inputs))
            return {d: f"{step}({sig})" for d in outs}

        return fn

    return {s: mk(s, inst.out_data(s)) for s in inst.workflow.steps}
