"""Optimisation rewriting — Def. 15, checked against the paper's §4 examples."""

from repro.core import encode, optimize
from repro.core.parser import parse_system
from repro.core.syntax import Exec, Recv, Send, actions, congruent
from repro.core.translate import genomes_1000


class TestPaperExample1:
    """§4 first example: same-location send/recv pair is removed (R1)."""

    def test_local_comm_removed(self):
        w = parse_system(
            "<l,{},"
            "recv(p,l1,l).exec(s,{d}->{d1},{l}).send(d1->p1,l,l)"
            " | recv(p1,l,l).exec(s1,{d1}->{},{l})>"
        )
        o, stats = optimize(w)
        want = parse_system(
            "<l,{},recv(p,l1,l).exec(s,{d}->{d1},{l}) | exec(s1,{d1}->{},{l})>"
        )
        assert congruent(o["l"].trace, want["l"].trace)
        assert stats.removed_local == 2  # the send and the recv


class TestPaperExample2:
    """§4 second example: duplicate sends over one port collapse (R2)."""

    def test_duplicate_sends_removed(self):
        w = parse_system(
            "<l,{},recv(p,l1,l).exec(s,{d}->{d1},{l})."
            "(send(d1->p1,l,lp) | send(d1->p1,l,lp) | send(d1->p1,l,lp))>"
            " | <lp,{},"
            "recv(p1,l,lp).exec(s1,{d1}->{},{lp})"
            " | recv(p1,l,lp).exec(s2,{d1}->{},{lp})"
            " | recv(p1,l,lp).exec(s3,{d1}->{},{lp})>"
        )
        o, stats = optimize(w)
        sends = [a for a in actions(o["l"].trace) if isinstance(a, Send)]
        recvs = [a for a in actions(o["lp"].trace) if isinstance(a, Recv)]
        assert len(sends) == 1
        assert len(recvs) == 1
        execs = [a for a in actions(o["lp"].trace) if isinstance(a, Exec)]
        assert {e.step for e in execs} == {"s1", "s2", "s3"}
        assert stats.removed_duplicate == 4  # 2 sends + 2 recvs


class TestOptimizerProperties:
    def test_execs_never_removed(self):
        w = encode(genomes_1000(n=5, m=4, a=2, b=2, c=2))
        o, _ = optimize(w)
        before = sorted(
            a.step for c in w.configs for a in actions(c.trace) if isinstance(a, Exec)
        )
        after = sorted(
            a.step for c in o.configs for a in actions(c.trace) if isinstance(a, Exec)
        )
        assert before == after

    def test_idempotent(self):
        w = encode(genomes_1000(n=4, m=3, a=2, b=2, c=2))
        o1, s1 = optimize(w)
        o2, s2 = optimize(o1)
        assert o1 == o2
        assert s2.removed == 0

    def test_send_recv_balance(self):
        """Optimised systems keep sends and recvs matched per channel."""
        w = encode(genomes_1000(n=4, m=3, a=2, b=2, c=2))
        o, _ = optimize(w)
        sends: dict = {}
        recvs: dict = {}
        for c in o.configs:
            for a in actions(c.trace):
                if isinstance(a, Send) and a.src != a.dst:
                    sends[(a.port, a.src, a.dst)] = sends.get((a.port, a.src, a.dst), 0) + 1
                if isinstance(a, Recv) and a.src != a.dst:
                    recvs[(a.port, a.src, a.dst)] = recvs.get((a.port, a.src, a.dst), 0) + 1
        assert sends == recvs


class TestR3SpatialDedup:
    """Beyond-paper R3: transfers to co-executing locations are elided."""

    def test_removes_rebroadcast_to_participants(self):
        from repro.core import optimize_spatial, run, weak_barbed_bisimilar
        from repro.core.parser import parse_system
        import random

        # s is executed jointly by a and b; both then 'receive' its output —
        # the encoding's conservative pattern.
        w = parse_system(
            "<a,{x},exec(s,{x}->{d},{a,b}).send(d->p,a,b)"
            " | recv(p,b,a).exec(t,{d}->{},{a})>"
            " | <b,{x},exec(s,{x}->{d},{a,b}).send(d->p,b,a)"
            " | recv(p,a,b).exec(u,{d}->{},{b})>"
        )
        o, stats = optimize_spatial(w)
        assert stats.removed == 4  # both cross sends + both recvs
        assert o.comm_count() == 0
        assert weak_barbed_bisimilar(w, o)
        r = run(o, rng=random.Random(0))
        assert not r.deadlocked and len(r.exec_events) == 3

    def test_trainer_gradsync_collapse(self):
        from repro.core import encode, optimize, optimize_spatial
        from repro.core.translate import TrainPipelineTranslator

        inst = TrainPipelineTranslator(n_pods=3, with_checkpoint=False).instance()
        w, _ = optimize(encode(inst))
        o, stats = optimize_spatial(w)
        # grad_sync is produced by the gradsync exec on ALL pods → the
        # n·(n−1) re-broadcast pairs vanish; the grad_i feeds remain.
        assert stats.removed == 2 * 3 * 2
        from repro.core.syntax import Send, actions

        remaining = [
            a for c in o.configs for a in actions(c.trace)
            if isinstance(a, Send) and a.src != a.dst
        ]
        assert all(a.data.startswith("grad_") for a in remaining)

    def test_r3_bisimilar_random(self):
        from repro.core import encode, optimize, optimize_spatial, weak_barbed_bisimilar
        from repro.core.translate import TrainPipelineTranslator

        inst = TrainPipelineTranslator(n_pods=2, with_checkpoint=False).instance()
        w, _ = optimize(encode(inst))
        o, _ = optimize_spatial(w)
        assert weak_barbed_bisimilar(w, o, max_states=50_000)

    def test_noop_without_spatial_steps(self):
        from repro.core import encode, optimize, optimize_spatial
        from repro.core.translate import genomes_1000

        w, _ = optimize(encode(genomes_1000(n=3, m=2, a=2, b=2, c=2)))
        o, stats = optimize_spatial(w)
        assert stats.removed == 0
        assert o == w


class TestGenomesAppendixB:
    """App. B: when m > b, the IM→MO broadcast collapses from m to b sends."""

    def test_im_broadcast_collapse(self):
        m, b = 3, 2
        inst = genomes_1000(n=4, m=m, a=2, b=b, c=2)
        w = encode(inst)
        o, _ = optimize(w)
        sends_before = [
            a for a in actions(w["l^IM"].trace)
            if isinstance(a, Send) and a.data == "d^IM" and a.dst.startswith("l^MO")
        ]
        sends_after = [
            a for a in actions(o["l^IM"].trace)
            if isinstance(a, Send) and a.data == "d^IM" and a.dst.startswith("l^MO")
        ]
        assert len(sends_before) == m
        assert len(sends_after) == b

    def test_mo_location_keeps_execs(self):
        inst = genomes_1000(n=4, m=3, a=2, b=2, c=2)
        o, _ = optimize(encode(inst))
        execs = [
            a for a in actions(o["l^MO_1"].trace) if isinstance(a, Exec)
        ]
        assert len(execs) == 2  # ceil(3/2) MO steps on location 1
