"""Observability (repro.obs): tracing, exporters, profiling, metrics.

Covers the repro.obs acceptance criteria:

* **identical span schemas across backends** — the same workflow traced
  on every registered backend yields the same timing-free
  :meth:`SpanEvent.identity` multiset (the differential unit);
* **zero-cost disabled path** — a disabled recorder performs no
  allocations per rejected span, and untraced results carry no profile;
* **crash-resilient multiprocess spans** — a SIGKILLed worker's
  previously shipped spans survive in ``program.last_profile``;
* **exporters** — Chrome trace JSON is schema-valid and survives a
  file round-trip;
* **predicted-vs-actual** — :meth:`Plan.profile` aligns recorded spans
  against the sched simulator, and :meth:`CostModel.from_profile`
  calibrates the simulator to measured step durations on 1000 Genomes.
"""

from __future__ import annotations

import gc
import json
import signal
import sys
import time

import numpy as np
import pytest

from repro import swirl
from repro.backends import WorkerFailedError, available_backends
from repro.core.translate import genomes_1000
from repro.obs import (
    RunProfile,
    SpanEvent,
    TraceRecorder,
    chrome_trace,
    validate_chrome_trace,
    write_chrome_trace,
)
from repro.sched import CostModel, NetworkModel

EDGES = {
    "preprocess": ["train_a", "train_b"],
    "train_a": ["evaluate"],
    "train_b": ["evaluate"],
    "evaluate": ["report"],
    "report": [],
}
MAPPING = {
    "preprocess": ("cpu0",),
    "train_a": ("gpu0",),
    "train_b": ("gpu1",),
    "evaluate": ("gpu0",),
    "report": ("cpu0",),
}

BACKEND_OPTIONS = {
    "threaded": {"timeout_s": 60},
    "multiprocess": {"timeout_s": 120},
}


def quickstart_steps():
    return {
        "preprocess": lambda inp: {"d^preprocess": list(range(10))},
        "train_a": lambda inp: {"d^train_a": sum(inp["d^preprocess"])},
        "train_b": lambda inp: {"d^train_b": max(inp["d^preprocess"])},
        "evaluate": lambda inp: {
            "d^evaluate": inp["d^train_a"] + inp["d^train_b"]
        },
        "report": lambda inp: {},
    }


@pytest.fixture
def plan():
    return swirl.trace(EDGES, mapping=MAPPING).optimize()


def traced_run(plan, backend, **extra):
    opts = {**BACKEND_OPTIONS.get(backend, {}), **extra}
    exe = plan.lower(backend, trace=True, **opts).compile(quickstart_steps())
    return exe.run()


# ---------------------------------------------------------------------------
# The recorder primitive
# ---------------------------------------------------------------------------


class TestTraceRecorder:
    def test_span_roundtrip(self):
        rec = TraceRecorder()
        rec.span("exec", "l0", "s1", 0.1, 0.2)
        rec.span("send", "l0", "d1", 0.2, 0.3, src="l0", dst="l1", nbytes=8)
        assert len(rec) == 2
        spans = rec.drain()
        assert len(rec) == 0
        assert [s.kind for s in spans] == ["exec", "send"]
        assert spans[1].nbytes == 8 and spans[1].duration == pytest.approx(0.1)

    def test_absorb_applies_clock_offset(self):
        rec = TraceRecorder(t_zero=0.0)
        worker_spans = [SpanEvent("exec", "w0", "s", 10.0, 11.0)]
        rec.absorb(worker_spans, offset=-9.5)
        (merged,) = rec.drain()
        assert merged.start == pytest.approx(0.5)
        assert merged.end == pytest.approx(1.5)

    def test_drain_merge_ordered_by_location(self):
        rec = TraceRecorder()
        rec.span("exec", "z", "s1", 0.0, 1.0)
        rec.span("exec", "a", "s2", 0.0, 1.0)
        assert [s.location for s in rec.drain()] == ["a", "z"]

    def test_disabled_span_allocates_nothing(self):
        """The disabled hot path must not allocate per rejected span."""
        rec = TraceRecorder(enabled=False)
        rec.span("exec", "l0", "warmup", 0.0, 1.0)  # warm any lazy state
        gc.disable()
        try:
            gc.collect()
            before = sys.getallocatedblocks()
            for _ in range(10_000):
                rec.span("exec", "l0", "step", 0.0, 1.0)
            after = sys.getallocatedblocks()
        finally:
            gc.enable()
        # Zero in principle; a few blocks of slack for interpreter noise.
        assert after - before <= 16
        assert len(rec) == 0


class TestPayloadSizing:
    """``payload_nbytes`` must size without serializing the payload."""

    def test_array_and_buffer_sizes_are_exact(self):
        from repro.obs.events import payload_nbytes

        assert payload_nbytes(np.zeros(65536)) == 65536 * 8
        assert payload_nbytes(b"x" * 4096) == 4096
        assert payload_nbytes(bytearray(8192)) == 8192
        assert payload_nbytes(memoryview(bytearray(1024))) == 1024
        # Opaque objects still get the getsizeof fallback.
        assert payload_nbytes({"a": 1}) == sys.getsizeof({"a": 1})

    def test_sizing_large_array_allocates_nothing(self):
        """Like the disabled-recorder path: O(1) blocks, no copy."""
        from repro.obs.events import payload_nbytes

        arr = np.zeros(1 << 20)  # 8 MB — a copy or pickle would show
        payload_nbytes(arr)  # warm any lazy state
        gc.disable()
        try:
            gc.collect()
            before = sys.getallocatedblocks()
            for _ in range(1_000):
                payload_nbytes(arr)
            after = sys.getallocatedblocks()
        finally:
            gc.enable()
        assert after - before <= 16

    def test_sizing_buffer_payload_is_zero_copy(self):
        """memoryview sizing must not materialise the buffer's bytes."""
        import tracemalloc

        from repro.obs.events import payload_nbytes

        buf = bytearray(4 << 20)  # no .nbytes attribute — memoryview path
        payload_nbytes(buf)
        tracemalloc.start()
        try:
            payload_nbytes(buf)
            _, peak = tracemalloc.get_traced_memory()
        finally:
            tracemalloc.stop()
        assert peak < 64 * 1024  # a copy would show up as ≥4 MB


# ---------------------------------------------------------------------------
# Identical span schemas across every backend
# ---------------------------------------------------------------------------


class TestCrossBackendSpans:
    def test_span_schema_identical_on_all_backends(self, plan):
        backends = available_backends()
        profiles = {}
        for b in backends:
            result = traced_run(plan, b)
            assert isinstance(result.profile, RunProfile), b
            assert result.profile.backend == b
            profiles[b] = result.profile
        reference = profiles[backends[0]].span_schema()
        assert reference, "traced run recorded no spans"
        for b in backends[1:]:
            assert profiles[b].span_schema() == reference, (
                f"{b} span schema diverged from {backends[0]}"
            )

    @pytest.mark.parametrize("seed", range(5))
    def test_span_schema_identical_on_seeded_dags(self, seed):
        """Random layered DAGs: every backend, same span multiset."""
        import random

        from test_differential import random_instance

        inst = random_instance(random.Random(1000 + seed))
        dag_plan = swirl.trace(inst).optimize()
        fns = {
            s: (lambda i, _outs=inst.out_data(s): {d: 1 for d in _outs})
            for s in inst.workflow.steps
        }
        schemas = {}
        for b in available_backends():
            opts = BACKEND_OPTIONS.get(b, {})
            exe = dag_plan.lower(b, trace=True, **opts).compile(fns)
            schemas[b] = exe.run().profile.span_schema()
        reference_backend = available_backends()[0]
        for b, schema in schemas.items():
            assert schema == schemas[reference_backend], (
                f"seed {seed}: {b} diverged from {reference_backend}"
            )

    def test_exec_spans_cover_every_step_placement(self, plan):
        profile = traced_run(plan, "inprocess").profile
        execs = {
            (ev.name, ev.location)
            for ev in profile.spans
            if ev.kind == "exec"
        }
        expected = {
            (step, loc)
            for step, locs in plan.placement().items()
            for loc in locs
        }
        assert execs == expected

    def test_send_recv_pair_and_carry_bytes(self, plan):
        profile = traced_run(plan, "threaded").profile
        sends = [ev for ev in profile.spans if ev.kind == "send"]
        recvs = [ev for ev in profile.spans if ev.kind == "recv"]
        assert sends and len(sends) == len(recvs)
        # Every transfer shows up once per side, on the right endpoint.
        assert {(s.src, s.dst) for s in sends} == {
            (r.src, r.dst) for r in recvs
        }
        assert all(s.location == s.src for s in sends)
        assert all(r.location == r.dst for r in recvs)
        assert all(s.src != s.dst for s in sends)
        assert all((s.nbytes or 0) > 0 for s in sends)
        assert profile.cross_bytes() == sum(s.nbytes for s in sends)

    def test_untraced_run_has_no_profile(self, plan):
        exe = plan.lower("inprocess").compile(quickstart_steps())
        assert exe.run().profile is None

    def test_run_many_attaches_one_profile_per_result(self, plan):
        exe = plan.lower("threaded", trace=True, timeout_s=60).compile(
            quickstart_steps()
        )
        results = exe.run_many([None, None, None])
        schemas = {r.profile.span_schema() for r in results}
        assert len(schemas) == 1  # instances are schema-identical
        assert all(len(r.profile.spans) > 0 for r in results)

    def test_profile_carries_pipeline_phases(self, plan):
        result = traced_run(plan, "inprocess")
        labels = [label for label, _ in result.profile.phases]
        assert "lower" in labels
        assert "compile[inprocess]" in labels

    def test_explain_renders_lower_and_compile_timings(self, plan):
        plan.lower("inprocess").compile(quickstart_steps())
        report = plan.explain()
        assert "lower" in report
        assert "compile[inprocess]" in report


# ---------------------------------------------------------------------------
# Multiprocess: spans survive a killed worker
# ---------------------------------------------------------------------------


class TestMultiprocessSpans:
    def test_spans_survive_sigkill_up_to_last_merge(self, plan):
        exe = plan.lower(
            "multiprocess",
            trace=True,
            _kill_at_step="evaluate",
            timeout_s=120,
        ).compile(quickstart_steps())
        with pytest.raises(WorkerFailedError) as e:
            exe.run()
        assert e.value.exitcode == -signal.SIGKILL
        profile = exe.program.last_profile
        assert profile is not None
        # train_a ran on the killed worker (gpu0) *before* evaluate; its
        # spans were shipped on the pre-step flush and must survive.
        exec_steps = {ev.name for ev in profile.spans if ev.kind == "exec"}
        assert "train_a" in exec_steps
        assert "evaluate" not in exec_steps

    def test_worker_spans_align_to_coordinator_clock(self, plan):
        result = traced_run(plan, "multiprocess")
        spans = result.profile.spans
        assert spans
        # Realigned worker times are small offsets from run start — never
        # raw worker-monotonic stamps (hours of uptime).
        assert all(0.0 <= s.start < 120.0 for s in spans)
        assert all(s.end >= s.start for s in spans)


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------


class TestChromeTrace:
    def test_schema_valid_and_roundtrips(self, plan, tmp_path):
        profile = traced_run(plan, "threaded").profile
        obj = profile.chrome_trace()
        validate_chrome_trace(obj)
        path = tmp_path / "trace.json"
        profile.save_chrome_trace(str(path))
        loaded = json.loads(path.read_text())
        validate_chrome_trace(loaded)
        assert loaded == json.loads(json.dumps(obj))

    def test_tracks_named_after_locations(self, plan):
        obj = traced_run(plan, "inprocess").profile.chrome_trace()
        names = {
            ev["args"]["name"]
            for ev in obj["traceEvents"]
            if ev["ph"] == "M" and ev["name"] == "thread_name"
        }
        assert {"cpu0", "gpu0", "gpu1"} <= names

    def test_flow_events_pair_sends_to_recvs(self, plan):
        obj = traced_run(plan, "threaded").profile.chrome_trace()
        starts = [e for e in obj["traceEvents"] if e["ph"] == "s"]
        finishes = [e for e in obj["traceEvents"] if e["ph"] == "f"]
        assert starts and len(starts) == len(finishes)
        assert {e["id"] for e in starts} == {e["id"] for e in finishes}

    def test_validator_rejects_malformed(self):
        with pytest.raises(ValueError):
            validate_chrome_trace({"no": "traceEvents"})
        with pytest.raises(ValueError):
            validate_chrome_trace(
                {"traceEvents": [{"ph": "X", "pid": 1, "tid": 1}]}
            )

    def test_plain_export_from_bare_spans(self, tmp_path):
        spans = (
            SpanEvent("exec", "l0", "s1", 0.0, 0.5),
            SpanEvent("exec", "l0", "s2", 0.5, 0.9),
        )
        path = tmp_path / "bare.json"
        write_chrome_trace(str(path), spans, phases=(("lower", 0.001),))
        obj = json.loads(path.read_text())
        validate_chrome_trace(obj)
        assert chrome_trace(spans)["traceEvents"]


# ---------------------------------------------------------------------------
# Predicted vs actual: Plan.profile + CostModel.from_profile
# ---------------------------------------------------------------------------


def _genomes_setup(sleep_s):
    inst = genomes_1000(n=2, m=2, a=1, b=1, c=1)
    rng = np.random.default_rng(0)
    init = {("l^d", d): rng.random(256) for d in inst.g("l^d")}
    fns = {}
    for s in inst.workflow.steps:
        outs = inst.out_data(s)

        def fn(ins, _outs=outs):
            if sleep_s:
                time.sleep(sleep_s)
            return {
                d: sum(float(np.sum(np.atleast_1d(v))) for v in ins.values())
                for d in _outs
            }

        fns[s] = fn
    return inst, init, fns


class TestPredictedVsActual:
    def test_profile_aligns_scheduled_genomes(self):
        inst, init, fns = _genomes_setup(sleep_s=0.0)
        plan = swirl.trace(inst).optimize().schedule(
            NetworkModel.preset("uniform")
        )
        exe = plan.lower("threaded", trace=True, timeout_s=60).compile(fns)
        result = exe.run(initial_payloads=init)
        report = plan.profile(result)
        assert report.predicted_makespan > 0
        assert report.actual_makespan > 0
        assert report.drifts, "no steps aligned"
        predicted_steps = {d.step for d in report.drifts}
        assert predicted_steps <= set(plan.steps())
        assert not report.unmatched_actual
        assert "predicted vs actual" in report.summary()

    def test_profile_requires_traced_result(self):
        inst, init, fns = _genomes_setup(sleep_s=0.0)
        plan = swirl.trace(inst).optimize()
        exe = plan.lower("inprocess").compile(fns)
        result = exe.run(initial_payloads=init)
        with pytest.raises(ValueError, match="trace=True"):
            plan.profile(result)

    def test_cost_model_calibration_closes_the_loop(self):
        """from_profile → re-schedule → prediction within tolerance."""
        sleep_s = 0.02
        inst, init, fns = _genomes_setup(sleep_s)
        network = NetworkModel.preset("uniform", bandwidth=1e9, latency=1e-5)
        plan = swirl.trace(inst).optimize().schedule(network)
        result = (
            plan.lower("threaded", trace=True, timeout_s=60)
            .compile(fns)
            .run(initial_payloads=init)
        )
        model = CostModel.from_profile(result.profile)
        # Every measured step slept for sleep_s: the calibrated cost must
        # be ≥ the sleep and within loose overhead bounds of it.
        for step in plan.steps():
            assert sleep_s * 0.9 <= model.exec_s(step) <= sleep_s * 5.0, step
        replan = swirl.trace(inst).optimize().schedule(
            network, costs=model
        )
        report = replan.profile(
            result, network=network, costs=model
        )
        # The calibrated simulator predicts the measured makespan within
        # a generous CI-safe tolerance (sleeps dominate, comms are ~free).
        ratio = report.predicted_makespan / report.actual_makespan
        assert 0.2 <= ratio <= 3.0, report.summary()

    def test_from_profile_accepts_mappings(self):
        m = CostModel.from_profile({"a": 0.5, "b": [0.1, 0.3]})
        assert m.exec_s("a") == pytest.approx(0.5)
        assert m.exec_s("b") == pytest.approx(0.2)
        with pytest.raises(TypeError):
            CostModel.from_profile(42)
